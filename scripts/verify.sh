#!/usr/bin/env bash
# One-stop verification: the tier-1 gate plus a kernel-bench smoke.
#
#   scripts/verify.sh            # build + tests + quick kernel bench
#   SKIP_BENCH=1 scripts/verify.sh   # tier-1 only
#
# Runs fully offline with default features (no xla/PJRT required).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== examples build (quickstart, pareto_recovery, elastic_serving, e2e_flexrank) =="
cargo build --release --examples

echo "== tier-1: cargo test -q =="
cargo test -q

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== kernel bench smoke (BENCH_QUICK=1) =="
  BENCH_QUICK=1 cargo bench -p flexrank --bench kernels
  echo "wrote results/BENCH_kernels.json"
fi

echo "verify OK"
