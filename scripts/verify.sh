#!/usr/bin/env bash
# One-stop verification: the tier-1 gate plus a kernel-bench smoke.
#
#   scripts/verify.sh            # build + tests + quick kernel bench
#   SKIP_BENCH=1 scripts/verify.sh   # tier-1 only
#
# Runs fully offline with default features (no xla/PJRT required).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== examples build (quickstart, pareto_recovery, elastic_serving, e2e_flexrank) =="
cargo build --release --examples

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== attention equivalence suite (release: streaming ≡ blocked ≡ scalar + grads) =="
cargo test --release -q --test attention_equivalence

echo "== decode equivalence suite (release: paged decode ≡ full window + continuous ≡ sequential) =="
cargo test --release -q --test decode_equivalence

echo "== ingest fuzz smoke (release: mutated frames/JSON panic-free + allocator-counted zero-alloc) =="
cargo test --release -q --test fuzz_ingest

echo "== listener e2e (release: sockets ≡ in-process replay, shed, drain, adversarial streams) =="
cargo test --release -q --test listener_serving

echo "== routing/controller suite (release: hysteresis ≤1 switch/dwell, never-demote budget, bursty e2e) =="
cargo test --release -q --test routing_controller

echo "== trace-scenario smoke (elastic policy over a bursty multi-tenant trace) =="
cargo run --release --bin repro -- serve --config tiny --policy elastic --scenario bursty \
  --tenants --requests 40 --rate 2000 --queue-cap 32 --dwell-ms 5

echo "== repro lint (static invariants R1-R4 over rust/src) =="
cargo run --release --bin repro -- lint

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== kernel bench smoke (BENCH_QUICK=1) =="
  BENCH_QUICK=1 cargo bench -p flexrank --bench kernels
  # The bench writes under FLEXRANK_RESULTS when set (flexrank::results_dir).
  BENCH_JSON="${FLEXRANK_RESULTS:-results}/BENCH_kernels.json"
  echo "wrote ${BENCH_JSON}"
  echo "== BENCH_kernels.json schema: flash + decode + simd_vs_scalar + quantized_vs_f32 rows =="
  BENCH_JSON="$BENCH_JSON" python3 - <<'EOF'
import json
import os

rows = json.load(open(os.environ["BENCH_JSON"]))
flash = [r for r in rows if r["kernel"].startswith("attention_flash ")]
assert flash, "no attention_flash rows in results/BENCH_kernels.json"
assert len(flash) >= 3, f"expected flash rows at 1x/4x/16x seq, got {len(flash)}"
decode = [r for r in rows if r["kernel"].startswith("attention_decode ")]
assert decode, "no attention_decode rows in results/BENCH_kernels.json"
assert len(decode) >= 3, f"expected decode rows at 1x/4x/16x context, got {len(decode)}"
for r in rows:
    for key in ("kernel", "shape", "mean_ns", "gflops", "speedup_vs_reference"):
        assert key in r, f"row missing '{key}': {r}"
simd = [r for r in rows if r["kernel"].startswith("simd_vs_scalar ")]
assert any(
    r["kernel"].startswith("simd_vs_scalar matmul_f32 ") for r in simd
), "no simd_vs_scalar matmul_f32 rows"
assert any(
    r["kernel"].startswith("simd_vs_scalar gar_emit_f32 ") for r in simd
), "no simd_vs_scalar gar_emit_f32 rows"
quant = [r for r in rows if r["kernel"].startswith("quantized_vs_f32 ")]
assert any(" bf16 " in r["kernel"] for r in quant), "no quantized_vs_f32 bf16 rows"
assert any(" i8 " in r["kernel"] for r in quant), "no quantized_vs_f32 i8 rows"
for r in flash + decode + simd + quant:
    assert r["mean_ns"] > 0 and r["gflops"] > 0, f"degenerate row: {r}"
    assert r["speedup_vs_reference"] > 0, f"degenerate speedup: {r}"
print(
    f"OK: {len(flash)} flash, {len(decode)} decode, {len(simd)} simd_vs_scalar, "
    f"{len(quant)} quantized_vs_f32 rows, schema valid across {len(rows)} records"
)
EOF
fi

echo "verify OK"
