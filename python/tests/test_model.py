"""L2 model-level tests: teacher/student equivalence, GAR exactness, masks,
train steps, AdamW, covariance capture — all at the tiny config so the suite
stays fast."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def cfg():
    return M.load_config("tiny")


@pytest.fixture(scope="module")
def teacher(cfg):
    return M.init_teacher(cfg, seed=0)


@pytest.fixture(scope="module")
def student(cfg, teacher):
    return M.init_student_svd(cfg, teacher)


def tokens(cfg, seed=0, extra=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, cfg.vocab, (cfg.batch_eval, cfg.seq_len + extra)), jnp.int32
    )


def test_teacher_fwd_shape_and_finite(cfg, teacher):
    t = tokens(cfg)
    logits = M.teacher_fwd(cfg, teacher, t)
    assert logits.shape == (cfg.batch_eval, cfg.seq_len, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_student_full_rank_equals_teacher(cfg, teacher, student):
    t = tokens(cfg, 1)
    tl = M.teacher_fwd(cfg, teacher, t)
    sl = M.student_fwd(cfg, student, M.full_masks(cfg), t)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(tl), rtol=3e-3, atol=3e-3)


def test_masking_reduces_monotonically(cfg, teacher, student):
    """Truncation error (vs teacher) must not grow with more kept ranks."""
    t = tokens(cfg, 2)
    tl = np.asarray(M.teacher_fwd(cfg, teacher, t))
    errs = []
    for keep in [cfg.rank_full // 4, cfg.rank_full // 2, cfg.rank_full]:
        masks = np.zeros((cfg.n_blocks, 4, cfg.rank_full), np.float32)
        masks[:, :, :keep] = 1.0
        sl = np.asarray(M.student_fwd(cfg, student, jnp.asarray(masks), t))
        errs.append(float(np.abs(sl - tl).mean()))
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[2] < 1e-2


def test_covariance_outputs_match_direct_computation(cfg, teacher):
    t = tokens(cfg, 3)
    logits, covs = M.teacher_fwd_acts(cfg, teacher, t)
    assert len(covs) == cfg.n_fact_layers
    # Every cov must be PSD-symmetric with the right dims.
    dims = cfg.layer_dims()
    expected = []
    for _ in range(cfg.n_blocks):
        for kind in M.LAYER_KINDS:
            expected.append(dims[kind][0])
    for c, n in zip(covs, expected):
        c = np.asarray(c)
        assert c.shape == (n, n)
        np.testing.assert_allclose(c, c.T, rtol=1e-4, atol=1e-4)
        ev = np.linalg.eigvalsh(c)
        assert ev.min() > -1e-3
    # Logits must equal the plain forward.
    np.testing.assert_allclose(
        logits, M.teacher_fwd(cfg, teacher, t), rtol=1e-5, atol=1e-5
    )


def test_teacher_train_step_reduces_loss(cfg, teacher):
    t = tokens(cfg, 4, extra=1)
    p = teacher
    m = M.zeros_like_tree(p)
    v = M.zeros_like_tree(p)
    losses = []
    for step in range(8):
        p, m, v, loss = jax.jit(
            lambda p, m, v, s, t: M.teacher_train_step(cfg, p, m, v, s, t)
        )(p, m, v, jnp.float32(step + 1), t)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_kd_step_loss_near_zero_at_full_rank(cfg, teacher, student):
    t = tokens(cfg, 5, extra=1)
    m = M.zeros_like_tree(student)
    v = M.zeros_like_tree(student)
    _, _, _, loss = M.kd_train_step(
        cfg, student, m, v, jnp.float32(1.0), teacher, M.full_masks(cfg), t
    )
    # Student == teacher at init, so the KD loss must be ~0.
    assert float(loss) < 1e-3, float(loss)


def test_kd_step_improves_truncated_student(cfg, teacher, student):
    masks = np.zeros((cfg.n_blocks, 4, cfg.rank_full), np.float32)
    masks[:, :, : cfg.rank_full // 4] = 1.0
    masks = jnp.asarray(masks)
    t = tokens(cfg, 6, extra=1)
    p = student
    m = M.zeros_like_tree(p)
    v = M.zeros_like_tree(p)
    step_fn = jax.jit(
        lambda p, m, v, s, t: M.kd_train_step(cfg, p, m, v, s, teacher, masks, t)
    )
    first = None
    loss = None
    for step in range(10):
        p, m, v, loss = step_fn(p, m, v, jnp.float32(step + 1), t)
        if first is None:
            first = float(loss)
    assert float(loss) < first, (first, float(loss))


def test_gar_param_spec_omits_empty_uhat(cfg):
    full = [cfg.rank_full] * cfg.n_fact_layers
    spec = M.gar_param_spec(cfg, full)
    names = [n for n, _ in spec]
    # proj and fcp at full rank are square => no uhat entries.
    assert not any("proj_uhat" in n for n in names)
    assert not any("fcp_uhat" in n for n in names)
    assert any("qkv_uhat" in n for n in names)
    # No zero-size shapes anywhere.
    assert all(np.prod(s) > 0 for _, s in spec)


def test_gar_fwd_matches_masked_student(cfg, teacher, student):
    r = cfg.rank_full // 2
    profile = [r] * cfg.n_fact_layers
    masks = np.zeros((cfg.n_blocks, 4, cfg.rank_full), np.float32)
    masks[:, :, :r] = 1.0
    t = tokens(cfg, 7)
    sl = M.student_fwd(cfg, student, jnp.asarray(masks), t)

    flat = [student["tok_emb"], student["pos_emb"], student["lnf_g"], student["lnf_b"]]
    for i, blk in enumerate(student["blocks"]):
        for g in ("ln1_g", "ln1_b", "ln2_g", "ln2_b"):
            flat.append(blk[g])
        for kind in M.LAYER_KINDS:
            u = np.asarray(blk[f"{kind}_u"])[:, :r]
            v = np.asarray(blk[f"{kind}_v"])[:, :r]
            G = np.linalg.inv(u[:r, :])
            u_t = (u @ G)[r:]
            v_t = v @ np.linalg.inv(G).T
            if u_t.shape[0] > 0:
                flat.append(jnp.asarray(u_t, jnp.float32))
            flat.append(jnp.asarray(v_t, jnp.float32))
            flat.append(blk[f"{kind}_b"])
    gl = M.gar_fwd(cfg, flat, profile, t)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(sl), rtol=2e-2, atol=2e-2)


def test_adamw_moves_toward_target(cfg):
    # AdamW on a quadratic must shrink the parameter.
    p = {"w": jnp.ones((4,), jnp.float32) * 5.0}
    m = M.zeros_like_tree(p)
    v = M.zeros_like_tree(p)
    w0 = float(jnp.abs(p["w"]).max())
    for step in range(300):
        g = {"w": p["w"]}  # grad of 0.5 w^2
        p, m, v = M.adamw_update(cfg, p, g, m, v, jnp.float32(step + 1))
    w1 = float(jnp.abs(p["w"]).max())
    # Adam's step size is bounded by lr; expect ~lr·steps of progress.
    assert w1 < w0 - 200 * cfg.lr, (w0, w1)


def test_ce_loss_perfect_prediction_is_zero(cfg):
    logits = jnp.full((1, 3, cfg.vocab), -30.0)
    targets = jnp.asarray([[1, 2, 3]], jnp.int32)
    logits = logits.at[0, 0, 1].set(30.0).at[0, 1, 2].set(30.0).at[0, 2, 3].set(30.0)
    assert float(M.ce_loss(logits, targets)) < 1e-5


def test_lora_spec_and_init(cfg):
    spec = M.lora_param_spec(cfg)
    lora = M.init_lora(cfg)
    assert len(spec) == 2 * cfg.n_fact_layers
    for (name, shape), arr in zip(spec, lora):
        assert arr.shape == shape
        if name.endswith("_lb"):
            assert float(jnp.abs(arr).max()) == 0.0  # B zero-init
