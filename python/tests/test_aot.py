"""AOT export pipeline tests: HLO-text validity, manifest consistency, and
re-export idempotence at the tiny config (fast)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

_TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
_PY_DIR = os.path.dirname(_TESTS_DIR)


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("art_tiny")
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--config", "tiny", "--out", str(out)],
        cwd=_PY_DIR,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    return out


def test_manifest_lists_all_files(tiny_artifacts):
    m = json.load(open(tiny_artifacts / "manifest.json"))
    assert m["config"]["name"] == "byte-gpt-tiny"
    assert len(m["artifacts"]) >= 15
    for name, a in m["artifacts"].items():
        path = tiny_artifacts / a["file"]
        assert path.exists(), f"{name} missing {a['file']}"
        txt = path.read_text()
        assert txt.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in txt
        assert a["inputs"], name
        assert a["outputs"], name


def test_no_zero_size_inputs_declared(tiny_artifacts):
    """Zero-size args are pruned by the MLIR->XLA conversion; the manifest
    must never promise them (regression test for the serve_gar crash)."""
    m = json.load(open(tiny_artifacts / "manifest.json"))
    for name, a in m["artifacts"].items():
        for spec in a["inputs"] + a["outputs"]:
            assert np.prod(spec["shape"]) > 0 or spec["shape"] == [], (name, spec)


def test_teacher_init_blob_matches_spec(tiny_artifacts):
    m = json.load(open(tiny_artifacts / "manifest.json"))
    ti = m["teacher_init"]
    blob = np.fromfile(tiny_artifacts / ti["file"], dtype=np.float32)
    total = sum(int(np.prod(p["shape"])) for p in ti["params"])
    assert blob.size == total == ti["total_f32"]
    assert np.isfinite(blob).all()


def test_train_step_echoes_param_specs(tiny_artifacts):
    """kd_train_step outputs must mirror (params, m, v) then the loss."""
    m = json.load(open(tiny_artifacts / "manifest.json"))
    a = m["artifacts"]["kd_train_step"]
    n_student = sum(1 for i in a["inputs"] if i["name"].startswith("0."))
    outs = a["outputs"]
    assert len(outs) == 3 * n_student + 1
    # Output shapes match the student input shapes, tripled.
    in_shapes = [i["shape"] for i in a["inputs"] if i["name"].startswith("0.")]
    for rep in range(3):
        for k, shape in enumerate(in_shapes):
            assert outs[rep * n_student + k]["shape"] == shape
    assert outs[-1]["shape"] == []


def test_serve_profiles_recorded(tiny_artifacts):
    m = json.load(open(tiny_artifacts / "manifest.json"))
    cfg = m["config"]
    assert len(m["profiles"]) == len(cfg["serve_tiers"])
    for i, tier in enumerate(cfg["serve_tiers"]):
        a = m["artifacts"][f"serve_gar_t{i}"]
        assert a["tier"] == tier
        assert len(a["profile"]) == 4 * cfg["n_blocks"]


def test_selective_reexport(tiny_artifacts):
    """--only re-exports a single artifact without touching others."""
    before = (tiny_artifacts / "teacher_fwd.hlo.txt").read_text()
    r = subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--config", "tiny",
            "--out", str(tiny_artifacts),
            "--only", "teacher_fwd",
        ],
        cwd=_PY_DIR,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    after = (tiny_artifacts / "teacher_fwd.hlo.txt").read_text()
    assert before == after  # deterministic lowering
