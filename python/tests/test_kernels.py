"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle from
`compile.kernels.ref` over hypothesis-generated shapes (including
non-tile-multiples and degenerate dims) and explicit edge cases; the
differentiable wrappers' gradients are checked against jax.grad of the
oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention,
    attention_bh,
    factorized_linear,
    gar_matmul,
    kd_loss,
    pl_matmul,
)
from compile.kernels.gar_matmul import gar_matmul_ad
from compile.kernels.matmul import pl_matmul_ad
from compile.kernels import ref as R

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# pl_matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    bm=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_oracle(m, k, n, bm, seed):
    a = rand(seed, m, k)
    b = rand(seed + 1, k, n)
    got = pl_matmul(a, b, bm=bm, bk=bm, bn=bm)
    np.testing.assert_allclose(got, R.matmul_ref(a, b), rtol=2e-4, atol=2e-4)


def test_matmul_multitile_accumulation():
    # Forces a multi-step contraction loop (gk > 1).
    a = rand(0, 100, 300)
    b = rand(1, 300, 50)
    got = pl_matmul(a, b, bm=32, bk=64, bn=32)
    np.testing.assert_allclose(got, a @ b, rtol=1e-3, atol=1e-3)


def test_matmul_ad_gradients():
    a = rand(2, 9, 7)
    b = rand(3, 7, 5)
    f = lambda a, b: jnp.sum(jnp.tanh(pl_matmul_ad(a, b)))
    fr = lambda a, b: jnp.sum(jnp.tanh(R.matmul_ref(a, b)))
    ga = jax.grad(f, argnums=(0, 1))(a, b)
    gr = jax.grad(fr, argnums=(0, 1))(a, b)
    for x, y in zip(ga, gr):
        np.testing.assert_allclose(x, y, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# factorized_linear
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 50),
    n=st.integers(1, 50),
    m=st.integers(1, 50),
    r=st.integers(1, 40),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_factorized_matches_oracle(b, n, m, r, density, seed):
    x = rand(seed, b, n)
    u = rand(seed + 1, m, r)
    v = rand(seed + 2, n, r)
    key = jax.random.PRNGKey(seed + 3)
    mask = (jax.random.uniform(key, (r,)) < density).astype(jnp.float32)
    got = factorized_linear(x, u, v, mask)
    np.testing.assert_allclose(
        got, R.factorized_matmul_ref(x, u, v, mask), rtol=3e-4, atol=3e-4
    )


def test_factorized_zero_mask_gives_zero():
    x, u, v = rand(0, 4, 6), rand(1, 5, 3), rand(2, 6, 3)
    out = factorized_linear(x, u, v, jnp.zeros((3,), jnp.float32))
    np.testing.assert_allclose(out, jnp.zeros((4, 5)), atol=1e-7)


def test_factorized_gradients_match_oracle():
    x, u, v = rand(3, 8, 6), rand(4, 5, 4), rand(5, 6, 4)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    f = lambda x, u, v, m: jnp.sum(jnp.sin(factorized_linear(x, u, v, m)))
    fr = lambda x, u, v, m: jnp.sum(jnp.sin(R.factorized_matmul_ref(x, u, v, m)))
    g = jax.grad(f, argnums=(0, 1, 2, 3))(x, u, v, mask)
    gr = jax.grad(fr, argnums=(0, 1, 2, 3))(x, u, v, mask)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_factorized_masked_grads_are_zero_for_masked_components():
    # Gradients w.r.t. masked-out columns of U and V must vanish.
    x, u, v = rand(6, 8, 5), rand(7, 4, 3), rand(8, 5, 3)
    mask = jnp.asarray([1.0, 0.0, 1.0])
    f = lambda u, v: jnp.sum(factorized_linear(x, u, v, mask) ** 2)
    du, dv = jax.grad(f, argnums=(0, 1))(u, v)
    np.testing.assert_allclose(du[:, 1], jnp.zeros(4), atol=1e-7)
    np.testing.assert_allclose(dv[:, 1], jnp.zeros(5), atol=1e-7)


# ---------------------------------------------------------------------------
# gar_matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 40),
    n=st.integers(1, 40),
    mr=st.integers(0, 30),
    r=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_gar_matches_oracle(b, n, mr, r, seed):
    x = rand(seed, b, n)
    u_hat = rand(seed + 1, mr, r)
    v_tilde = rand(seed + 2, n, r)
    got = gar_matmul(x, u_hat, v_tilde)
    np.testing.assert_allclose(
        got, R.gar_matmul_ref(x, u_hat, v_tilde), rtol=3e-4, atol=3e-4
    )


def test_gar_identity_block_semantics():
    # First r outputs must equal x @ v_tilde exactly.
    x, uh, vt = rand(0, 5, 7), rand(1, 4, 3), rand(2, 7, 3)
    out = gar_matmul(x, uh, vt)
    np.testing.assert_allclose(out[:, :3], x @ vt, rtol=1e-5, atol=1e-5)


def test_gar_ad_gradients():
    x, uh, vt = rand(3, 6, 5), rand(4, 3, 2), rand(5, 5, 2)
    f = lambda x, uh, vt: jnp.sum(jnp.cos(gar_matmul_ad(x, uh, vt)))
    fr = lambda x, uh, vt: jnp.sum(jnp.cos(R.gar_matmul_ref(x, uh, vt)))
    g = jax.grad(f, argnums=(0, 1, 2))(x, uh, vt)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, uh, vt)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# kd_loss
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 60),
    v=st.integers(2, 80),
    tau=st.floats(0.5, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kd_loss_matches_oracle(b, v, tau, seed):
    s = rand(seed, b, v) * 3.0
    t = rand(seed + 1, b, v) * 3.0
    got = kd_loss(s, t, float(tau))
    want = R.kd_loss_ref(s, t, float(tau))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_kd_loss_zero_when_equal():
    s = rand(0, 10, 16)
    assert float(kd_loss(s, s, 2.0)) < 1e-6


def test_kd_loss_grad_matches_oracle():
    s, t = rand(1, 7, 12), rand(2, 7, 12)
    gs = jax.grad(lambda s: kd_loss(s, t, 3.0))(s)
    gr = jax.grad(lambda s: R.kd_loss_ref(s, t, 3.0))(s)
    np.testing.assert_allclose(gs, gr, rtol=1e-3, atol=1e-7)
    # Teacher side must be treated as constant.
    gt = jax.grad(lambda t: kd_loss(s, t, 3.0))(t)
    np.testing.assert_allclose(gt, jnp.zeros_like(t), atol=1e-9)


def test_kd_loss_extreme_logits_stable():
    s = jnp.asarray([[1000.0, -1000.0, 0.0]])
    t = jnp.asarray([[-1000.0, 1000.0, 0.0]])
    out = float(kd_loss(s, t, 1.0))
    assert np.isfinite(out)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    t=st.integers(1, 50),
    hd=st.integers(1, 32),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_oracle(t, hd, causal, seed):
    q = rand(seed, t, hd)
    k = rand(seed + 1, t, hd)
    v = rand(seed + 2, t, hd)
    got = attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        got, R.attention_ref(q, k, v, causal), rtol=3e-4, atol=3e-4
    )


def test_attention_batched_heads():
    q = rand(0, 2, 3, 17, 8)
    k = rand(1, 2, 3, 17, 8)
    v = rand(2, 2, 3, 17, 8)
    got = attention_bh(q, k, v)
    want = jax.vmap(jax.vmap(lambda q, k, v: R.attention_ref(q, k, v, True)))(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_attention_first_token_attends_only_itself():
    q, k, v = rand(0, 6, 4), rand(1, 6, 4), rand(2, 6, 4)
    out = attention(q, k, v, causal=True)
    np.testing.assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
