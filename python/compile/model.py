"""L2 — FlexRank's JAX compute graphs (build-time only, never on the request
path).

Defines the byte-level GPT used throughout the repo (DESIGN.md §substitutions:
stands in for GPT-2/Llama at CPU-tractable scale, same per-block layer
inventory: fused qkv, attention out-proj, MLP fc / fc-proj — the four
factorization surfaces per block) in two parameterizations:

  * **teacher** — dense weights, plain jnp ops (it is the substrate/baseline
    and the frozen KD teacher; the paper's contribution does not live here).
  * **student** — every linear factorized as ``W = V diag(mask) U^T`` with
    per-component rank masks (Sec. 2.1), the Pallas ``factorized_linear``
    kernel on the hot path and the Pallas ``kd_loss`` for Eq. 5.

Also defines the **GAR serving forward** (Sec. 3.5) over re-gauged factors
``(Û, Ṽ)`` at a fixed rank profile, and the AdamW train steps that aot.py
lowers to HLO text for the rust runtime.

Weight convention: activations are row vectors, ``y = x @ W + b`` with
``W : (n_in, m_out)``.  Relative to the paper's ``W_paper : (m × n)`` acting
on column vectors, ``W = W_paper^T``; the factor pair ``(U : (m, r),
V : (n, r))`` is exactly the paper's, with ``W = V U^T``.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os

import jax
import jax.numpy as jnp

from .kernels import attention_bh, factorized_linear, gar_matmul, kd_loss, pl_matmul
from .kernels.gar_matmul import gar_matmul_ad
from .kernels.matmul import pl_matmul_ad
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@dataclasses.dataclass(frozen=True)
class Config:
    """Model + training hyperparameters, shared with rust via configs/*.json."""

    name: str
    vocab: int
    d_model: int
    n_blocks: int
    n_heads: int
    seq_len: int
    batch_train: int
    batch_eval: int
    batch_calib: int
    batch_serve: int
    tau_kd: float
    lr: float
    weight_decay: float
    beta1: float
    beta2: float
    adam_eps: float
    serve_tiers: list
    bench_ranks: list
    bench_dim: int
    bench_batch: int
    lora_rank: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    # The four factorization surfaces per block, in canonical order.
    # name -> (n_in, m_out); full rank r = min(n, m) = d_model for all four.
    def layer_dims(self) -> dict:
        d, f = self.d_model, self.d_ff
        return {
            "qkv": (d, 3 * d),
            "proj": (d, d),
            "fc": (d, f),
            "fcp": (f, d),
        }

    @property
    def rank_full(self) -> int:
        return self.d_model

    @property
    def n_fact_layers(self) -> int:
        return 4 * self.n_blocks


LAYER_KINDS = ("qkv", "proj", "fc", "fcp")


def load_config(name_or_path: str | None = None) -> Config:
    """Load a Config from configs/ (``FLEXRANK_CONFIG`` env overrides)."""
    spec = name_or_path or os.environ.get("FLEXRANK_CONFIG", "base")
    path = spec if os.path.exists(spec) else os.path.join(_REPO, "configs", f"model_{spec}.json")
    with open(path) as f:
        return Config(**json.load(f))


# ---------------------------------------------------------------------------
# Parameter trees & init
# ---------------------------------------------------------------------------


def init_teacher(cfg: Config, seed: int = 0) -> dict:
    """GPT-2-style init: N(0, 0.02), residual projections scaled by 1/sqrt(2L)."""
    key = jax.random.PRNGKey(seed)
    d, f, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    ks = iter(jax.random.split(key, 4 + 8 * cfg.n_blocks))
    std = 0.02
    resid_std = std / math.sqrt(2 * cfg.n_blocks)

    def nrm(k, shape, s=std):
        return (jax.random.normal(k, shape) * s).astype(jnp.float32)

    params: dict = {
        "tok_emb": nrm(next(ks), (v, d)),
        "pos_emb": nrm(next(ks), (t, d)),
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
    }
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append(
            {
                "ln1_g": jnp.ones((d,), jnp.float32),
                "ln1_b": jnp.zeros((d,), jnp.float32),
                "ln2_g": jnp.ones((d,), jnp.float32),
                "ln2_b": jnp.zeros((d,), jnp.float32),
                "qkv_w": nrm(next(ks), (d, 3 * d)),
                "qkv_b": jnp.zeros((3 * d,), jnp.float32),
                "proj_w": nrm(next(ks), (d, d), resid_std),
                "proj_b": jnp.zeros((d,), jnp.float32),
                "fc_w": nrm(next(ks), (d, f)),
                "fc_b": jnp.zeros((f,), jnp.float32),
                "fcp_w": nrm(next(ks), (f, d), resid_std),
                "fcp_b": jnp.zeros((d,), jnp.float32),
            }
        )
    params["blocks"] = blocks
    return params


def init_student_from_factors(cfg: Config, teacher: dict, factors: list) -> dict:
    """Assemble student params from teacher non-matrix params + (U, V) factors.

    ``factors`` is a flat list of (u, v) pairs in canonical layer order
    (block-major, LAYER_KINDS within a block) — normally produced by the rust
    DataSVD stage; python only needs this for tests.
    """
    assert len(factors) == cfg.n_fact_layers
    student: dict = {
        "tok_emb": teacher["tok_emb"],
        "pos_emb": teacher["pos_emb"],
        "lnf_g": teacher["lnf_g"],
        "lnf_b": teacher["lnf_b"],
    }
    blocks = []
    it = iter(factors)
    for tb in teacher["blocks"]:
        sb = {k: tb[k] for k in ("ln1_g", "ln1_b", "ln2_g", "ln2_b",
                                 "qkv_b", "proj_b", "fc_b", "fcp_b")}
        for kind in LAYER_KINDS:
            u, v = next(it)
            sb[f"{kind}_u"] = u
            sb[f"{kind}_v"] = v
        blocks.append(sb)
    student["blocks"] = blocks
    return student


def init_student_svd(cfg: Config, teacher: dict) -> dict:
    """Plain-SVD student init (the weight-SVD baseline; DataSVD lives in rust)."""
    factors = []
    for tb in teacher["blocks"]:
        for kind in LAYER_KINDS:
            w = tb[f"{kind}_w"]  # (n, m) ; paper W = w.T
            # SVD of W_paper = w.T = P Σ Q^T ; U = P Σ^{1/2}, V = Q Σ^{1/2}.
            p, s, qt = jnp.linalg.svd(w.T, full_matrices=False)
            r = cfg.rank_full
            sh = jnp.sqrt(s[:r])
            factors.append((p[:, :r] * sh[None, :], qt[:r, :].T * sh[None, :]))
    return init_student_from_factors(cfg, teacher, factors)


def full_masks(cfg: Config) -> jax.Array:
    """(n_blocks, 4, rank_full) all-ones mask = full-budget profile."""
    return jnp.ones((cfg.n_blocks, 4, cfg.rank_full), jnp.float32)


# ---------------------------------------------------------------------------
# Shared blocks
# ---------------------------------------------------------------------------


def _layer_norm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _gelu(x: jax.Array) -> jax.Array:
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def _split_heads(x: jax.Array, b: int, t: int, h: int, hd: int) -> jax.Array:
    return x.reshape(b, t, h, hd).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array, b: int, t: int, d: int) -> jax.Array:
    return x.transpose(0, 2, 1, 3).reshape(b, t, d)


def _attention_jnp(q, k, v):
    """vmapped oracle attention — used where gradients must flow (training)."""
    return jax.vmap(jax.vmap(lambda q, k, v: kref.attention_ref(q, k, v, True)))(q, k, v)


# ---------------------------------------------------------------------------
# Teacher (dense)
# ---------------------------------------------------------------------------


def teacher_fwd(cfg: Config, params: dict, tokens: jax.Array) -> jax.Array:
    """Dense forward. tokens: (B, T) int32 → logits (B, T, V)."""
    b, t = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    for blk in params["blocks"]:
        a = _layer_norm(x, blk["ln1_g"], blk["ln1_b"])
        qkv = a @ blk["qkv_w"] + blk["qkv_b"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(z, b, t, h, hd) for z in (q, k, v))
        att = _merge_heads(_attention_jnp(q, k, v), b, t, d)
        x = x + att @ blk["proj_w"] + blk["proj_b"]
        a = _layer_norm(x, blk["ln2_g"], blk["ln2_b"])
        x = x + _gelu(a @ blk["fc_w"] + blk["fc_b"]) @ blk["fcp_w"] + blk["fcp_b"]
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_emb"].T  # tied head


def teacher_fwd_acts(cfg: Config, params: dict, tokens: jax.Array):
    """Forward that additionally returns per-factorized-layer covariance
    increments ``X_l^T X_l`` (App. C.1 online covariance estimation) — one
    (n_l, n_l) matrix per factorized layer, canonical order."""
    b, t = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    covs = []

    def track(a2d):
        covs.append(jnp.dot(a2d.T, a2d, preferred_element_type=jnp.float32))

    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    for blk in params["blocks"]:
        a = _layer_norm(x, blk["ln1_g"], blk["ln1_b"])
        track(a.reshape(-1, d))
        qkv = a @ blk["qkv_w"] + blk["qkv_b"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(z, b, t, h, hd) for z in (q, k, v))
        att = _merge_heads(_attention_jnp(q, k, v), b, t, d)
        track(att.reshape(-1, d))
        x = x + att @ blk["proj_w"] + blk["proj_b"]
        a = _layer_norm(x, blk["ln2_g"], blk["ln2_b"])
        track(a.reshape(-1, d))
        fco = _gelu(a @ blk["fc_w"] + blk["fc_b"])
        track(fco.reshape(-1, cfg.d_ff))
        x = x + fco @ blk["fcp_w"] + blk["fcp_b"]
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T
    return logits, tuple(covs)


def ce_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token cross entropy. logits (B,T,V), targets (B,T) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Student (factorized + masked, Pallas hot path)
# ---------------------------------------------------------------------------


def student_fwd(
    cfg: Config,
    params: dict,
    masks: jax.Array,
    tokens: jax.Array,
    *,
    pallas_attention: bool = True,
) -> jax.Array:
    """Masked factorized forward.  masks: (n_blocks, 4, rank_full)."""
    b, t = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim

    def flin(a2d, blk, kind, mask):
        return factorized_linear(a2d, blk[f"{kind}_u"], blk[f"{kind}_v"], mask)

    attn_fn = attention_bh if pallas_attention else _attention_jnp

    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    for i, blk in enumerate(params["blocks"]):
        a = _layer_norm(x, blk["ln1_g"], blk["ln1_b"])
        qkv = flin(a.reshape(-1, d), blk, "qkv", masks[i, 0]).reshape(b, t, 3 * d) + blk["qkv_b"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(z, b, t, h, hd) for z in (q, k, v))
        att = _merge_heads(attn_fn(q, k, v), b, t, d)
        o = flin(att.reshape(-1, d), blk, "proj", masks[i, 1]).reshape(b, t, d) + blk["proj_b"]
        x = x + o
        a = _layer_norm(x, blk["ln2_g"], blk["ln2_b"])
        f = _gelu(flin(a.reshape(-1, d), blk, "fc", masks[i, 2]).reshape(b, t, cfg.d_ff) + blk["fc_b"])
        x = x + flin(f.reshape(-1, cfg.d_ff), blk, "fcp", masks[i, 3]).reshape(b, t, d) + blk["fcp_b"]
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_emb"].T


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_update(cfg: Config, params, grads, m, v, step):
    """One AdamW step over an arbitrary pytree; step is 1-based float32."""
    b1, b2, eps, lr, wd = cfg.beta1, cfg.beta2, cfg.adam_eps, cfg.lr, cfg.weight_decay
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step

    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1 - b1) * g
        v2 = b2 * v_ + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        return p2, m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(*z) for z in zip(flat_p, flat_g, flat_m, flat_v)]
    p2 = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    m2 = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    v2 = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return p2, m2, v2


def zeros_like_tree(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


# ---------------------------------------------------------------------------
# Train steps (lowered by aot.py)
# ---------------------------------------------------------------------------


def teacher_train_step(cfg: Config, params, m, v, step, tokens):
    """Dense LM pretraining step.  tokens: (B, T+1) int32.

    Returns (params', m', v', loss).  This builds the 'pretrained base model'
    the paper assumes as input (DESIGN.md §substitutions).
    """
    x, y = tokens[:, :-1], tokens[:, 1:]

    def loss_fn(p):
        return ce_loss(teacher_fwd(cfg, p, x), y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    p2, m2, v2 = adamw_update(cfg, params, grads, m, v, step)
    return p2, m2, v2, loss


def kd_train_step(cfg: Config, sparams, m, v, step, tparams, masks, tokens):
    """Knowledge-consolidation step (Alg. 1 lines 14–17, Eq. 5–6).

    The budget profile is selected by the rust driver (sampled ∝ α_k) and
    arrives as the ``masks`` input, so one lowered executable serves every
    profile.  Teacher runs forward-only (frozen).
    """
    x = tokens[:, :-1]
    t_logits = jax.lax.stop_gradient(teacher_fwd(cfg, tparams, x))
    vdim = t_logits.shape[-1]

    def loss_fn(sp):
        s_logits = student_fwd(cfg, sp, masks, x, pallas_attention=False)
        return kd_loss(s_logits.reshape(-1, vdim), t_logits.reshape(-1, vdim), cfg.tau_kd)

    loss, grads = jax.value_and_grad(loss_fn)(sparams)
    p2, m2, v2 = adamw_update(cfg, sparams, grads, m, v, step)
    return p2, m2, v2, loss


def student_eval(cfg: Config, sparams, masks, tokens):
    """Eval entry: CE loss of the masked student on (B, T+1) token windows."""
    x, y = tokens[:, :-1], tokens[:, 1:]
    logits = student_fwd(cfg, sparams, masks, x, pallas_attention=True)
    return ce_loss(logits, y)


# ---------------------------------------------------------------------------
# GAR serving forward (Sec. 3.5) — fixed rank profile, re-gauged factors
# ---------------------------------------------------------------------------


def gar_param_spec(cfg: Config, profile: list) -> list:
    """Flat (name, shape) list for a GAR submodel at ``profile``.

    ``profile``: n_blocks × 4 ints (rank per factorized layer, canonical
    order).  Shapes: per layer ``u_hat (m−r, r)``, ``v_tilde (n, r)``.
    """
    dims = cfg.layer_dims()
    spec = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
    ]
    for i in range(cfg.n_blocks):
        for g in ("ln1_g", "ln1_b", "ln2_g", "ln2_b"):
            spec.append((f"b{i}.{g}", (cfg.d_model,)))
        for j, kind in enumerate(LAYER_KINDS):
            n, mm = dims[kind]
            r = int(profile[i * 4 + j])
            if mm - r > 0:
                # Full-rank square layers have an empty Û; zero-size args are
                # pruned by the MLIR->XLA conversion, so never declare them.
                spec.append((f"b{i}.{kind}_uhat", (mm - r, r)))
            spec.append((f"b{i}.{kind}_vt", (n, r)))
            spec.append((f"b{i}.{kind}_b", (mm,)))
    return spec


def gar_fwd(cfg: Config, flat_params: list, profile: list, tokens: jax.Array) -> jax.Array:
    """Serving forward over GAR factors (flat param list per gar_param_spec).

    GAR's output coordinates live in the gauge where the first r outputs equal
    ``t`` directly; the rust GAR stage bakes the corresponding output
    rotation into ``Û``/``Ṽ`` (identity block convention: first r rows of Ũ),
    so no runtime permutation is needed here.
    """
    spec = gar_param_spec(cfg, profile)
    p = {name: arr for (name, _), arr in zip(spec, flat_params)}
    b, t = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim

    def glin(a2d, i, kind):
        key = f"b{i}.{kind}_uhat"
        if key in p:
            return gar_matmul(a2d, p[key], p[f"b{i}.{kind}_vt"]) + p[f"b{i}.{kind}_b"]
        # Full-rank square layer: Ũ = I, so y = x @ Ṽ directly.
        return pl_matmul(a2d, p[f"b{i}.{kind}_vt"]) + p[f"b{i}.{kind}_b"]

    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t, :]
    for i in range(cfg.n_blocks):
        a = _layer_norm(x, p[f"b{i}.ln1_g"], p[f"b{i}.ln1_b"])
        qkv = glin(a.reshape(-1, d), i, "qkv").reshape(b, t, 3 * d)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(z, b, t, h, hd) for z in (q, k, v))
        att = _merge_heads(attention_bh(q, k, v), b, t, d)
        x = x + glin(att.reshape(-1, d), i, "proj").reshape(b, t, d)
        a = _layer_norm(x, p[f"b{i}.ln2_g"], p[f"b{i}.ln2_b"])
        f = _gelu(glin(a.reshape(-1, d), i, "fc").reshape(b, t, cfg.d_ff))
        x = x + glin(f.reshape(-1, cfg.d_ff), i, "fcp").reshape(b, t, d)
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["tok_emb"].T


# ---------------------------------------------------------------------------
# LoRA post-adaptation (Tab. 1) on a frozen GAR submodel
# ---------------------------------------------------------------------------


def lora_param_spec(cfg: Config) -> list:
    """LoRA adapters: one (A: (n, ra), B: (ra, m)) pair per factorized layer."""
    dims = cfg.layer_dims()
    spec = []
    for i in range(cfg.n_blocks):
        for kind in LAYER_KINDS:
            n, mm = dims[kind]
            spec.append((f"b{i}.{kind}_la", (n, cfg.lora_rank)))
            spec.append((f"b{i}.{kind}_lb", (cfg.lora_rank, mm)))
    return spec


def init_lora(cfg: Config, seed: int = 0) -> list:
    key = jax.random.PRNGKey(seed)
    out = []
    for _, shape in lora_param_spec(cfg):
        if shape[0] == cfg.lora_rank:  # B side: zeros (standard LoRA init)
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            key, k = jax.random.split(key)
            out.append((jax.random.normal(k, shape) * 0.02).astype(jnp.float32))
    return out


def gar_lora_fwd(cfg, gar_flat, lora_flat, profile, tokens, scale: float = 2.0):
    """GAR forward with additive LoRA on every factorized layer."""
    spec = gar_param_spec(cfg, profile)
    p = {name: arr for (name, _), arr in zip(spec, gar_flat)}
    lp = {name: arr for (name, _), arr in zip(lora_param_spec(cfg), lora_flat)}
    b, t = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim

    def glin(a2d, i, kind):
        key = f"b{i}.{kind}_uhat"
        if key in p:
            base = gar_matmul_ad(a2d, p[key], p[f"b{i}.{kind}_vt"])
        else:
            base = pl_matmul_ad(a2d, p[f"b{i}.{kind}_vt"])
        lo = pl_matmul_ad(pl_matmul_ad(a2d, lp[f"b{i}.{kind}_la"]), lp[f"b{i}.{kind}_lb"])
        return base + scale * lo + p[f"b{i}.{kind}_b"]

    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t, :]
    for i in range(cfg.n_blocks):
        a = _layer_norm(x, p[f"b{i}.ln1_g"], p[f"b{i}.ln1_b"])
        qkv = glin(a.reshape(-1, d), i, "qkv").reshape(b, t, 3 * d)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(z, b, t, h, hd) for z in (q, k, v))
        att = _merge_heads(_attention_jnp(q, k, v), b, t, d)
        x = x + glin(att.reshape(-1, d), i, "proj").reshape(b, t, d)
        a = _layer_norm(x, p[f"b{i}.ln2_g"], p[f"b{i}.ln2_b"])
        f = _gelu(glin(a.reshape(-1, d), i, "fc").reshape(b, t, cfg.d_ff))
        x = x + glin(f.reshape(-1, cfg.d_ff), i, "fcp").reshape(b, t, d)
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["tok_emb"].T


def lora_train_step(cfg, gar_flat, lora_flat, m, v, step, profile, tokens):
    """CE finetuning of LoRA adapters on a frozen GAR submodel (Tab. 1)."""
    x, y = tokens[:, :-1], tokens[:, 1:]

    def loss_fn(lf):
        return ce_loss(gar_lora_fwd(cfg, gar_flat, lf, profile, x), y)

    loss, grads = jax.value_and_grad(loss_fn)(lora_flat)
    p2, m2, v2 = adamw_update(cfg, lora_flat, grads, m, v, step)
    return p2, m2, v2, loss


# ---------------------------------------------------------------------------
# Fig. 10 bench entry points (dense vs naive low-rank vs GAR single matmul)
# ---------------------------------------------------------------------------


def bench_dense(x, w):
    return (pl_matmul(x, w),)


def bench_lowrank(x, v, ut):
    """Naive factorized forward: two full products, identity block included."""
    return (pl_matmul(pl_matmul(x, v), ut),)


def bench_gar(x, u_hat, v_tilde):
    return (gar_matmul(x, u_hat, v_tilde),)
