"""Blocked causal attention as a Pallas kernel (serving forward path).

Row-blocked schedule: each program instance owns a ``bt``-row block of
queries with the full K/V panels VMEM-resident (T ≤ 128 at our configs, so
K/V fit comfortably; a production TPU kernel would stream K/V in flash-style
chunks — at these sequence lengths the single-panel schedule is the better
VMEM/compute trade-off and keeps the grid coarse for interpret mode).

Causality is enforced inside the kernel with an iota comparison against the
absolute row offset (``program_id * bt``), so no (T, T) mask is materialized
in HBM.

VMEM model (per instance, f32): ``bt·hd + 2·T·hd + bt·T`` words — base config
(bt = 64, T = 64, hd = 32) → ~40 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _ceil_div

_BT = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bt: int, t: int, causal: bool):
    i = pl.program_id(0)
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        row = i * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, t), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (bt, t), 1)
        scores = jnp.where(col <= row, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Single-head attention over (T, hd) panels; vmap for batch/heads."""
    t, hd = q.shape
    bt = min(_BT, t)
    gt = _ceil_div(t, bt)
    pt = gt * bt
    if pt != t:
        q = jnp.pad(q, ((0, pt - t), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_attn_kernel, bt=bt, t=t, causal=causal),
        grid=(gt,),
        in_specs=[
            pl.BlockSpec((bt, hd), lambda i: (i, 0)),
            pl.BlockSpec((t, hd), lambda i: (0, 0)),
            pl.BlockSpec((t, hd), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pt, hd), jnp.float32),
        interpret=True,
    )(q, k, v)
    return out[:t]


def attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Attention over (B, H, T, hd) by vmapping the single-head kernel."""
    fn = functools.partial(attention, causal=causal)
    return jax.vmap(jax.vmap(fn))(q, k, v)
