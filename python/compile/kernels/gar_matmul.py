"""GAR (Gauge-Aligned Reparametrization) forward — FlexRank's serving-time
hot spot (Sec. 3.5).

After rank selection the factorization is re-gauged so ``Ũ = [I_r; Û]``:
the first ``r`` output coordinates are exactly ``t = x @ Ṽ`` and only the
remaining ``m - r`` rows need the second product ``t @ Û^T``.  Total cost is
``O((m + n − r)·r)`` vs ``O((m + n)·r)`` for the naive factorization and
``O(m·n)`` dense — the identity block is never stored nor multiplied.

The kernel is fused: one Pallas program computes the ``t`` block once in VMEM
and emits both output segments, so ``t`` never round-trips through HBM.

VMEM model (per instance, f32): ``bb·n + n·r + (m−r)·r + bb·m`` words — at the
Fig. 10 bench scale (m = n = 256..1024, bb = 128) ≤ ~5 MiB, inside budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _ceil_div

_BB = 128


def _gar_kernel(x_ref, vt_ref, uh_ref, o_ref, *, r: int):
    """One batch-block step: t = x@Ṽ; o = [t, t @ Û^T] written in one pass."""
    t = jnp.dot(x_ref[...], vt_ref[...], preferred_element_type=jnp.float32)
    rest = jnp.dot(t, uh_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.concatenate([t, rest], axis=-1)


@jax.jit
def gar_matmul(x: jax.Array, u_hat: jax.Array, v_tilde: jax.Array) -> jax.Array:
    """``y = [x@Ṽ, (x@Ṽ)@Û^T]`` — see module docstring.

    Args:
      x:       (B, n) input activations.
      u_hat:   (m − r, r) non-identity block of the re-gauged left factor.
      v_tilde: (n, r) re-gauged right factor.

    Returns:
      (B, m) output.
    """
    b, n = x.shape
    mr, r = u_hat.shape
    m = mr + r
    assert v_tilde.shape == (n, r), (v_tilde.shape, (n, r))

    if mr == 0:
        # Full-rank square layer: Ũ = I, output is t = x @ Ṽ directly.
        from .matmul import pl_matmul

        return pl_matmul(x, v_tilde)

    bb = min(_BB, b)
    gb = _ceil_div(b, bb)
    pb = gb * bb
    if pb != b:
        x = jnp.pad(x, ((0, pb - b), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_gar_kernel, r=r),
        grid=(gb,),
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((n, r), lambda i: (0, 0)),
            pl.BlockSpec((mr, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pb, m), jnp.float32),
        interpret=True,
    )(x, v_tilde, u_hat)
    return out[:b]


# ---------------------------------------------------------------------------
# Differentiable wrapper.  The LoRA post-adaptation path (Tab. 1) backprops
# *through* frozen GAR layers to reach upstream adapters, so the kernel needs
# a VJP; backward products reuse the tiled Pallas matmul.
# ---------------------------------------------------------------------------

from .matmul import pl_matmul  # noqa: E402


@jax.custom_vjp
def gar_matmul_ad(x: jax.Array, u_hat: jax.Array, v_tilde: jax.Array) -> jax.Array:
    """Differentiable ``gar_matmul`` (same semantics, custom VJP)."""
    return gar_matmul(x, u_hat, v_tilde)


def _gar_fwd_rule(x, u_hat, v_tilde):
    return gar_matmul(x, u_hat, v_tilde), (x, u_hat, v_tilde)


def _gar_bwd_rule(res, g):
    x, u_hat, v_tilde = res
    r = v_tilde.shape[1]
    if u_hat.shape[0] == 0:
        dx = pl_matmul(g, v_tilde.T)
        return dx, jnp.zeros_like(u_hat), pl_matmul(x.T, g)
    g1, g2 = g[:, :r], g[:, r:]
    t = pl_matmul(x, v_tilde)                 # rematerialized
    dt = g1 + pl_matmul(g2, u_hat)            # (B, r)
    dx = pl_matmul(dt, v_tilde.T)             # (B, n)
    du_hat = pl_matmul(g2.T, t)               # (m-r, r)
    dv_tilde = pl_matmul(x.T, dt)             # (n, r)
    return dx, du_hat, dv_tilde


gar_matmul_ad.defvjp(_gar_fwd_rule, _gar_bwd_rule)
