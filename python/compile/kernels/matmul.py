"""Generic tiled Pallas matmul — the compute primitive every other kernel
composes.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output into
``(bm, bn)`` blocks resident in VMEM; the contraction dimension streams in
``bk`` chunks, accumulating into the revisited output block — the Pallas
analogue of the threadblock/shared-memory schedule a CUDA kernel would use.
Tile sides default to MXU-friendly multiples and are clamped to the problem
size so small test shapes run a 1×1×1 grid.

Always executed with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Default tile sides.  128 matches the MXU systolic array; VMEM footprint of
# one program instance is (bm*bk + bk*bn + bm*bn) * 4 bytes ≈ 192 KiB at the
# defaults, far below the ~16 MiB VMEM model documented in DESIGN.md.
_BM, _BK, _BN = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += A[i,k] @ B[k,j].

    The output BlockSpec maps every k to the same (i, j) block, so the block
    stays VMEM-resident across the contraction loop (innermost grid dim) and
    acts as the accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def pl_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = _BM,
    bk: int = _BK,
    bn: int = _BN,
) -> jax.Array:
    """``a @ b`` via the tiled Pallas kernel.

    Shapes need not be tile-multiples: inputs are zero-padded up to the tile
    grid and the result is sliced back, so the kernel body never sees ragged
    blocks (keeps the VMEM schedule uniform, as a real TPU kernel would).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)

    gm, gk, gn = _ceil_div(m, bm), _ceil_div(k, bk), _ceil_div(n, bn)
    pm, pk, pn = gm * bm, gk * bk, gn * bn
    if (pm, pk) != (m, k):
        a = jnp.pad(a, ((0, pm - m), (0, pk - k)))
    if (pk, pn) != (k, n):
        b = jnp.pad(b, ((0, pk - k), (0, pn - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Differentiable wrapper — raw pallas_call has no VJP; training graphs that
# need gradients through a plain matmul (e.g. LoRA adapters) use this, with
# both the forward and the two backward products running the Pallas kernel.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def pl_matmul_ad(a: jax.Array, b: jax.Array) -> jax.Array:
    """Differentiable ``a @ b`` backed by the tiled Pallas kernel."""
    return pl_matmul(a, b)


def _mm_fwd(a, b):
    return pl_matmul(a, b), (a, b)


def _mm_bwd(res, g):
    a, b = res
    return pl_matmul(g, b.T), pl_matmul(a.T, g)


pl_matmul_ad.defvjp(_mm_fwd, _mm_bwd)
