"""L1 Pallas kernels for FlexRank (always ``interpret=True`` — see DESIGN.md).

Exports:
  pl_matmul          — generic tiled matmul (the composable primitive)
  factorized_linear  — masked factorized linear, differentiable (custom VJP)
  gar_matmul         — gauge-aligned rank-r forward (serving hot path)
  kd_loss            — fused temperature-scaled KL distillation loss
  attention, attention_bh — blocked causal attention
"""

from .matmul import pl_matmul
from .factorized_matmul import factorized_linear
from .gar_matmul import gar_matmul
from .kd_loss import kd_loss
from .attention import attention, attention_bh

__all__ = [
    "pl_matmul",
    "factorized_linear",
    "gar_matmul",
    "kd_loss",
    "attention",
    "attention_bh",
]
