"""Fused temperature-scaled KL distillation loss (Eq. 5) as a Pallas kernel.

One program instance owns a block of rows (token positions) with the full
vocabulary resident in VMEM, computes both log-softmaxes and the row KL in a
single pass — the fusion XLA would otherwise need several elementwise +
reduce ops (and extra HBM traffic) for.

VMEM model (per instance, f32): ``2·bb·V + bb`` words; base config
(bb = 128, V = 256) → ~256 KiB.

The public entry point carries a custom VJP (only the student side needs
gradients during consolidation; the teacher is frozen).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _ceil_div

_BB = 128


def _kd_kernel(s_ref, t_ref, o_ref, *, tau: float):
    s = s_ref[...] / tau
    t = t_ref[...] / tau
    s_max = jnp.max(s, axis=-1, keepdims=True)
    t_max = jnp.max(t, axis=-1, keepdims=True)
    s_lse = jnp.log(jnp.sum(jnp.exp(s - s_max), axis=-1, keepdims=True)) + s_max
    t_lse = jnp.log(jnp.sum(jnp.exp(t - t_max), axis=-1, keepdims=True)) + t_max
    log_ps = s - s_lse
    log_pt = t - t_lse
    pt = jnp.exp(log_pt)
    o_ref[...] = jnp.sum(pt * (log_pt - log_ps), axis=-1)


def _kd_rows(student_logits: jax.Array, teacher_logits: jax.Array, tau: float) -> jax.Array:
    """Per-row KL(p_t || p_s) at temperature tau; returns (B,)."""
    b, v = student_logits.shape
    bb = min(_BB, b)
    gb = _ceil_div(b, bb)
    pb = gb * bb
    if pb != b:
        # Pad with zeros: padded rows give KL(uniform||uniform) = 0.
        student_logits = jnp.pad(student_logits, ((0, pb - b), (0, 0)))
        teacher_logits = jnp.pad(teacher_logits, ((0, pb - b), (0, 0)))

    rows = pl.pallas_call(
        functools.partial(_kd_kernel, tau=tau),
        grid=(gb,),
        in_specs=[
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pb,), jnp.float32),
        interpret=True,
    )(student_logits, teacher_logits)
    return rows[:b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def kd_loss(student_logits, teacher_logits, tau: float):
    """Mean over rows of ``tau² · KL(softmax(t/τ) || softmax(s/τ))``."""
    return jnp.mean(_kd_rows(student_logits, teacher_logits, tau)) * (tau**2)


def _kd_fwd(student_logits, teacher_logits, tau):
    loss = jnp.mean(_kd_rows(student_logits, teacher_logits, tau)) * (tau**2)
    return loss, (student_logits, teacher_logits)


def _kd_bwd(tau, res, g):
    s, t = res
    b = s.shape[0]
    # d/ds_i [tau² · mean_rows KL] = tau · (p_s − p_t) / B
    ps = jax.nn.softmax(s / tau, axis=-1)
    pt = jax.nn.softmax(t / tau, axis=-1)
    ds = g * tau * (ps - pt) / b
    return ds, jnp.zeros_like(t)


kd_loss.defvjp(_kd_fwd, _kd_bwd)
