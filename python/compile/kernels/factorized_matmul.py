"""Masked factorized linear — FlexRank's training-time hot spot.

Computes ``y = ((x @ V) * mask) @ U^T`` where ``mask`` is the per-component
rank mask of the currently sampled budget profile (Alg. 1, knowledge
consolidation).  The paper (App. D.4) notes an unfused ``B @ (X @ A)`` is
memory-bound; this kernel fuses both factor products in a single Pallas
program so the intermediate ``t = x @ V`` never round-trips through HBM.

Differentiability: ``pallas_call`` has no automatic VJP, so the public entry
point ``factorized_linear`` carries a ``jax.custom_vjp`` whose backward pass
is itself built from the tiled Pallas matmul (``pl_matmul``) — every matmul in
the lowered train-step HLO is a Pallas kernel.

VMEM model (per program instance, f32): ``bb·n + n·br + bm·br + bb·bm`` words.
At the base config (n ≤ 512, bb = bm = br = 128) that is ≤ 640 KiB, well
inside the 16 MiB VMEM budget documented in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pl_matmul, _ceil_div

_BB, _BM, _BR = 128, 128, 128


def _fact_kernel(x_ref, v_ref, mask_ref, u_ref, o_ref):
    """One (i, j, k) step: o[i,j] += ((x[i] @ V[:,k]) * mask[k]) @ U[j,k]^T.

    x block:    (bb, n)   — full contraction dim resident.
    V block:    (n, br)   — an r-chunk of the right factor.
    mask block: (br,)     — matching chunk of the rank mask.
    U block:    (bm, br)  — matching chunk of the left factor rows j.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    t = jnp.dot(x_ref[...], v_ref[...], preferred_element_type=jnp.float32)
    t = t * mask_ref[...][None, :]
    o_ref[...] += jnp.dot(t, u_ref[...].T, preferred_element_type=jnp.float32)


def _fact_fwd_pallas(
    x: jax.Array, u: jax.Array, v: jax.Array, mask: jax.Array,
    bb: int, bm: int, br: int,
) -> jax.Array:
    b, n = x.shape
    m, r = u.shape
    assert v.shape == (n, r) and mask.shape == (r,)
    bb, bm, br = min(bb, b), min(bm, m), min(br, r)
    gb, gm, gr = _ceil_div(b, bb), _ceil_div(m, bm), _ceil_div(r, br)
    pb, pm, pr = gb * bb, gm * bm, gr * br
    if pb != b:
        x = jnp.pad(x, ((0, pb - b), (0, 0)))
    if (pm, pr) != (m, r):
        u = jnp.pad(u, ((0, pm - m), (0, pr - r)))
    if pr != r:
        v = jnp.pad(v, ((0, 0), (0, pr - r)))
        mask = jnp.pad(mask, (0, pr - r))

    out = pl.pallas_call(
        _fact_kernel,
        grid=(gb, gm, gr),
        in_specs=[
            pl.BlockSpec((bb, n), lambda i, j, k: (i, 0)),
            pl.BlockSpec((n, br), lambda i, j, k: (0, k)),
            pl.BlockSpec((br,), lambda i, j, k: (k,)),
            pl.BlockSpec((bm, br), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pm), jnp.float32),
        interpret=True,
    )(x, v, mask, u)
    return out[:b, :m]


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def factorized_linear(x, u, v, mask):
    """``((x @ V) * mask) @ U^T`` with Pallas fwd and bwd (differentiable)."""
    return _fact_fwd_pallas(x, u, v, mask, _BB, _BM, _BR)


def _fl_fwd(x, u, v, mask):
    y = _fact_fwd_pallas(x, u, v, mask, _BB, _BM, _BR)
    # Rematerialize t in the backward pass instead of saving it: residuals are
    # the (small) operands only, matching the paper's memory-bound concern.
    return y, (x, u, v, mask)


def _fl_bwd(res, g):
    x, u, v, mask = res
    # t = x @ V                      (b, r)
    # y = (t * mask) @ U^T           (b, m)
    t = pl_matmul(x, v)
    gu_path = pl_matmul(g, u)                      # (b, r) = g @ U
    dt = gu_path * mask[None, :]                   # (b, r)
    dx = pl_matmul(dt, v.T)                        # (b, n)
    dv = pl_matmul(x.T, dt)                        # (n, r)
    du = pl_matmul(g.T, t * mask[None, :])         # (m, r)
    dmask = jnp.sum(t * gu_path, axis=0)           # (r,)
    return dx, du, dv, dmask


factorized_linear.defvjp(_fl_fwd, _fl_bwd)
