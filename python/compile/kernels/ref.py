"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (python/tests/) asserts
`assert_allclose(kernel(...), ref(...))` over hypothesis-generated shape and
value sweeps.  The oracles intentionally use only `jnp` primitives so any
divergence is attributable to the Pallas implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain matmul oracle for the generic tiled Pallas matmul."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def factorized_matmul_ref(
    x: jax.Array, u: jax.Array, v: jax.Array, mask: jax.Array
) -> jax.Array:
    """Masked factorized linear: ``((x @ V) * mask) @ U^T``.

    Args:
      x:    (B, n) input activations.
      u:    (m, r) left factor.
      v:    (n, r) right factor.
      mask: (r,)   0/1 (or soft) rank mask selecting active components.

    Returns:
      (B, m) output — equal to ``x @ (U diag(mask) V^T)^T``.
    """
    t = jnp.dot(x, v, preferred_element_type=jnp.float32)
    return jnp.dot(t * mask, u.T, preferred_element_type=jnp.float32)


def gar_matmul_ref(x: jax.Array, u_hat: jax.Array, v_tilde: jax.Array) -> jax.Array:
    """Gauge-Aligned Reparametrization forward: ``y = [t, t @ Û^T]``.

    After GAR, ``W^T = Ṽ Ũ^T`` with ``Ũ = [I_r; Û]``; the identity block is
    never stored or multiplied.  ``t = x @ Ṽ`` gives the first r outputs
    directly, the remaining ``m - r`` come from ``t @ Û^T``.

    Args:
      x:       (B, n) input.
      u_hat:   (m - r, r) the non-identity part of Ũ.
      v_tilde: (n, r) right factor in the gauge.

    Returns:
      (B, m) output.
    """
    t = jnp.dot(x, v_tilde, preferred_element_type=jnp.float32)
    rest = jnp.dot(t, u_hat.T, preferred_element_type=jnp.float32)
    return jnp.concatenate([t, rest], axis=-1)


def kd_loss_ref(
    student_logits: jax.Array, teacher_logits: jax.Array, tau: float
) -> jax.Array:
    """Temperature-scaled KL distillation loss, mean over rows.

    ``KL(softmax(t/tau) || softmax(s/tau)) * tau^2`` averaged over the batch,
    the standard Hinton scaling so gradients are O(1) in tau.
    """
    sl = student_logits / tau
    tl = teacher_logits / tau
    log_ps = jax.nn.log_softmax(sl, axis=-1)
    log_pt = jax.nn.log_softmax(tl, axis=-1)
    pt = jnp.exp(log_pt)
    kl = jnp.sum(pt * (log_pt - log_ps), axis=-1)
    return jnp.mean(kl) * (tau**2)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Scaled-dot-product attention oracle.

    Args:
      q, k, v: (T, hd) single-head slices.
      causal:  apply a lower-triangular mask.

    Returns:
      (T, hd) attention output.
    """
    t = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.dot(p, v, preferred_element_type=jnp.float32)
