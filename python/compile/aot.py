"""AOT export: lower every L2 entry point to HLO **text** in ``artifacts/``.

Run once by ``make artifacts`` — python never runs on the request path.  The
rust runtime loads these with ``HloModuleProto::from_text_file`` and executes
them via the PJRT CPU client.

Interchange is HLO text, NOT a serialized ``HloModuleProto``: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Alongside each ``<name>.hlo.txt`` we write ``manifest.json`` describing the
exact flattened input/output order (pytree-path names, shapes, dtypes) so the
rust side never has to guess jax's dict-key flattening order.

Two-phase serving export: phase 1 (default) uses uniform-rank tier profiles;
after the rust DP stage writes ``artifacts/profiles.json`` the serving
forwards are re-lowered at the Pareto profiles (``make serve-artifacts``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return ".".join(out)


def _spec_of(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        {
            "name": _path_str(path),
            "shape": list(np.shape(leaf)),
            "dtype": str(np.asarray(leaf).dtype) if not hasattr(leaf, "dtype") else str(leaf.dtype),
        }
        for path, leaf in flat
    ]


class Exporter:
    def __init__(self, cfg: M.Config, out_dir: str):
        self.cfg = cfg
        self.out_dir = out_dir
        self.manifest = {
            "config": json.loads(json.dumps(cfg.__dict__)),
            "artifacts": {},
        }
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn, *example_args):
        """Lower fn(*example_args) and record its I/O spec in the manifest."""
        t0 = time.time()
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *example_args)
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _spec_of(list(example_args)),
            "outputs": _spec_of(outs),
        }
        print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


def _tier_profiles(cfg: M.Config, out_dir: str) -> list:
    """Per-tier rank profiles: DP output if present, else uniform ranks."""
    pj = os.path.join(out_dir, "profiles.json")
    if os.path.exists(pj):
        with open(pj) as f:
            data = json.load(f)
        profs = data["tiers"]
        assert len(profs) == len(cfg.serve_tiers)
        print(f"  using DP profiles from {pj}")
        return [[int(r) for r in p] for p in profs]
    return [
        [max(4, round(t * cfg.rank_full))] * cfg.n_fact_layers for t in cfg.serve_tiers
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=os.environ.get("FLEXRANK_CONFIG", "base"))
    ap.add_argument("--out", default=os.path.join(_REPO, "artifacts"))
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact names to (re)export (default: all)",
    )
    args = ap.parse_args()
    cfg = M.load_config(args.config)
    ex = Exporter(cfg, args.out)
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    print(f"AOT export: config={cfg.name} -> {args.out}")
    d = cfg.d_model
    tshape = jax.ShapeDtypeStruct  # alias

    tp = M.init_teacher(cfg)
    tp_spec = jax.tree_util.tree_map(lambda x: tshape(x.shape, x.dtype), tp)
    sp = M.init_student_svd(cfg, tp)
    sp_spec = jax.tree_util.tree_map(lambda x: tshape(x.shape, x.dtype), sp)
    masks_spec = tshape((cfg.n_blocks, 4, cfg.rank_full), jnp.float32)
    step_spec = tshape((), jnp.float32)

    tok_train = tshape((cfg.batch_train, cfg.seq_len + 1), jnp.int32)
    tok_eval = tshape((cfg.batch_eval, cfg.seq_len + 1), jnp.int32)
    tok_fwd = tshape((cfg.batch_eval, cfg.seq_len), jnp.int32)
    tok_calib = tshape((cfg.batch_calib, cfg.seq_len), jnp.int32)
    tok_serve = tshape((cfg.batch_serve, cfg.seq_len), jnp.int32)

    # --- teacher -----------------------------------------------------------
    if want("teacher_fwd"):
        ex.export("teacher_fwd", lambda p, t: (M.teacher_fwd(cfg, p, t),), tp_spec, tok_fwd)
    if want("teacher_acts"):
        ex.export("teacher_acts", lambda p, t: M.teacher_fwd_acts(cfg, p, t), tp_spec, tok_calib)
    if want("teacher_train_step"):
        ex.export(
            "teacher_train_step",
            lambda p, m, v, s, t: M.teacher_train_step(cfg, p, m, v, s, t),
            tp_spec, tp_spec, tp_spec, step_spec, tok_train,
        )

    # --- student -----------------------------------------------------------
    if want("student_eval"):
        ex.export(
            "student_eval",
            lambda p, mk, t: (M.student_eval(cfg, p, mk, t),),
            sp_spec, masks_spec, tok_eval,
        )
    if want("student_logits"):
        ex.export(
            "student_logits",
            lambda p, mk, t: (M.student_fwd(cfg, p, mk, t, pallas_attention=True),),
            sp_spec, masks_spec, tok_fwd,
        )
    if want("kd_train_step"):
        ex.export(
            "kd_train_step",
            lambda p, m, v, s, tpar, mk, t: M.kd_train_step(cfg, p, m, v, s, tpar, mk, t),
            sp_spec, sp_spec, sp_spec, step_spec, tp_spec, masks_spec, tok_train,
        )

    # --- GAR serving tiers + LoRA (Tab. 1) ---------------------------------
    profiles = _tier_profiles(cfg, args.out)
    lora_spec = [tshape(s, jnp.float32) for _, s in M.lora_param_spec(cfg)]
    for i, prof in enumerate(profiles):
        gar_spec = [tshape(s, jnp.float32) for _, s in M.gar_param_spec(cfg, prof)]
        if want(f"serve_gar_t{i}"):
            ex.export(
                f"serve_gar_t{i}",
                lambda fp, t, prof=prof: (M.gar_fwd(cfg, fp, prof, t),),
                gar_spec, tok_serve,
            )
            ex.manifest["artifacts"][f"serve_gar_t{i}"]["profile"] = prof
            ex.manifest["artifacts"][f"serve_gar_t{i}"]["tier"] = cfg.serve_tiers[i]
        if want(f"lora_train_step_t{i}"):
            ex.export(
                f"lora_train_step_t{i}",
                lambda gp, lp, m, v, s, t, prof=prof: M.lora_train_step(
                    cfg, gp, lp, m, v, s, prof, t
                ),
                gar_spec, lora_spec, lora_spec, lora_spec, step_spec, tok_train,
            )
        if want(f"lora_logits_t{i}"):
            ex.export(
                f"lora_logits_t{i}",
                lambda gp, lp, t, prof=prof: (M.gar_lora_fwd(cfg, gp, lp, prof, t),),
                gar_spec, lora_spec, tok_fwd,
            )

    # --- Fig. 10 bench kernels ----------------------------------------------
    bdim, bb = cfg.bench_dim, cfg.bench_batch
    if want("bench_dense"):
        ex.export(
            "bench_dense", M.bench_dense,
            tshape((bb, bdim), jnp.float32), tshape((bdim, bdim), jnp.float32),
        )
    for r in cfg.bench_ranks:
        if r > bdim:
            continue
        if want(f"bench_lowrank_r{r}"):
            ex.export(
                f"bench_lowrank_r{r}", M.bench_lowrank,
                tshape((bb, bdim), jnp.float32),
                tshape((bdim, r), jnp.float32), tshape((r, bdim), jnp.float32),
            )
        if want(f"bench_gar_r{r}") and r < bdim:
            ex.export(
                f"bench_gar_r{r}", M.bench_gar,
                tshape((bb, bdim), jnp.float32),
                tshape((bdim - r, r), jnp.float32), tshape((bdim, r), jnp.float32),
            )

    # --- initial teacher parameters (random init, canonical flat order) -----
    flat, _ = jax.tree_util.tree_flatten(tp)
    blob = np.concatenate([np.asarray(a, np.float32).ravel() for a in flat])
    blob.tofile(os.path.join(args.out, "teacher_init.bin"))
    ex.manifest["teacher_init"] = {
        "file": "teacher_init.bin",
        "params": _spec_of(tp),
        "total_f32": int(blob.size),
    }
    ex.manifest["profiles"] = profiles
    ex.finish()
    print(f"wrote manifest with {len(ex.manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
