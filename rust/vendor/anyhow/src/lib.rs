//! Offline stand-in for the `anyhow` crate.
//!
//! The CI image has no crates.io access, so this path dependency provides
//! the exact API subset the repo uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`] extension
//! trait.  Error chains are flattened into one message string (`ctx: cause`),
//! which is all the repo's error reporting relies on.  On a networked
//! machine the real `anyhow = "1"` is a drop-in replacement.

use std::fmt;

/// A flattened error message chain.
///
/// Like `anyhow::Error`, this deliberately does NOT implement
/// `std::error::Error` — that keeps the blanket `From<E: std::error::Error>`
/// conversion coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the source chain into one line.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prepends() {
        let e = io_fail().context("loading config").unwrap_err();
        assert!(e.to_string().starts_with("loading config: "), "{e}");
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e: Error = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");

        fn f(v: usize) -> Result<usize> {
            ensure!(v < 10, "too big: {v}");
            if v == 5 {
                bail!("five is right out");
            }
            Ok(v)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }
}
