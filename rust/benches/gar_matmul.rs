//! Bench: GAR vs naive low-rank vs dense forward (paper Fig. 10).
//!
//! Times the native kernels across the rank sweep and prints
//! relative-to-dense costs next to the analytic MAC model
//! `(m + n − r)·r / (m·n)`.  (The PJRT artifact variant of these numbers
//! lives in `benches/train_step.rs` behind `--features pjrt`.)
//!
//! `cargo bench --bench gar_matmul` (BENCH_QUICK=1 for the short profile).

use flexrank::bench_harness;
use flexrank::flexrank::gar::Gar;
use flexrank::linalg::{kernels, Mat};
use flexrank::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = flexrank::config::load_model_config("base")?;
    let mut bench = bench_harness::from_env();
    let mut rng = Rng::new(10);
    let (bdim, bb) = (cfg.bench_dim, cfg.bench_batch);
    let elems = (bb * bdim) as f64;

    let x = Mat::randn(bb, bdim, &mut rng);
    let w = Mat::randn(bdim, bdim, &mut rng);
    let dense = bench
        .run("bench_dense", Some(elems), || {
            std::hint::black_box(kernels::matmul(&x, &w).data.len());
        })
        .mean_secs();

    println!("\nrank  rel_measured(lowrank)  rel_measured(gar)  rel_macs(lowrank)  rel_macs(gar)");
    for &r in &cfg.bench_ranks {
        if r > bdim {
            continue;
        }
        // Naive factorized: two full products through (n, r) and (r, m).
        let v = Mat::randn(bdim, r, &mut rng);
        let ut = Mat::randn(r, bdim, &mut rng);
        let low = bench
            .run(&format!("bench_lowrank_r{r}"), Some(elems), || {
                let t = kernels::matmul(&x, &v);
                std::hint::black_box(kernels::matmul(&t, &ut).data.len());
            })
            .mean_secs()
            / dense;
        let (gar_rel, gar_mac) = if r < bdim {
            let gar = Gar {
                u_hat: Mat::randn(bdim - r, r, &mut rng),
                v_tilde: Mat::randn(bdim, r, &mut rng),
                rank: r,
            };
            let mut arena = kernels::Arena::new();
            let warm = gar.forward_arena(&x, &mut arena);
            arena.give(warm);
            let g = bench
                .run(&format!("bench_gar_r{r}"), Some(elems), || {
                    let y = gar.forward_arena(&x, &mut arena);
                    std::hint::black_box(y[0]);
                    arena.give(y);
                })
                .mean_secs()
                / dense;
            (g, ((2 * bdim - r) * r) as f64 / (bdim * bdim) as f64)
        } else {
            (f64::NAN, f64::NAN)
        };
        let low_mac = (2 * bdim * r) as f64 / (bdim * bdim) as f64;
        println!("{r:>4}  {low:>20.3}  {gar_rel:>17.3}  {low_mac:>17.3}  {gar_mac:>13.3}");
    }
    bench.write_csv(flexrank::results_dir().join("bench_gar_matmul.csv"))?;
    Ok(())
}
