//! Bench: GAR vs naive low-rank vs dense forward (paper Fig. 10).
//!
//! Times the AOT single-matmul artifacts through PJRT across the rank sweep
//! and prints relative-to-dense costs next to the analytic MAC model.
//! `cargo bench --bench gar_matmul` (BENCH_QUICK=1 for the short profile).

use flexrank::bench_harness;
use flexrank::runtime::{Engine, Tensor};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(flexrank::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let mut bench = bench_harness::from_env();
    let (bdim, bb) = (cfg.bench_dim, cfg.bench_batch);
    let elems = (bb * bdim) as f64;

    let mut run_one = |name: &str| -> anyhow::Result<f64> {
        let exe = engine.load(name)?;
        let inputs: Vec<Tensor> = exe
            .spec
            .inputs
            .iter()
            .map(|s| Tensor::f32(s.shape.clone(), vec![0.01; s.numel()]))
            .collect();
        let stats = bench.run(name, Some(elems), || {
            exe.run(&inputs).expect("bench exec failed");
        });
        Ok(stats.mean_secs())
    };

    let dense = run_one("bench_dense")?;
    println!("\nrank  rel_measured(lowrank)  rel_measured(gar)  rel_macs(lowrank)  rel_macs(gar)");
    for &r in &cfg.bench_ranks.clone() {
        if r > bdim {
            continue;
        }
        let low = run_one(&format!("bench_lowrank_r{r}"))? / dense;
        let (gar, gar_mac) = if r < bdim {
            (
                run_one(&format!("bench_gar_r{r}"))? / dense,
                ((2 * bdim - r) * r) as f64 / (bdim * bdim) as f64,
            )
        } else {
            (f64::NAN, f64::NAN)
        };
        let low_mac = (2 * bdim * r) as f64 / (bdim * bdim) as f64;
        println!("{r:>4}  {low:>20.3}  {gar:>17.3}  {low_mac:>17.3}  {gar_mac:>13.3}");
    }
    bench.write_csv(flexrank::results_dir().join("bench_gar_matmul.csv"))?;
    Ok(())
}
