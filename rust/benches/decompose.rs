//! Bench: DataSVD layer decomposition (covariance accumulation + whitened
//! SVD) at the model's real layer shapes.

use flexrank::bench_harness;
use flexrank::flexrank::decompose::{CovAccum, DataSvd};
use flexrank::linalg::Mat;
use flexrank::rng::Rng;

fn main() {
    let mut bench = bench_harness::from_env();
    let mut rng = Rng::new(3);
    // The byte-GPT base layer shapes: (n_in, m_out).
    for (name, n, m) in [
        ("qkv 128x384", 128usize, 384usize),
        ("proj 128x128", 128, 128),
        ("fc 128x512", 128, 512),
        ("fcp 512x128", 512, 128),
    ] {
        let w = Mat::randn(n, m, &mut rng);
        let x = Mat::randn(256, n, &mut rng);
        let mut cov = CovAccum::new(n);
        cov.add_batch(&x);
        bench.run(&format!("cov_accum {name}"), Some((256 * n) as f64), || {
            let mut c = CovAccum::new(n);
            c.add_batch(&x);
            std::hint::black_box(c.count);
        });
        bench.run(&format!("datasvd {name}"), Some((n * m) as f64), || {
            std::hint::black_box(DataSvd::compute(&w, &cov, 1e-7).lambda.len());
        });
        bench.run(&format!("plain_svd {name}"), Some((n * m) as f64), || {
            std::hint::black_box(DataSvd::compute_plain(&w).lambda.len());
        });
    }
    bench
        .write_csv(flexrank::results_dir().join("bench_decompose.csv"))
        .expect("csv");
}
