//! Bench: end-to-end elastic serving throughput/latency under load, static
//! vs adaptive policy — the L3 headline numbers, now on the native kernel
//! backend (runs fully offline, no PJRT).

use flexrank::coordinator::{serve_trace, serve_trace_decode, PolicyKind, ServeCfg, SubmodelRegistry};
use flexrank::data::{Corpus, TraceCfg, TraceGen};
use flexrank::runtime::ServingBackend;
use flexrank::training::params::{decompose_teacher, random_teacher, student_from_factors};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let cfg = flexrank::config::load_model_config(if quick { "tiny" } else { "base" })?;
    let teacher = random_teacher(&cfg, 7);
    let factors = decompose_teacher(&cfg, &teacher, None)?;
    let student = student_from_factors(&cfg, &teacher, &factors)?;
    let mut registry = SubmodelRegistry::load_native(&cfg, &student, None)?;
    println!("attention path: {} (seq_len {})", registry.attn_path_label(), cfg.seq_len);
    println!(
        "simd: {}; tier precision: [{}]",
        flexrank::linalg::simd::isa_label(),
        (0..registry.n_tiers())
            .map(|t| registry.tier_precision_label(t))
            .collect::<Vec<_>>()
            .join(", ")
    );
    for (i, tier) in registry.tiers.iter().enumerate() {
        println!(
            "  tier {i}: {} stored factor bytes ({})",
            flexrank::training::params::quantized_profile_bytes(&cfg, &tier.profile, tier.precision),
            tier.precision.label()
        );
    }
    let corpus = Corpus::generate(100_000, 5);
    let n = if quick { 80 } else { 400 };

    println!("policy    rate(req/s)  achieved(req/s)  p50(ms)  p95(ms)  exec_p50(ms)  occupancy");
    for policy in [PolicyKind::Static, PolicyKind::Adaptive] {
        for rate in [100.0, 400.0, 1600.0] {
            let trace = TraceGen::new(
                TraceCfg {
                    n_requests: n,
                    rate,
                    seq_len: cfg.seq_len,
                    vocab: cfg.vocab,
                    seed: 7,
                    ..Default::default()
                },
                &corpus.heldout,
            )
            .generate();
            let report = serve_trace(
                &mut registry,
                trace,
                &ServeCfg { policy, max_wait_ms: 4.0, replay_speed: 1.0 },
            )?;
            // Aggregate across tiers (exec_p50 is the kernel-path number
            // the pooled kernels + blocked attention move at batch ≥ 4).
            let mut all: Vec<f64> = Vec::new();
            let mut exec: Vec<f64> = Vec::new();
            for t in 0..report.tier_budgets.len() {
                all.extend(report.metrics.latency_ms[t].iter());
                exec.extend(report.metrics.exec_ms[t].iter());
            }
            let stats = flexrank::coordinator::LatencyStats::from_samples(&all);
            let estats = flexrank::coordinator::LatencyStats::from_samples(&exec);
            println!(
                "{:>8}  {rate:>11.0}  {:>15.1}  {:>7.1}  {:>7.1}  {:>12.2}  {:>8.2}",
                format!("{policy:?}"),
                report.throughput_rps(),
                stats.p50_ms,
                stats.p95_ms,
                estats.p50_ms,
                report.metrics.mean_occupancy(),
            );
        }
    }

    // Continuous-batching decode path: variable-length prompts with
    // generation through the prefill/decode seam over the paged K/V cache.
    // The headline is tokens/sec (prefilled + generated over the wall), and
    // the step latencies the batcher's join/retire churn produces.
    println!();
    println!(
        "decode    rate(req/s)  tok/s  prefill_p50(ms)  decode_p50(ms)  decode_p99(ms)  req_p50(ms)"
    );
    for rate in [100.0, 400.0] {
        let trace = TraceGen::new(
            TraceCfg {
                n_requests: n,
                rate,
                seq_len: cfg.seq_len,
                vocab: cfg.vocab,
                seed: 11,
                prompt_len_min: (cfg.seq_len / 8).max(1),
                prompt_len_max: cfg.seq_len,
                gen_len_min: 1,
                gen_len_max: (cfg.seq_len / 2).max(1),
                ..Default::default()
            },
            &corpus.heldout,
        )
        .generate();
        let report = serve_trace_decode(
            &mut registry,
            trace,
            &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 4.0, replay_speed: 1.0 },
        )?;
        let d = report.decode_latency();
        let p = report.prefill_latency();
        let l = report.request_latency();
        println!(
            "{:>8}  {rate:>11.0}  {:>5.0}  {:>15.3}  {:>14.3}  {:>14.3}  {:>10.1}",
            "Static",
            report.tokens_per_sec(),
            p.p50_ms,
            d.p50_ms,
            d.p99_ms,
            l.p50_ms,
        );
    }
    Ok(())
}
