//! Bench: end-to-end elastic serving throughput/latency under load, static
//! vs adaptive policy (the L3 headline numbers for EXPERIMENTS.md §Perf).

use flexrank::coordinator::{serve_trace, PolicyKind, ServeCfg};
use flexrank::data::{Corpus, TraceCfg, TraceGen};
use flexrank::runtime::Engine;
use flexrank::training::params::{decompose_teacher, student_from_factors, ParamSet};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(flexrank::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let teacher = ParamSet::from_specs(
        &engine.manifest.teacher_init,
        engine.manifest.load_teacher_init()?,
    );
    let factors = decompose_teacher(&cfg, &teacher, None)?;
    let student = student_from_factors(&cfg, &teacher, &factors)?;
    let corpus = Corpus::generate(100_000, 5);
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n = if quick { 80 } else { 400 };

    println!("policy    rate(req/s)  achieved(req/s)  p50(ms)  p95(ms)  occupancy");
    for policy in [PolicyKind::Static, PolicyKind::Adaptive] {
        for rate in [100.0, 400.0, 1600.0] {
            let trace = TraceGen::new(
                TraceCfg {
                    n_requests: n,
                    rate,
                    seq_len: cfg.seq_len,
                    vocab: cfg.vocab,
                    seed: 7,
                    ..Default::default()
                },
                &corpus.heldout,
            )
            .generate();
            let report = serve_trace(
                &engine,
                &student,
                trace,
                &ServeCfg { policy, max_wait_ms: 4.0, replay_speed: 1.0 },
            )?;
            // Aggregate across tiers.
            let mut all: Vec<f64> = Vec::new();
            for t in 0..report.tier_budgets.len() {
                all.extend(report.metrics.latency_ms[t].iter());
            }
            let stats = flexrank::coordinator::LatencyStats::from_samples(&all);
            println!(
                "{:>8}  {rate:>11.0}  {:>15.1}  {:>7.1}  {:>7.1}  {:>8.2}",
                format!("{policy:?}"),
                report.throughput_rps(),
                stats.p50_ms,
                stats.p95_ms,
                report.metrics.mean_occupancy(),
            );
        }
    }
    Ok(())
}
