//! Bench: end-to-end elastic serving throughput/latency under load, static
//! vs adaptive policy — the L3 headline numbers, now on the native kernel
//! backend (runs fully offline, no PJRT).

use flexrank::coordinator::{
    serve_trace, serve_trace_decode, ListenCfg, Listener, PolicyKind, ServeCfg, SubmodelRegistry,
};
use flexrank::data::{Corpus, TraceCfg, TraceGen};
use flexrank::runtime::ServingBackend;
use flexrank::training::params::{decompose_teacher, random_teacher, student_from_factors};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let cfg = flexrank::config::load_model_config(if quick { "tiny" } else { "base" })?;
    let teacher = random_teacher(&cfg, 7);
    let factors = decompose_teacher(&cfg, &teacher, None)?;
    let student = student_from_factors(&cfg, &teacher, &factors)?;
    let mut registry = SubmodelRegistry::load_native(&cfg, &student, None)?;
    println!("attention path: {} (seq_len {})", registry.attn_path_label(), cfg.seq_len);
    println!(
        "simd: {}; tier precision: [{}]",
        flexrank::linalg::simd::isa_label(),
        (0..registry.n_tiers())
            .map(|t| registry.tier_precision_label(t))
            .collect::<Vec<_>>()
            .join(", ")
    );
    for (i, tier) in registry.tiers.iter().enumerate() {
        println!(
            "  tier {i}: {} stored factor bytes ({})",
            flexrank::training::params::quantized_profile_bytes(&cfg, &tier.profile, tier.precision),
            tier.precision.label()
        );
    }
    let corpus = Corpus::generate(100_000, 5);
    let n = if quick { 80 } else { 400 };

    println!("policy    rate(req/s)  achieved(req/s)  p50(ms)  p95(ms)  exec_p50(ms)  occupancy");
    for policy in [PolicyKind::Static, PolicyKind::Adaptive] {
        for rate in [100.0, 400.0, 1600.0] {
            let trace = TraceGen::new(
                TraceCfg {
                    n_requests: n,
                    rate,
                    seq_len: cfg.seq_len,
                    vocab: cfg.vocab,
                    seed: 7,
                    ..Default::default()
                },
                &corpus.heldout,
            )?
            .generate();
            let report = serve_trace(
                &mut registry,
                trace,
                &ServeCfg { policy, max_wait_ms: 4.0, replay_speed: 1.0, ..Default::default() },
            )?;
            // Aggregate across tiers (exec_p50 is the kernel-path number
            // the pooled kernels + blocked attention move at batch ≥ 4).
            let mut all: Vec<f64> = Vec::new();
            let mut exec: Vec<f64> = Vec::new();
            for t in 0..report.tier_budgets.len() {
                all.extend(report.metrics.latency_ms[t].iter());
                exec.extend(report.metrics.exec_ms[t].iter());
            }
            let stats = flexrank::coordinator::LatencyStats::from_samples(&all);
            let estats = flexrank::coordinator::LatencyStats::from_samples(&exec);
            println!(
                "{:>8}  {rate:>11.0}  {:>15.1}  {:>7.1}  {:>7.1}  {:>12.2}  {:>8.2}",
                format!("{policy:?}"),
                report.throughput_rps(),
                stats.p50_ms,
                stats.p95_ms,
                estats.p50_ms,
                report.metrics.mean_occupancy(),
            );
        }
    }

    // Continuous-batching decode path: variable-length prompts with
    // generation through the prefill/decode seam over the paged K/V cache.
    // The headline is tokens/sec (prefilled + generated over the wall), and
    // the step latencies the batcher's join/retire churn produces.
    println!();
    println!(
        "decode    rate(req/s)  tok/s  prefill_p50(ms)  decode_p50(ms)  decode_p99(ms)  req_p50(ms)"
    );
    for rate in [100.0, 400.0] {
        let trace = TraceGen::new(
            TraceCfg {
                n_requests: n,
                rate,
                seq_len: cfg.seq_len,
                vocab: cfg.vocab,
                seed: 11,
                prompt_len_min: (cfg.seq_len / 8).max(1),
                prompt_len_max: cfg.seq_len,
                gen_len_min: 1,
                gen_len_max: (cfg.seq_len / 2).max(1),
                ..Default::default()
            },
            &corpus.heldout,
        )?
        .generate();
        let report = serve_trace_decode(
            &mut registry,
            trace,
            &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 4.0, replay_speed: 1.0, ..Default::default() },
        )?;
        let d = report.decode_latency();
        let p = report.prefill_latency();
        let l = report.request_latency();
        println!(
            "{:>8}  {rate:>11.0}  {:>5.0}  {:>15.3}  {:>14.3}  {:>14.3}  {:>10.1}",
            "Static",
            report.tokens_per_sec(),
            p.p50_ms,
            d.p50_ms,
            d.p99_ms,
            l.p50_ms,
        );
    }

    // Quality-vs-load Pareto: the same overload trace per arrival scenario,
    // served under each routing policy with an explicit queue cap (shed on)
    // and a fast controller dwell.  Rows are the Pareto coordinates: the
    // served-quality proxy (request-weighted tier calibration error — lower
    // is better), the shed/demotion rates, the latency tail, and how often
    // the elastic controller actually moved.  The watchpoint: under the
    // bursty overload, Elastic must shed strictly less than Adaptive at an
    // equal-or-better p99 (demote-before-shed doing its job), at the cost
    // of a higher loss proxy while demoted.
    println!();
    println!(
        "pareto    scenario     policy     loss_proxy  shed%   demote%  p50(ms)  p99(ms)  switches"
    );
    let pareto_cap = 2 * registry.batch();
    let pareto_rate = if quick { 4000.0 } else { 8000.0 };
    for scenario in ["steady", "diurnal", "bursty", "adversarial"] {
        let shape = flexrank::data::ArrivalShape::parse(scenario)?;
        let mut bursty_rows: Vec<(PolicyKind, f64, f64)> = Vec::new();
        for policy in [PolicyKind::Static, PolicyKind::Adaptive, PolicyKind::Elastic] {
            let trace = TraceGen::new(
                TraceCfg {
                    n_requests: n,
                    rate: pareto_rate,
                    seq_len: cfg.seq_len,
                    vocab: cfg.vocab,
                    seed: 7,
                    shape,
                    tenants: flexrank::data::TenantCfg::default_mix(),
                    ..Default::default()
                },
                &corpus.heldout,
            )?
            .generate();
            let report = serve_trace(
                &mut registry,
                trace,
                &ServeCfg {
                    policy,
                    max_wait_ms: 4.0,
                    replay_speed: 1.0,
                    queue_cap: pareto_cap,
                    dwell_ms: 2.0,
                    ..Default::default()
                },
            )?;
            let mut all: Vec<f64> = Vec::new();
            for t in 0..report.tier_budgets.len() {
                all.extend(report.metrics.latency_ms[t].iter());
            }
            let stats = flexrank::coordinator::LatencyStats::from_samples(&all);
            println!(
                "{:>8}  {scenario:>11}  {:>8}  {:>10.4}  {:>5.1}  {:>7.1}  {:>7.1}  {:>7.1}  {:>8}",
                "pareto",
                policy.label(),
                report.eval_loss_proxy(),
                report.shed_rate() * 100.0,
                report.metrics.demotion_rate() * 100.0,
                stats.p50_ms,
                stats.p99_ms,
                report.tier_switches,
            );
            if scenario == "bursty" {
                bursty_rows.push((policy, report.shed_rate(), stats.p99_ms));
            }
        }
        if let (Some(adap), Some(elas)) = (
            bursty_rows.iter().find(|r| r.0 == PolicyKind::Adaptive),
            bursty_rows.iter().find(|r| r.0 == PolicyKind::Elastic),
        ) {
            let dominated = elas.1 < adap.1 && elas.2 <= adap.2 * 1.05
                || elas.1 <= adap.1 && elas.2 < adap.2;
            println!(
                "pareto verdict (bursty overload): elastic shed {:.1}% p99 {:.1}ms vs \
                 adaptive shed {:.1}% p99 {:.1}ms -> {}",
                elas.1 * 100.0,
                elas.2,
                adap.1 * 100.0,
                adap.2,
                if dominated { "elastic dominates" } else { "no dominance (check load)" }
            );
        }
    }

    // Online listener front-end over loopback: bursty multi-tenant clients
    // pipeline framed requests through real sockets; the headline is
    // sustained req/s and the end-to-end (send → response frame) latency
    // tail, plus explicit shed counts under the admission bound.
    println!();
    println!("listener  tenants  reqs  ok  shed  req/s  p50(ms)  p99(ms)");
    let lcfg = ListenCfg {
        serve: ServeCfg { policy: PolicyKind::Static, max_wait_ms: 4.0, replay_speed: 1.0, ..Default::default() },
        max_connections: 16,
        queue_cap: 64,
        conn_pipeline: 8,
    };
    let listener = Listener::bind("127.0.0.1:0", lcfg)?;
    let addr = listener.local_addr()?;
    let handle = listener.shutdown_handle();
    let n_clients: usize = if quick { 3 } else { 6 };
    let per_client: usize = if quick { 24 } else { 80 };
    let seq = registry.seq_len();
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || -> anyhow::Result<(Vec<f64>, usize, usize)> {
                use flexrank::data::trace::wire::{self, Status};
                use flexrank::data::trace::Slo;
                use flexrank::data::Request;
                use std::io::Write;
                let mut stream = std::net::TcpStream::connect(addr)?;
                stream.set_nodelay(true)?;
                let burst = 4usize;
                let mut latencies = Vec::new();
                let (mut ok, mut shed) = (0usize, 0usize);
                let mut buf = Vec::with_capacity(wire::MAX_PAYLOAD);
                let mut out = Vec::new();
                let mut sent_at = std::collections::HashMap::new();
                let mut next_id = 1u64;
                for _ in 0..per_client / burst {
                    out.clear();
                    for _ in 0..burst {
                        let req = Request {
                            id: next_id,
                            arrival_s: 0.0,
                            slo: Slo::ALL[next_id as usize % Slo::ALL.len()],
                            tokens: (0..(seq / 4).max(1)).map(|t| (t % 50) as i32).collect(),
                            gen_len: 4,
                            budget: None,
                        };
                        wire::encode_request(&mut out, &req);
                        sent_at.insert(next_id, std::time::Instant::now());
                        next_id += 1;
                    }
                    stream.write_all(&out)?;
                    for _ in 0..burst {
                        let magic = wire::read_frame(&mut stream, &mut buf, wire::MAX_PAYLOAD)?
                            .ok_or_else(|| anyhow::anyhow!("server closed mid-burst"))?;
                        anyhow::ensure!(magic == wire::RESP_MAGIC, "bad response magic {magic}");
                        let (id, status, _tokens) = wire::decode_response(&buf)?;
                        if let Some(t0) = sent_at.remove(&id) {
                            latencies.push(t0.elapsed().as_secs_f64() * 1e3);
                        }
                        match status {
                            Status::Ok => ok += 1,
                            Status::Shed => shed += 1,
                            Status::Error => {}
                        }
                    }
                    // Bursty tenant: idle gap between bursts, staggered per
                    // tenant so arrivals overlap unevenly.
                    std::thread::sleep(std::time::Duration::from_millis(2 + c as u64));
                }
                Ok((latencies, ok, shed))
            })
        })
        .collect();
    // The supervisor joins every tenant, then begins the graceful drain;
    // the main thread owns the backend and runs the serving loop.
    let supervisor = std::thread::spawn(move || {
        let mut latencies = Vec::new();
        let (mut ok, mut shed) = (0usize, 0usize);
        for c in clients {
            match c.join() {
                Ok(Ok((l, o, s))) => {
                    latencies.extend(l);
                    ok += o;
                    shed += s;
                }
                Ok(Err(e)) => eprintln!("bench tenant failed: {e}"),
                Err(_) => eprintln!("bench tenant panicked"),
            }
        }
        handle.shutdown();
        (latencies, ok, shed)
    });
    let report = listener.run(&mut registry)?;
    let (latencies, ok, shed) = supervisor.join().expect("supervisor thread");
    let stats = flexrank::coordinator::LatencyStats::from_samples(&latencies);
    println!(
        "{:>8}  {:>7}  {:>4}  {ok:>2}  {shed:>4}  {:>5.0}  {:>7.2}  {:>7.2}",
        "framed",
        n_clients,
        n_clients * per_client,
        report.requests_done as f64 / report.wall_s.max(1e-9),
        stats.p50_ms,
        stats.p99_ms,
    );
    anyhow::ensure!(
        report.ingest_fingerprint_drift == 0,
        "zero-alloc ingest invariant broke under load ({} drifts)",
        report.ingest_fingerprint_drift
    );
    Ok(())
}
