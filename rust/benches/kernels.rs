//! Bench: the native kernel layer vs the naive reference loops.
//!
//! Covers the two acceptance surfaces of the kernel PR:
//!   * blocked+parallel matmul vs the seed's scalar ikj loop (f64 and f32)
//!     across square and model-shaped problems, and
//!   * the fused GAR forward vs the two-matmul + row-copy implementation
//!     across the rank sweep.
//!
//! Emits `results/BENCH_kernels.json` (kernel, shape, mean ns, GFLOP/s,
//! speedup-vs-reference) via `bench_harness::write_kernel_json` — the seed
//! of the perf trajectory — plus the usual CSV.  Since the SIMD PR the file
//! also carries `simd_vs_scalar …` rows (dispatched f32 kernels re-based on
//! the scalar oracle) and `quantized_vs_f32 …` rows (bf16/i8 factor kernels
//! re-based on their f32 twins, one pair per serve tier).  Since the paged
//! decode PR it also carries `attention_decode …` rows — the single-query
//! page-gather step re-based on a contiguous scalar single-query reference
//! at 1×/4×/16× context lengths.
//!
//! `cargo bench --bench kernels` (`BENCH_QUICK=1` for the short profile).

use flexrank::bench_harness::{self, write_kernel_json, KernelRecord};
use flexrank::flexrank::gar::Gar;
use flexrank::linalg::quant::{Precision, QuantMat};
use flexrank::linalg::{kernels, reference, simd, Mat};
use flexrank::rng::Rng;
use flexrank::runtime::attention::{causal_attention, AttnWorkspace, DEFAULT_ATTN_TILE};
use flexrank::runtime::native::uniform_budget_profile;

fn main() {
    let mut bench = bench_harness::from_env();
    let mut rng = Rng::new(17);
    let mut records: Vec<KernelRecord> = Vec::new();
    println!("simd: {}", simd::isa_label());

    // --- matmul: square sweep + the model's layer shapes -------------------
    let shapes: &[(usize, usize, usize)] = &[
        // Pool-dispatch-sensitive sizes: (64³, 96³) sat below the old
        // scoped-thread 1M-MAC floor and ran serial; (128,64,128) sat just
        // above it and paid a thread spawn+join per call.  With the
        // persistent pool all three go parallel for ~µs of dispatch —
        // these rows are where BENCH_kernels.json records the win.
        (64, 64, 64),
        (96, 96, 96),
        (128, 64, 128),
        (128, 128, 128),
        (256, 256, 256),
        (512, 512, 512),
        (512, 128, 384), // (B·T, n, m) of the qkv layer
        (512, 512, 128), // fcp layer
    ];
    for &(m, k, n) in shapes {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let shape = format!("{m}x{k}x{n}");
        let flops = (2 * m * k * n) as f64;

        let refstats = bench.run(&format!("matmul_ref {shape}"), Some(flops), || {
            std::hint::black_box(reference::matmul(&a, &b).data.len());
        });
        let blk = bench.run(&format!("matmul_f64 {shape}"), Some(flops), || {
            std::hint::black_box(kernels::matmul(&a, &b).data.len());
        });
        records.push(KernelRecord::from_stats(&blk, &refstats, &shape, flops));

        // Allocation-free variant (the serving configuration).
        let mut out = Mat::zeros(m, n);
        let into = bench.run(&format!("matmul_f64_into {shape}"), Some(flops), || {
            kernels::matmul_into(&a, &b, &mut out);
            std::hint::black_box(out.data[0]);
        });
        records.push(KernelRecord::from_stats(&into, &refstats, &shape, flops));

        // f32 path.
        let a32 = a.to_f32();
        let b32 = b.to_f32();
        let mut o32 = vec![0f32; m * n];
        let f32s = bench.run(&format!("matmul_f32 {shape}"), Some(flops), || {
            kernels::matmul_f32(&a32, &b32, m, k, n, &mut o32);
            std::hint::black_box(o32[0]);
        });
        records.push(KernelRecord::from_stats(&f32s, &refstats, &shape, flops));

        // Dispatched f32 re-based on the scalar oracle — the row the SIMD
        // acceptance gate reads (speedup ≈ 1 when FLEXRANK_SIMD=scalar or
        // on ISAs without a vector path).
        let scal = bench.run(&format!("matmul_f32_scalar {shape}"), Some(flops), || {
            kernels::matmul_f32_scalar(&a32, &b32, m, k, n, &mut o32);
            std::hint::black_box(o32[0]);
        });
        let mut simd_row = KernelRecord::from_stats(&f32s, &scal, &shape, flops);
        simd_row.kernel = format!("simd_vs_scalar matmul_f32 {shape}");
        records.push(simd_row);
    }

    // --- fused GAR forward vs two-matmul + copy across the rank sweep ------
    let (bsz, n, m) = (256usize, 256usize, 256usize);
    let x = Mat::randn(bsz, n, &mut rng);
    for r in [8usize, 16, 32, 64, 128, 192] {
        let gar = Gar {
            u_hat: Mat::randn(m - r, r, &mut rng),
            v_tilde: Mat::randn(n, r, &mut rng),
            rank: r,
        };
        let shape = format!("B={bsz} n={n} m={m} r={r}");
        // (n + m − r)·r MACs per row, 2 flops per MAC.
        let flops = (2 * bsz * (n + m - r) * r) as f64;

        let refstats = bench.run(&format!("gar_forward_ref r={r}"), Some(flops), || {
            std::hint::black_box(
                reference::gar_forward(&gar.u_hat, &gar.v_tilde, gar.rank, &x).data.len(),
            );
        });
        let fused = bench.run(&format!("gar_forward_fused r={r}"), Some(flops), || {
            std::hint::black_box(gar.forward(&x).data.len());
        });
        records.push(KernelRecord::from_stats(&fused, &refstats, &shape, flops));

        // Arena-backed zero-alloc variant.
        let mut arena = kernels::Arena::new();
        let warm = gar.forward_arena(&x, &mut arena);
        arena.give(warm);
        let fused_a = bench.run(&format!("gar_forward_arena r={r}"), Some(flops), || {
            let y = gar.forward_arena(&x, &mut arena);
            std::hint::black_box(y[0]);
            arena.give(y);
        });
        records.push(KernelRecord::from_stats(&fused_a, &refstats, &shape, flops));

        // f32 fused emit: dispatched vs scalar oracle (the serving path).
        let t32: Vec<f32> = (0..bsz * r).map(|_| rng.normal() as f32).collect();
        let uh32 = gar.u_hat.to_f32();
        let mut y32 = vec![0f32; bsz * m];
        let emit_flops = (2 * bsz * (m - r) * r) as f64;
        let emit = bench.run(&format!("gar_emit_f32 r={r}"), Some(emit_flops), || {
            kernels::gar_emit_f32(&t32, bsz, r, &uh32, m - r, &mut y32, m, 0);
            std::hint::black_box(y32[0]);
        });
        let emit_s = bench.run(&format!("gar_emit_f32_scalar r={r}"), Some(emit_flops), || {
            kernels::gar_emit_f32_scalar(&t32, bsz, r, &uh32, m - r, &mut y32, m, 0);
            std::hint::black_box(y32[0]);
        });
        let mut emit_row = KernelRecord::from_stats(&emit, &emit_s, &shape, emit_flops);
        emit_row.kernel = format!("simd_vs_scalar gar_emit_f32 r={r}");
        records.push(emit_row);
    }

    // --- quantized nested factors vs f32, one pair of rows per serve tier --
    // The serving registry stores one quantized factor set per tier; this
    // times the panel-dequantizing product x·Ṽ at each tier's uniform qkv
    // rank against the f32 kernel on identical data.
    {
        let cfg = flexrank::config::load_model_config("base").expect("configs/model_base.json");
        let (rows, n) = (cfg.batch_serve * cfg.seq_len, cfg.d_model);
        let xq: Vec<f32> = (0..rows * n).map(|_| rng.normal() as f32).collect();
        for (i, &budget) in cfg.serve_tiers.iter().enumerate() {
            let r = uniform_budget_profile(&cfg, budget)[0].max(1);
            let v32: Vec<f32> = (0..n * r).map(|_| rng.normal() as f32).collect();
            let mut yq = vec![0f32; rows * r];
            let flops = (2 * rows * n * r) as f64;
            let shape = format!("tier={i} {rows}x{n}x{r}");
            let base = bench.run(&format!("factor_matmul_f32 {shape}"), Some(flops), || {
                kernels::matmul_f32(&xq, &v32, rows, n, r, &mut yq);
                std::hint::black_box(yq[0]);
            });
            for prec in [Precision::Bf16, Precision::I8] {
                let q = QuantMat::from_f32(&v32, n, r, prec);
                let qs = bench.run(
                    &format!("factor_matmul_{} {shape}", prec.label()),
                    Some(flops),
                    || {
                        kernels::matmul_f32_q(&xq, &q, rows, n, r, &mut yq);
                        std::hint::black_box(yq[0]);
                    },
                );
                let mut qrow = KernelRecord::from_stats(&qs, &base, &shape, flops);
                qrow.kernel = format!("quantized_vs_f32 {} {shape}", prec.label());
                records.push(qrow);
            }
        }
    }

    // --- causal attention: streaming (flash) vs blocked vs sequential ------
    // The serving-shaped problem at model_base head sizes, then the same
    // problem at 4×/16×-longer sequences (batch scaled down to bound bench
    // time) — the regime the streaming tile exists for: the blocked path's
    // (t, t) score matrices fall out of cache while the streaming workspace
    // stays linear in t and skips the masked upper triangle entirely.
    //
    // Three rows per shape on the BENCH_kernels.json trajectory:
    //   attention_par_heads  — blocked head-parallel vs sequential-head
    //                          (slots=1) baseline, as since PR 4;
    //   attention_flash      — streaming vs the *blocked head-parallel*
    //                          baseline (speedup > 1 = flash wins);
    //   attention_flash_vs_seq — streaming vs the sequential-head baseline
    //                          (the end-to-end win of both optimizations).
    {
        let cfg = flexrank::config::load_model_config("base").expect("configs/model_base.json");
        let (d, heads) = (cfg.d_model, cfg.n_heads);
        let hd = d / heads;
        let tile = DEFAULT_ATTN_TILE;
        for (mult, batch) in [(1usize, cfg.batch_serve), (4, 2), (16, 1)] {
            let seq = cfg.seq_len * mult;
            let rows = batch * seq;
            let qkv: Vec<f32> = (0..rows * 3 * d).map(|_| rng.normal() as f32).collect();
            let mut att = vec![0f32; rows * d];
            let mut ws_seq = AttnWorkspace::new(seq, hd, 1);
            let mut ws_par = AttnWorkspace::new(seq, hd, AttnWorkspace::auto_slots(batch * heads));
            let mut ws_fla =
                AttnWorkspace::new_streaming(seq, hd, AttnWorkspace::auto_slots(batch * heads), tile);
            let shape = format!("B={batch} H={heads} T={seq} hd={hd}");
            // Per (batch, head) pair: QKᵀ + S·V, 2 flops per MAC each (full
            // (t, t) count, so GFLOP/s stays comparable across rows even
            // though the streaming path skips the masked half).
            let flops = (batch * heads * 4 * seq * seq * hd) as f64;

            let refstats = bench.run(&format!("attention_seq_heads {shape}"), Some(flops), || {
                causal_attention(&qkv, batch, seq, d, heads, &mut ws_seq, &mut att, None);
                std::hint::black_box(att[0]);
            });
            let par = bench.run(&format!("attention_par_heads {shape}"), Some(flops), || {
                causal_attention(&qkv, batch, seq, d, heads, &mut ws_par, &mut att, None);
                std::hint::black_box(att[0]);
            });
            records.push(KernelRecord::from_stats(&par, &refstats, &shape, flops));
            let fla = bench.run(&format!("attention_flash {shape}"), Some(flops), || {
                causal_attention(&qkv, batch, seq, d, heads, &mut ws_fla, &mut att, None);
                std::hint::black_box(att[0]);
            });
            records.push(KernelRecord::from_stats(&fla, &par, &shape, flops));
            // Same measurement, re-based on the sequential-head baseline.
            let mut vs_seq = KernelRecord::from_stats(&fla, &refstats, &shape, flops);
            vs_seq.kernel = format!("attention_flash_vs_seq {shape}");
            records.push(vs_seq);
        }
    }

    // --- paged single-query decode attention (the serving decode step) -----
    // One query row per live request, K/V gathered from the paged pool —
    // the kernel every generated token pays once per layer.  Reference is
    // the same single-query softmax over *contiguous* K/V in plain scalar
    // loops, so the row measures what the page-tiled SIMD online-softmax
    // step buys (and what page-gather indirection costs) at serving shapes:
    // the base context, then 4×/16× contexts where the pool no longer fits
    // in cache and the tile gather earns its keep.
    {
        use flexrank::runtime::attention::{paged_decode_attention, DecodeWorkspace};
        use flexrank::runtime::{PagedKvCache, DEFAULT_KV_PAGE_SIZE};
        let cfg = flexrank::config::load_model_config("base").expect("configs/model_base.json");
        let (d, heads) = (cfg.d_model, cfg.n_heads);
        let hd = d / heads;
        let page = DEFAULT_KV_PAGE_SIZE;
        for (mult, batch) in [(1usize, cfg.batch_serve), (4, cfg.batch_serve), (16, 4)] {
            let kv_len = cfg.seq_len * mult;
            // One layer of cache is all the kernel touches.
            let mut cache = PagedKvCache::new(page, 1, heads, hd, batch, kv_len, 0);
            let mut flat_k = vec![0f32; batch * kv_len * d];
            let mut flat_v = vec![0f32; batch * kv_len * d];
            let mut slots = Vec::with_capacity(batch);
            for b in 0..batch {
                let slot = cache.try_acquire(kv_len).expect("pool sized for every slot");
                for pos in 0..kv_len {
                    let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    cache.write_kv(slot, 0, pos, &k, &v);
                    flat_k[(b * kv_len + pos) * d..][..d].copy_from_slice(&k);
                    flat_v[(b * kv_len + pos) * d..][..d].copy_from_slice(&v);
                }
                cache.advance(slot, kv_len);
                slots.push(slot);
            }
            let qkv: Vec<f32> = (0..batch * 3 * d).map(|_| rng.normal() as f32).collect();
            let row_lens = vec![kv_len; batch];
            let mut ws =
                DecodeWorkspace::new(hd, page, AttnWorkspace::auto_slots(batch * heads));
            let mut att = vec![0f32; batch * d];
            let mut att_ref = vec![0f32; batch * d];
            let mut scores = vec![0f32; kv_len];
            let shape = format!("B={batch} H={heads} kv={kv_len} hd={hd}");
            // One query per request: q·Kᵀ + softmax·V over kv_len cached
            // rows, 2 flops per MAC each.
            let flops = (batch * heads * 4 * kv_len * hd) as f64;
            let scale = 1.0 / (hd as f32).sqrt();

            let refstats =
                bench.run(&format!("attention_decode_ref {shape}"), Some(flops), || {
                    for r in 0..batch {
                        for h in 0..heads {
                            let q = &qkv[r * 3 * d + h * hd..r * 3 * d + h * hd + hd];
                            let mut mx = f32::NEG_INFINITY;
                            for (t, s) in scores.iter_mut().enumerate() {
                                let kr = &flat_k[(r * kv_len + t) * d + h * hd..][..hd];
                                let mut acc = 0f32;
                                for j in 0..hd {
                                    acc += q[j] * kr[j];
                                }
                                *s = acc * scale;
                                mx = mx.max(*s);
                            }
                            let mut l = 0f32;
                            for s in scores.iter_mut() {
                                *s = (*s - mx).exp();
                                l += *s;
                            }
                            let inv = 1.0 / l;
                            let o = &mut att_ref[r * d + h * hd..][..hd];
                            o.fill(0.0);
                            for (t, s) in scores.iter().enumerate() {
                                let vr = &flat_v[(r * kv_len + t) * d + h * hd..][..hd];
                                let w = s * inv;
                                for j in 0..hd {
                                    o[j] += w * vr[j];
                                }
                            }
                        }
                    }
                    std::hint::black_box(att_ref[0]);
                });
            let paged = bench.run(&format!("attention_decode {shape}"), Some(flops), || {
                paged_decode_attention(
                    &cache, &qkv, &slots, &row_lens, 0, d, heads, &mut ws, &mut att,
                );
                std::hint::black_box(att[0]);
            });
            records.push(KernelRecord::from_stats(&paged, &refstats, &shape, flops));
        }
    }

    // --- covariance gram accumulation (DataSVD stage 1) --------------------
    {
        let x = Mat::randn(512, 128, &mut rng);
        let flops = (2 * 512 * 128 * 128) as f64;
        let refstats = bench.run("cov_accum_ref 512x128", Some(flops), || {
            let mut sigma = Mat::zeros(128, 128);
            for i in 0..x.rows {
                let row = x.row(i).to_vec();
                sigma.add_outer(1.0, &row, &row);
            }
            std::hint::black_box(sigma.data[0]);
        });
        let mut sigma = Mat::zeros(128, 128);
        let tn = bench.run("cov_accum_tn 512x128", Some(flops), || {
            kernels::matmul_tn_acc(&x, &x, &mut sigma);
            std::hint::black_box(sigma.data[0]);
        });
        records.push(KernelRecord::from_stats(&tn, &refstats, "512x128 gram", flops));
    }

    let dir = flexrank::results_dir();
    bench.write_csv(dir.join("bench_kernels.csv")).expect("csv");
    write_kernel_json(dir.join("BENCH_kernels.json"), &records).expect("json");
    println!("\nwrote {}", dir.join("BENCH_kernels.json").display());

    // Loud acceptance summary.
    for rec in &records {
        if rec.kernel.starts_with("matmul_f64 512x512x512") {
            println!(
                "matmul 512³ speedup vs reference: {:.2}x ({:.2} GFLOP/s)",
                rec.speedup_vs_reference, rec.gflops
            );
        }
    }
    for rec in &records {
        if rec.kernel.starts_with("simd_vs_scalar matmul_f32 512x128x384") {
            println!(
                "simd matmul_f32 vs scalar oracle at qkv shape [{}]: {:.2}x",
                simd::isa_label(),
                rec.speedup_vs_reference
            );
        }
    }
    for rec in &records {
        if rec.kernel.starts_with("quantized_vs_f32 ") {
            println!(
                "quantized factor matmul vs f32 [{}]: {:.2}x ({:.2} GFLOP/s)",
                rec.kernel.trim_start_matches("quantized_vs_f32 "),
                rec.speedup_vs_reference,
                rec.gflops
            );
        }
    }
    for rec in &records {
        if rec.kernel.starts_with("attention_par_heads") {
            let verdict = if rec.speedup_vs_reference >= 1.0 { "OK" } else { "WARNING: slower" };
            println!(
                "attention head-parallel vs sequential-head [{}]: {:.2}x ({:.2} GFLOP/s) — {verdict}",
                rec.shape, rec.speedup_vs_reference, rec.gflops
            );
        }
    }
    for rec in &records {
        if rec.kernel.starts_with("attention_flash ") {
            let verdict = if rec.speedup_vs_reference >= 1.0 {
                "OK"
            } else {
                "below blocked (memory win only at this shape)"
            };
            println!(
                "attention flash vs blocked [{}]: {:.2}x ({:.2} GFLOP/s) — {verdict}",
                rec.shape, rec.speedup_vs_reference, rec.gflops
            );
        }
    }
    for rec in &records {
        if rec.kernel.starts_with("attention_decode ") {
            let verdict = if rec.speedup_vs_reference >= 1.0 {
                "OK"
            } else {
                "WARNING: paged gather slower than contiguous scalar"
            };
            println!(
                "attention decode (paged) vs contiguous scalar [{}]: {:.2}x ({:.2} GFLOP/s) — {verdict}",
                rec.shape, rec.speedup_vs_reference, rec.gflops
            );
        }
    }
    let slow_gar: Vec<&KernelRecord> = records
        .iter()
        .filter(|r| r.kernel.starts_with("gar_forward_fused") && r.speedup_vs_reference <= 1.0)
        .collect();
    if slow_gar.is_empty() {
        println!("fused GAR forward faster than two-matmul reference at every benched rank");
    } else {
        println!("WARNING: fused GAR not faster at: {slow_gar:?}");
    }
}
