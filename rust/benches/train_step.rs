//! Bench: per-step latency of the training artifacts (dense pretrain step vs
//! fused KD consolidation step) and of the evaluation forwards — the L2/L1
//! numbers for EXPERIMENTS.md §Perf.

#[cfg(feature = "pjrt")]
use flexrank::bench_harness;
#[cfg(feature = "pjrt")]
use flexrank::runtime::{DType, Engine, Tensor};

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("train_step benches the AOT train-step artifacts; rebuild with --features pjrt");
    eprintln!("(the offline kernel numbers live in `cargo bench --bench kernels`)");
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let engine = Engine::new(flexrank::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let mut bench = bench_harness::from_env();
    let tokens_per_step = (cfg.batch_train * cfg.seq_len) as f64;

    for (name, elems) in [
        ("teacher_fwd", tokens_per_step),
        ("student_eval", tokens_per_step),
        ("serve_gar_t0", (cfg.batch_serve * cfg.seq_len) as f64),
        ("serve_gar_t3", (cfg.batch_serve * cfg.seq_len) as f64),
        ("teacher_train_step", tokens_per_step),
        ("kd_train_step", tokens_per_step),
    ] {
        let exe = engine.load(name)?;
        let inputs: Vec<Tensor> = exe
            .spec
            .inputs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => Tensor::f32(s.shape.clone(), vec![0.01; s.numel()]),
                DType::I32 => Tensor::i32(s.shape.clone(), vec![1; s.numel()]),
            })
            .collect();
        bench.run(name, Some(elems), || {
            exe.run(&inputs).expect("exec");
        });
    }

    // Device-resident variant of the KD step: how much does keeping the
    // teacher on device save vs full host-literal execution?
    let exe = engine.load("kd_train_step")?;
    let inputs: Vec<Tensor> = exe
        .spec
        .inputs
        .iter()
        .map(|s| match s.dtype {
            DType::F32 => Tensor::f32(s.shape.clone(), vec![0.01; s.numel()]),
            DType::I32 => Tensor::i32(s.shape.clone(), vec![1; s.numel()]),
        })
        .collect();
    let bufs = engine.to_device_all(&inputs)?;
    bench.run("kd_train_step (device-resident)", Some(tokens_per_step), || {
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|d| d.buffer()).collect();
        exe.run_b(&refs).expect("exec_b");
    });

    bench.write_csv(flexrank::results_dir().join("bench_train_step.csv"))?;
    Ok(())
}
