//! Bench: DP rank selection (Alg. 2) scaling in layers L and levels K —
//! validates the paper's O(L·K) probing + near-linear DP claim.

use flexrank::bench_harness;
use flexrank::flexrank::dp::{dp_rank_selection, Candidate};
use flexrank::rng::Rng;

fn candidates(l: usize, k: usize, seed: u64) -> Vec<Vec<Candidate>> {
    let mut rng = Rng::new(seed);
    (0..l)
        .map(|_| {
            let mut err = 0.0;
            let mut c = vec![Candidate { saving: 0, err: 0.0, rank: k }];
            for r in (1..k).rev() {
                err += rng.f64() * 0.1;
                c.push(Candidate { saving: 500 * (k - r) as u64, err, rank: r });
            }
            c.sort_by_key(|x| x.saving);
            c
        })
        .collect()
}

fn main() {
    let mut bench = bench_harness::from_env();
    for (l, k) in [(8usize, 8usize), (16, 8), (32, 8), (16, 16), (64, 16), (128, 16)] {
        let cands = candidates(l, k, 42);
        let full: u64 = cands.iter().flat_map(|c| c.iter().map(|x| x.saving)).sum::<u64>() + 1000;
        // Exact (quant=1) and bucketed (quant=64) variants.
        bench.run(&format!("dp L={l} K={k} exact"), Some((l * k) as f64), || {
            std::hint::black_box(dp_rank_selection(&cands, full, 1).unwrap());
        });
        bench.run(&format!("dp L={l} K={k} quant64"), Some((l * k) as f64), || {
            std::hint::black_box(dp_rank_selection(&cands, full, 64).unwrap());
        });
    }
    bench
        .write_csv(flexrank::results_dir().join("bench_dp_select.csv"))
        .expect("csv");
}
