//! Elastic serving coordinator — the L3 deployment layer of the paper's
//! "train-once, deploy-everywhere" story.
//!
//! A single consolidated parameter set yields one GAR submodel executable per
//! budget tier (`serve_gar_t{i}` artifacts); the coordinator routes incoming
//! requests to tiers by SLO policy, batches them dynamically (max-batch /
//! deadline), executes on the PJRT runtime, and reports latency/throughput
//! metrics per tier.
//!
//! Threading: an ingest thread replays the trace through an mpsc channel
//! (only `Request`s cross threads); the main loop owns the PJRT engine (the
//! `xla` crate's client wraps raw pointers and is not `Send`), pulls
//! requests, and drives the batcher — the same ownership layout a
//! single-device vLLM-style worker uses.

mod batcher;
mod metrics;
mod policy;
mod registry;
mod server;

pub use batcher::{DynamicBatcher, Pending};
pub use metrics::{LatencyStats, Metrics};
pub use policy::{Policy, PolicyKind};
pub use registry::SubmodelRegistry;
pub use server::{serve_trace, ServeCfg, ServeReport};

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::data::{TraceCfg, TraceGen};
use crate::runtime::Engine;

/// `repro serve [--requests N] [--rate R] [--policy static|adaptive]`
pub fn run_cli(args: &Args) -> Result<()> {
    let engine = Engine::new(crate::artifacts_dir()).context("engine init")?;
    let cfg = engine.manifest.config.clone();

    // Student params: prefer the consolidated pipeline checkpoint.
    let stem = crate::training::pipeline::stage_dir().join("student_kd");
    let student = if crate::training::ckpt::exists(&stem) {
        eprintln!("[serve] using consolidated student checkpoint");
        crate::training::ckpt::load(&stem)?
    } else {
        eprintln!("[serve] no checkpoint; decomposing fresh teacher (mechanics demo)");
        let teacher = crate::training::params::ParamSet::from_specs(
            &engine.manifest.teacher_init,
            engine.manifest.load_teacher_init()?,
        );
        let factors = crate::training::params::decompose_teacher(&cfg, &teacher, None)?;
        crate::training::params::student_from_factors(&cfg, &teacher, &factors)?
    };

    let corpus = crate::data::Corpus::generate(crate::training::CORPUS_BYTES, 5);
    let trace_cfg = TraceCfg {
        n_requests: args.usize_or("requests", 200)?,
        rate: args.f64_or("rate", 100.0)?,
        seq_len: cfg.seq_len,
        vocab: cfg.vocab,
        seed: args.u64_or("seed", 77)?,
        ..Default::default()
    };
    let trace = TraceGen::new(trace_cfg, &corpus.heldout).generate();

    let policy = match args.get_or("policy", "static") {
        "adaptive" => PolicyKind::Adaptive,
        _ => PolicyKind::Static,
    };
    let serve_cfg = ServeCfg {
        max_wait_ms: args.f64_or("max-wait-ms", 4.0)?,
        policy,
        ..Default::default()
    };
    let report = serve_trace(&engine, &student, trace, &serve_cfg)?;
    report.print();

    let path = crate::results_dir().join("serving_report.json");
    std::fs::write(&path, report.to_json())?;
    println!("report -> {}", path.display());
    Ok(())
}
