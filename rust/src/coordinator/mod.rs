//! Elastic serving coordinator — the L3 deployment layer of the paper's
//! "train-once, deploy-everywhere" story.
//!
//! A single consolidated parameter set yields one GAR submodel per budget
//! tier; the coordinator routes incoming requests to tiers by SLO policy,
//! batches them dynamically (max-batch / deadline), executes on the native
//! kernel backend ([`crate::runtime::native`]), and reports
//! latency/throughput metrics per tier.  No PJRT/XLA required — the PJRT
//! registry survives behind the `pjrt` feature.
//!
//! Threading: an ingest thread replays the trace through an mpsc channel
//! (only `Request`s cross threads); the main loop owns the backend and its
//! scratch arena, pulls requests, and drives the batcher — the same
//! ownership layout a single-device vLLM-style worker uses.  The kernels
//! and the blocked attention fan out over the persistent worker pool
//! (`linalg::pool`) inside each forward.

mod batcher;
mod controller;
mod listener;
mod metrics;
mod policy;
mod registry;
mod server;

pub use batcher::{DynamicBatcher, Pending};
pub use controller::{ElasticController, RouteDecision, TierRouter};
pub use listener::{tier_waits, ListenCfg, ListenReport, Listener, ShutdownHandle};
pub use metrics::{LatencyStats, Metrics};
pub use policy::{Policy, PolicyKind, PressureBand};
#[cfg(feature = "pjrt")]
pub use registry::{PjrtRegistry, PjrtServing};
pub use registry::{load_tier_profiles, SubmodelRegistry, Tier, TierProfiles};
pub use server::{
    ingest_bound, serve_trace, serve_trace_decode, DecodeReport, ServeCfg, ServeReport,
};

use anyhow::{ensure, Context, Result};

use crate::cli::Args;
use crate::data::{TraceCfg, TraceGen};
use crate::runtime::{ModelConfig, ServingBackend};
use crate::training::params::{
    decompose_teacher, random_teacher, student_from_factors, ParamSet,
};

/// Student params for serving: the consolidated pipeline checkpoint when
/// present, else a freshly decomposed random teacher (mechanics demo).
pub fn serving_student(cfg: &crate::runtime::ModelConfig, seed: u64) -> Result<ParamSet> {
    let stem = crate::training::stage_dir().join("student_kd");
    if crate::training::ckpt::exists(&stem) {
        let s = crate::training::ckpt::load(&stem)?;
        // A checkpoint from a different config would slice in-bounds but
        // serve garbage — treat it as stale, like a mismatched profiles.json.
        let shape_ok = s.get("tok_emb").map(|t| t.shape() == [cfg.vocab, cfg.d_model])
            .unwrap_or(false)
            && s.get("pos_emb").map(|t| t.shape() == [cfg.seq_len, cfg.d_model]).unwrap_or(false);
        if shape_ok {
            eprintln!("[serve] using consolidated student checkpoint");
            return Ok(s);
        }
        eprintln!(
            "[serve] student_kd checkpoint was written for a different config than '{}' — ignoring it",
            cfg.name
        );
    }
    eprintln!("[serve] no checkpoint; decomposing a fresh random teacher (mechanics demo)");
    let teacher = random_teacher(cfg, seed);
    let factors = decompose_teacher(cfg, &teacher, None)?;
    student_from_factors(cfg, &teacher, &factors)
}

/// `repro serve [--requests N] [--rate R] [--policy static|adaptive|elastic]
/// [--scenario steady|diurnal|bursty|adversarial] [--tenants] [--queue-cap N]
/// [--dwell-ms MS] [--deadline-ms MS] [--config base|tiny]
/// [--backend native|pjrt]`
///
/// Builds the requested [`ServingBackend`] and drives it through the
/// backend-agnostic serving stack — native kernels by default, the PJRT
/// registry when compiled with the `pjrt` feature.
pub fn run_cli(args: &Args) -> Result<()> {
    let cfg = crate::config::load_model_config(args.get_or("config", "base"))
        .context("model config")?;
    let seed = args.u64_or("seed", 77)?;
    let backend_name = args.get_or("backend", "native");

    #[cfg(feature = "pjrt")]
    if backend_name == "pjrt" {
        let engine = crate::runtime::Engine::new(crate::artifacts_dir()).context("engine init")?;
        let student = serving_student(&cfg, seed ^ 0x5eed)?;
        let registry = PjrtRegistry::load(&engine, &student).context("pjrt registry load")?;
        let mut backend = PjrtServing::new(engine, registry);
        return serve_cli_on(&mut backend, &cfg, args, seed);
    }
    ensure!(
        backend_name == "native",
        "unknown --backend '{backend_name}' (this build supports: native{})",
        if cfg!(feature = "pjrt") { ", pjrt" } else { "" }
    );

    let student = serving_student(&cfg, seed ^ 0x5eed)?;
    // DP-selected per-tier profiles when the pipeline has produced them
    // for this config *and* this student; uniform budget profiles otherwise.
    let profiles = load_tier_profiles(&cfg, &student)?;
    match &profiles {
        Some(p) => eprintln!(
            "[serve] using {} DP-selected tier profiles from profiles.json \
             (difficulty signal: per-tier calibration error)",
            p.profiles.len()
        ),
        None => eprintln!("[serve] no DP profiles; serving uniform budget ranks"),
    }
    let mut registry = SubmodelRegistry::load_native(&cfg, &student, profiles.as_ref())
        .context("registry load")?;
    serve_cli_on(&mut registry, &cfg, args, seed)
}

/// Trace generation + serve + report over any loaded backend.
///
/// `--mode window` (default) replays the one-shot padded-batch path;
/// `--mode decode` replays variable-length prompts with generation through
/// the continuous-batching prefill/decode seam; `--listen [addr]` skips
/// trace replay and serves real sockets through the listener front-end.
fn serve_cli_on<B: ServingBackend>(
    backend: &mut B,
    cfg: &ModelConfig,
    args: &Args,
    seed: u64,
) -> Result<()> {
    if let Some(addr) = args.get("listen") {
        return listen_cli_on(backend, cfg, args, addr);
    }
    let corpus = crate::data::Corpus::generate(crate::training::CORPUS_BYTES, 5);
    let mode = args.get_or("mode", "window");
    ensure!(
        mode == "window" || mode == "decode",
        "unknown --mode '{mode}' (window | decode)"
    );
    let decode = mode == "decode";
    let trace_cfg = TraceCfg {
        n_requests: args.usize_or("requests", 200)?,
        rate: args.f64_or("rate", 100.0)?,
        seq_len: cfg.seq_len,
        vocab: cfg.vocab,
        seed,
        // Decode replays a realistic length mix: short-to-full prompts,
        // generation clamped so prompt + gen fits the positional table.
        prompt_len_min: if decode { (cfg.seq_len / 8).max(1) } else { 0 },
        prompt_len_max: if decode { cfg.seq_len } else { 0 },
        gen_len_min: if decode { 1 } else { 0 },
        gen_len_max: if decode { (cfg.seq_len / 2).max(1) } else { 0 },
        // Arrival-shape scenario (steady|diurnal|bursty|adversarial) and
        // the optional multi-tenant budget mix.
        shape: crate::data::trace::ArrivalShape::parse(args.get_or("scenario", "steady"))?,
        tenants: if args.flag("tenants") {
            crate::data::trace::TenantCfg::default_mix()
        } else {
            Vec::new()
        },
        ..Default::default()
    };
    let trace = TraceGen::new(trace_cfg, &corpus.heldout)?.generate();

    let serve_cfg = ServeCfg {
        max_wait_ms: args.f64_or("max-wait-ms", 4.0)?,
        policy: PolicyKind::parse(args.get_or("policy", "static"))?,
        // 0 (default) = unbounded replay queue, legacy serve-everything
        // semantics; a positive cap sheds explicitly and anchors the
        // elastic controller's demote-before-shed band.  Flags override
        // the (parse-time-validated) config knobs.
        queue_cap: args.usize_or("queue-cap", cfg.serve_queue_cap)?,
        dwell_ms: args.f64_or("dwell-ms", cfg.serve_dwell_ms)?,
        deadline_ms: args.f64_or("deadline-ms", 0.0)?,
        pressure: cfg
            .serve_pressure_band()
            .map(|(hi, lo)| PressureBand::new(hi, lo))
            .transpose()?,
        ..Default::default()
    };

    if decode {
        let report = serve_trace_decode(backend, trace, &serve_cfg)?;
        report.print();
        let path = crate::results_dir().join("decode_report.json");
        std::fs::write(&path, report.to_json())?;
        println!("report -> {}", path.display());
        return Ok(());
    }

    let report = serve_trace(backend, trace, &serve_cfg)?;
    report.print();

    let path = crate::results_dir().join("serving_report.json");
    std::fs::write(&path, report.to_json())?;
    println!("report -> {}", path.display());
    Ok(())
}

/// `repro serve --listen [addr]` — the online front-end: accept real
/// sockets (framed protocol + HTTP POST fallback) and serve through the
/// decode seam until `--listen-secs` elapses (0 = until killed).
fn listen_cli_on<B: ServingBackend>(
    backend: &mut B,
    cfg: &ModelConfig,
    args: &Args,
    addr: &str,
) -> Result<()> {
    // A bare `--listen` parses as the value "true"; use the default addr.
    let addr = if addr == "true" { "127.0.0.1:7171" } else { addr };
    let lcfg = ListenCfg {
        serve: ServeCfg {
            max_wait_ms: args.f64_or("max-wait-ms", 4.0)?,
            policy: PolicyKind::parse(args.get_or("policy", "static"))?,
            dwell_ms: args.f64_or("dwell-ms", cfg.serve_dwell_ms)?,
            deadline_ms: args.f64_or("deadline-ms", 0.0)?,
            pressure: cfg
                .serve_pressure_band()
                .map(|(hi, lo)| PressureBand::new(hi, lo))
                .transpose()?,
            ..Default::default()
        },
        max_connections: args.usize_or("max-conns", 32)?,
        queue_cap: args.usize_or("queue-cap", 64)?,
        conn_pipeline: args.usize_or("conn-pipeline", 8)?,
    };
    let listener = Listener::bind(addr, lcfg)?;
    let bound = listener.local_addr()?;
    let handle = listener.shutdown_handle();
    let secs = args.f64_or("listen-secs", 0.0)?;
    eprintln!(
        "[serve] listening on {bound} (framed protocol + HTTP POST){}",
        if secs > 0.0 { format!(", stopping after {secs}s") } else { String::new() }
    );
    if secs > 0.0 {
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
            handle.shutdown();
        });
    }
    let report = listener.run(backend)?;
    report.print();
    let path = crate::results_dir().join("listen_report.json");
    std::fs::write(&path, report.to_json())?;
    println!("report -> {}", path.display());
    Ok(())
}
