//! Online serving front-end: a blocking TCP listener in front of the
//! [`ServingBackend`] decode seam.
//!
//! Two wire protocols share one ingest path (see [`crate::data::trace::wire`]
//! for the frame layout):
//!
//! * **framed** — length-prefixed binary request/response frames; a
//!   connection may pipeline up to `conn_pipeline` requests and receives
//!   id-tagged responses, possibly out of submission order;
//! * **HTTP/1.1** — a `POST` with a JSON body, one request per connection
//!   (`curl`-able fallback); the body goes through the pull parser, never
//!   the tree builder.
//!
//! The ingest contract is the one `serve_trace_decode` enforces: budget in
//! (0, 1], non-empty prompt, prompt + gen_len within the positional table —
//! checked connection-side so a bad request answers `Error` without ever
//! touching the batcher.  Between `read()` and `batcher.push(…)` a framed
//! request performs **zero heap allocations**: frames decode into a reused
//! [`wire::RequestSlot`], and the token buffer hand-off swaps ownership
//! with a recycled buffer from a fixed per-connection pool
//! ([`wire::RequestSlot::take_request`]).  Buffer identity is watched
//! per-connection and surfaces as [`ListenReport::ingest_fingerprint_drift`]
//! (0 = the invariant held); the allocator-counted proof lives in
//! `tests/fuzz_ingest.rs`.
//!
//! Overload: admission is bounded by `queue_cap` in-flight requests across
//! all connections — past it a request is refused with an explicit `Shed`
//! response (HTTP 503) instead of queueing without bound.  Shutdown
//! ([`ShutdownHandle::shutdown`]) stops accepting and reading, then drains:
//! queued requests still admit oldest-head-first (the batcher's one
//! fairness rule), every in-flight request generates to completion, every
//! reply flushes before the connection closes.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::data::trace::wire::{self, Status};
use crate::data::trace::Request;
// lint: allow(json_value) -- response/stats side only: the ingest path decodes through the wire pull parser; Value builds the metrics snapshot and the HTTP fallback bodies.
use crate::json::{self, Value};
use crate::runtime::ServingBackend;

use super::batcher::DynamicBatcher;
use super::controller::TierRouter;
use super::metrics::LatencyStats;
use super::policy::PressureBand;
use super::server::{backend_tier_errors, ServeCfg};

/// Listener configuration on top of the serving knobs.
#[derive(Debug, Clone)]
pub struct ListenCfg {
    pub serve: ServeCfg,
    /// Concurrent connections; one past this is refused with a shed frame.
    pub max_connections: usize,
    /// In-flight request bound across all connections (admission + decode);
    /// past it new requests shed.  Also sizes the ingest channel.
    pub queue_cap: usize,
    /// Pipelined requests one framed connection may keep outstanding; also
    /// the size of its recycled token-buffer pool.
    pub conn_pipeline: usize,
}

impl Default for ListenCfg {
    fn default() -> Self {
        ListenCfg {
            serve: ServeCfg::default(),
            max_connections: 32,
            queue_cap: 64,
            conn_pipeline: 8,
        }
    }
}

/// Per-tier batch deadlines from one base wait: tier 0 (interactive SLO)
/// flushes tightest, the top (quality) tier gets the full base — queued
/// interactive heads overtake older lenient-tier heads once expired.
pub fn tier_waits(base: Duration, n_tiers: usize) -> Vec<Duration> {
    (0..n_tiers)
        .map(|t| base.mul_f64((t + 1) as f64 / n_tiers.max(1) as f64))
        .collect()
}

/// Counters shared between the accept loop, connection handlers, and the
/// serving loop.
struct Shared {
    shutdown: AtomicBool,
    /// Admitted, not-yet-replied requests (the shed bound).
    inflight: AtomicUsize,
    conns: AtomicUsize,
    accepted: AtomicUsize,
    rejected: AtomicUsize,
    shed: AtomicUsize,
    conn_errors: AtomicUsize,
    /// Times a connection's request-slot buffer changed identity (must
    /// stay 0 — the zero-alloc ingest invariant).
    fingerprint_drift: AtomicUsize,
}

impl Shared {
    fn new() -> Self {
        Shared {
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            conns: AtomicUsize::new(0),
            accepted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            conn_errors: AtomicUsize::new(0),
            fingerprint_drift: AtomicUsize::new(0),
        }
    }

    fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }
}

/// Clonable remote-control handle for a running [`Listener`].
#[derive(Clone)]
pub struct ShutdownHandle {
    shared: Arc<Shared>,
}

impl ShutdownHandle {
    /// Begin graceful drain: stop accepting/reading, finish everything
    /// already admitted or queued, flush replies, then return from `run`.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
    }
}

/// A request handed from a connection to the serving loop, carrying the
/// channel its reply goes back on.
struct IngestItem {
    req: Request,
    reply: mpsc::Sender<Reply>,
}

/// A finished request on its way back to the connection writer.  `tokens`
/// is the request's own buffer (now holding the generated tokens) — the
/// writer recycles it into the connection pool after encoding.
struct Reply {
    id: u64,
    status: Status,
    tokens: Vec<i32>,
}

/// Final report of a listener run.
pub struct ListenReport {
    pub accepted_conns: usize,
    pub rejected_conns: usize,
    pub requests_done: usize,
    pub shed: usize,
    pub conn_errors: usize,
    /// Must be 0: per-connection ingest buffers never changed identity.
    pub ingest_fingerprint_drift: usize,
    pub steps: usize,
    pub tokens_prefilled: usize,
    pub tokens_generated: usize,
    pub wall_s: f64,
    /// End-to-end latency samples (ms), enqueue → reply handed off.
    pub latency_ms: Vec<f64>,
    pub tier_requests: Vec<usize>,
    /// Requests served below the tier their SLO/difficulty asked for —
    /// the elastic controller's demote-before-shed work.
    pub demotions: usize,
    /// Elastic controller level changes over the run (0 for static/adaptive).
    pub tier_switches: u64,
}

impl ListenReport {
    pub fn request_latency(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.latency_ms)
    }

    pub fn print(&self) {
        println!("== listener report ==");
        println!(
            "conns {} (+{} refused)  requests {}  shed {}  conn-errors {}  \
             steps {}  prefill {} tok  generated {} tok  wall {:.2}s",
            self.accepted_conns,
            self.rejected_conns,
            self.requests_done,
            self.shed,
            self.conn_errors,
            self.steps,
            self.tokens_prefilled,
            self.tokens_generated,
            self.wall_s
        );
        let l = self.request_latency();
        println!(
            "request latency p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms  \
             fingerprint drift {}",
            l.p50_ms, l.p95_ms, l.p99_ms, self.ingest_fingerprint_drift
        );
        println!(
            "routing: demotions {}  tier switches {}",
            self.demotions, self.tier_switches
        );
        for (i, &n) in self.tier_requests.iter().enumerate() {
            println!("tier {i}: {n} reqs");
        }
    }

    pub fn to_json(&self) -> String {
        let l = self.request_latency();
        // lint: allow(hot_path) -- metrics snapshot, off the serving path.
        json::to_string(&json::obj(vec![
            ("accepted_conns", Value::Num(self.accepted_conns as f64)),
            ("rejected_conns", Value::Num(self.rejected_conns as f64)),
            ("requests", Value::Num(self.requests_done as f64)),
            ("shed", Value::Num(self.shed as f64)),
            ("conn_errors", Value::Num(self.conn_errors as f64)),
            (
                "ingest_fingerprint_drift",
                Value::Num(self.ingest_fingerprint_drift as f64),
            ),
            ("steps", Value::Num(self.steps as f64)),
            ("tokens_prefilled", Value::Num(self.tokens_prefilled as f64)),
            ("tokens_generated", Value::Num(self.tokens_generated as f64)),
            ("wall_s", json::finite_num(self.wall_s)),
            ("latency_p50_ms", json::finite_num(l.p50_ms)),
            ("latency_p95_ms", json::finite_num(l.p95_ms)),
            ("latency_p99_ms", json::finite_num(l.p99_ms)),
            ("demotions", Value::Num(self.demotions as f64)),
            ("tier_switches", Value::Num(self.tier_switches as f64)),
            (
                "tier_requests",
                Value::Arr(
                    self.tier_requests.iter().map(|&n| Value::Num(n as f64)).collect(),
                ),
            ),
        ]))
    }
}

/// The bound socket plus everything `run` needs.  Binding is separate from
/// running so callers can learn the ephemeral port and take a
/// [`ShutdownHandle`] before the (blocking) serving loop starts.
pub struct Listener {
    socket: TcpListener,
    cfg: ListenCfg,
    shared: Arc<Shared>,
}

impl Listener {
    pub fn bind(addr: &str, cfg: ListenCfg) -> Result<Listener> {
        ensure!(cfg.max_connections >= 1, "max_connections must be >= 1");
        ensure!(cfg.queue_cap >= 1, "queue_cap must be >= 1");
        ensure!(cfg.conn_pipeline >= 1, "conn_pipeline must be >= 1");
        // lint: allow(hot_path) -- bind-time error context, runs once.
        let socket = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Listener { socket, cfg, shared: Arc::new(Shared::new()) })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.socket.local_addr()?)
    }

    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { shared: Arc::clone(&self.shared) }
    }

    /// Accept and serve until [`ShutdownHandle::shutdown`], then drain.
    /// Runs the serving loop on the calling thread (it owns the backend);
    /// accepting and per-connection I/O run on their own threads.
    pub fn run<B: ServingBackend + ?Sized>(self, backend: &mut B) -> Result<ListenReport> {
        ensure!(
            backend.supports_decode() && backend.decode_slots() > 0,
            "the listener serves through the incremental decode seam; \
             this backend has none"
        );
        let n_tiers = backend.n_tiers();
        let seq = backend.seq_len();
        // The listener's admission bound is its own `queue_cap`, so unless
        // an explicit band override is set, the demote-before-shed band is
        // anchored to *that* cap — demotion pressure always engages below
        // the depth at which `try_admit` starts answering Shed.
        let band = match self.cfg.serve.pressure {
            Some(b) => b,
            None => PressureBand::from_queue_cap(self.cfg.queue_cap),
        };
        let tier_errors = backend_tier_errors(backend);
        let mut router = TierRouter::new(
            self.cfg.serve.policy,
            n_tiers,
            band,
            Duration::from_secs_f64(self.cfg.serve.dwell_ms.max(0.0) / 1e3),
            self.cfg.serve.deadline_ms,
            &tier_errors,
        )?;
        let base = Duration::from_secs_f64(self.cfg.serve.max_wait_ms / 1e3);
        let mut batcher =
            DynamicBatcher::with_tier_waits(backend.batch(), tier_waits(base, n_tiers));

        // Admission bound == channel bound: `try_admit` gates every send,
        // so the channel can never hold more than `queue_cap` items and a
        // handler's `send` never blocks the connection.
        let (tx, rx) = mpsc::sync_channel::<IngestItem>(self.cfg.queue_cap);
        let shared = Arc::clone(&self.shared);
        let accept = {
            let socket = self.socket;
            let shared = Arc::clone(&shared);
            let cfg = self.cfg.clone();
            std::thread::spawn(move || accept_loop(socket, shared, tx, cfg, seq))
        };

        /// One admitted, still-generating request in the serving loop.
        struct Active {
            tier: usize,
            slot: usize,
            id: u64,
            tag: usize,
            last: i32,
            remaining: usize,
            /// The request's own token buffer, now accumulating generated
            /// tokens; travels back to the connection inside the reply.
            gen: Vec<i32>,
            enqueued: Instant,
        }

        // Reply channels live in a slab indexed by the batcher tag — no
        // per-request map insertions on the ingest path.
        // lint: allow(hot_path) -- serving-loop startup; the slab grows to steady state then stops allocating.
        let mut slab: Vec<Option<mpsc::Sender<Reply>>> = Vec::new();
        // lint: allow(hot_path) -- serving-loop startup (free-list companion of the slab).
        let mut free: Vec<usize> = Vec::new();
        let mut active: Vec<Active> = Vec::with_capacity(backend.decode_slots());
        let mut step_slots: Vec<usize> = Vec::with_capacity(backend.decode_slots());
        let mut step_tokens: Vec<i32> = Vec::with_capacity(backend.decode_slots());
        // lint: allow(hot_path) -- per-tier counters sized once at loop startup.
        let mut tier_requests = vec![0usize; n_tiers];
        // lint: allow(hot_path) -- latency samples; serving-loop bookkeeping, amortized.
        let mut latency_ms: Vec<f64> = Vec::new();
        let mut demotions = 0usize;
        let (mut requests_done, mut steps) = (0usize, 0usize);
        let (mut tokens_prefilled, mut tokens_generated) = (0usize, 0usize);

        // Retire a request: hand the reply to its connection, free the
        // slab entry, release the admission token.
        let finish = |slab: &mut Vec<Option<mpsc::Sender<Reply>>>,
                      free: &mut Vec<usize>,
                      tag: usize,
                      reply: Reply| {
            if let Some(entry) = slab.get_mut(tag) {
                if let Some(reply_tx) = entry.take() {
                    // A send error means the connection died; the request
                    // still completed — drop the reply, keep serving.
                    let _ = reply_tx.send(reply);
                }
            }
            free.push(tag);
            shared.inflight.fetch_sub(1, Ordering::Relaxed);
        };

        let start = Instant::now();
        let mut open = true;
        while open || batcher.depth() > 0 || !active.is_empty() {
            // Drain arrivals into the batcher.
            loop {
                match rx.try_recv() {
                    Ok(item) => {
                        let now = Instant::now();
                        let d = router.route(&item.req, batcher.depth(), now);
                        let tag = match free.pop() {
                            Some(i) => {
                                slab[i] = Some(item.reply);
                                i
                            }
                            None => {
                                slab.push(Some(item.reply));
                                slab.len() - 1
                            }
                        };
                        tier_requests[d.served] += 1;
                        if d.served < d.requested {
                            demotions += 1;
                        }
                        batcher.push_tagged(d.served, item.req, now, tag as u64);
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            // The controller watches the post-drain depth every loop pass,
            // so pressure is observed even when no request arrives (drain
            // phases recover the level once the queue empties).
            router.observe(Instant::now(), batcher.depth());

            // Admission between decode steps: deadline-expired tiers first
            // (per-tier SLO waits), otherwise the oldest queue head — the
            // same rule the shutdown drain keeps, so drain order is just
            // steady-state order with no new arrivals.
            loop {
                let now = Instant::now();
                let Some(tier) =
                    batcher.ready_tier(now).or_else(|| batcher.oldest_head_tier())
                else {
                    break;
                };
                let need = match batcher.peek_head(tier) {
                    Some(p) => p.req.total_tokens(),
                    None => break,
                };
                let Some(slot) = backend.acquire_slot(need) else { break };
                // The head can only vanish if the queue was drained between
                // peek and pop (a bookkeeping bug); give the slot back and
                // stop admitting rather than panic the serving loop.
                let Some(p) = batcher.pop_head(tier) else {
                    backend.release_slot(slot);
                    break;
                };
                let tag = p.tag as usize;
                let first = match backend.prefill(tier, slot, &p.req.tokens) {
                    Ok(logits) => {
                        let vocab = logits.len() / p.req.tokens.len();
                        argmax(&logits[(p.req.tokens.len() - 1) * vocab..])
                    }
                    Err(e) => {
                        // Per-request failure: answer Error, keep serving.
                        backend.release_slot(slot);
                        eprintln!(
                            "[listen] prefill failed for request {}: {e:#}",
                            p.req.id
                        );
                        shared.conn_errors.fetch_add(1, Ordering::Relaxed);
                        finish(
                            &mut slab,
                            &mut free,
                            tag,
                            // lint: allow(hot_path) -- error reply carries no tokens; an empty Vec never allocates.
                            Reply { id: p.req.id, status: Status::Error, tokens: Vec::new() },
                        );
                        continue;
                    }
                };
                tokens_prefilled += p.req.tokens.len();
                let super::batcher::Pending { req, enqueued, .. } = p;
                let Request { id, gen_len, tokens: mut gen, .. } = req;
                gen.clear();
                if gen_len >= 1 {
                    gen.push(first);
                    tokens_generated += 1;
                }
                if gen_len <= 1 {
                    backend.release_slot(slot);
                    let ms = enqueued.elapsed().as_secs_f64() * 1e3;
                    latency_ms.push(ms);
                    router.observe_latency(ms);
                    requests_done += 1;
                    finish(
                        &mut slab,
                        &mut free,
                        tag,
                        Reply { id, status: Status::Ok, tokens: gen },
                    );
                    continue;
                }
                active.push(Active {
                    tier,
                    slot,
                    id,
                    tag,
                    last: first,
                    remaining: gen_len - 1,
                    gen,
                    enqueued,
                });
            }

            if active.is_empty() {
                if open || batcher.depth() > 0 {
                    let wait = batcher
                        .next_deadline(Instant::now())
                        .unwrap_or(Duration::from_millis(1))
                        .min(Duration::from_millis(2));
                    std::thread::sleep(wait.max(Duration::from_micros(100)));
                }
                continue;
            }

            // One decode step per tier group.
            for tier in 0..n_tiers {
                step_slots.clear();
                step_tokens.clear();
                for a in active.iter().filter(|a| a.tier == tier) {
                    step_slots.push(a.slot);
                    step_tokens.push(a.last);
                }
                if step_slots.is_empty() {
                    continue;
                }
                let n_rows = step_slots.len();
                {
                    let logits = backend.decode_step(tier, &step_slots, &step_tokens)?;
                    let vocab = logits.len() / n_rows;
                    step_tokens.clear();
                    for r in 0..n_rows {
                        step_tokens.push(argmax(&logits[r * vocab..(r + 1) * vocab]));
                    }
                }
                steps += 1;
                let mut r = 0;
                for a in active.iter_mut().filter(|a| a.tier == tier) {
                    a.last = step_tokens[r];
                    a.gen.push(step_tokens[r]);
                    a.remaining -= 1;
                    tokens_generated += 1;
                    r += 1;
                }
            }

            // Retire finished requests.
            let mut i = 0;
            while i < active.len() {
                if active[i].remaining == 0 {
                    let a = active.swap_remove(i);
                    backend.release_slot(a.slot);
                    let ms = a.enqueued.elapsed().as_secs_f64() * 1e3;
                    latency_ms.push(ms);
                    router.observe_latency(ms);
                    requests_done += 1;
                    finish(
                        &mut slab,
                        &mut free,
                        a.tag,
                        Reply { id: a.id, status: Status::Ok, tokens: a.gen },
                    );
                } else {
                    i += 1;
                }
            }
        }
        let wall_s = start.elapsed().as_secs_f64();
        accept.join().ok();

        Ok(ListenReport {
            accepted_conns: shared.accepted.load(Ordering::Relaxed),
            rejected_conns: shared.rejected.load(Ordering::Relaxed),
            requests_done,
            shed: shared.shed.load(Ordering::Relaxed),
            conn_errors: shared.conn_errors.load(Ordering::Relaxed),
            ingest_fingerprint_drift: shared.fingerprint_drift.load(Ordering::Relaxed),
            steps,
            tokens_prefilled,
            tokens_generated,
            wall_s,
            latency_ms,
            tier_requests,
            demotions,
            tier_switches: router.tier_switches(),
        })
    }
}

/// Greedy (deterministic) token choice from one logits row — the same rule
/// `serve_trace_decode` uses, so listener responses are bit-identical to an
/// in-process replay.
fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Strict admission: claim one of `cap` in-flight tokens, or refuse.  CAS
/// loop so concurrent connections can't overshoot the bound.
fn try_admit(shared: &Shared, cap: usize) -> bool {
    let mut cur = shared.inflight.load(Ordering::Relaxed);
    loop {
        if cur >= cap {
            return false;
        }
        match shared.inflight.compare_exchange(
            cur,
            cur + 1,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

/// The ingest contract `serve_trace_decode` enforces, applied
/// connection-side so violations answer `Error` without touching the
/// batcher (a bad request must never abort the serving loop).
fn validate_contract(slot: &wire::RequestSlot, seq: usize) -> Result<()> {
    if let Some(b) = slot.budget {
        ensure!(
            b.is_finite() && b > 0.0 && b <= 1.0,
            "request {} carries budget {b} outside the (0, 1] contract",
            slot.id
        );
    }
    ensure!(!slot.tokens.is_empty(), "request {} carries an empty prompt", slot.id);
    ensure!(
        slot.tokens.len() + slot.gen_len <= seq,
        "request {} needs {} tokens (prompt {} + gen {}) but the positional \
         table holds {seq}",
        slot.id,
        slot.tokens.len() + slot.gen_len,
        slot.tokens.len(),
        slot.gen_len
    );
    Ok(())
}

fn accept_loop(
    socket: TcpListener,
    shared: Arc<Shared>,
    tx: mpsc::SyncSender<IngestItem>,
    cfg: ListenCfg,
    seq: usize,
) {
    if let Err(e) = socket.set_nonblocking(true) {
        eprintln!("[listen] cannot poll the accept socket: {e}");
        shared.shutdown.store(true, Ordering::Relaxed);
        return;
    }
    // lint: allow(hot_path) -- accept-loop startup; one handle per connection thread.
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shared.is_shutdown() {
        match socket.accept() {
            Ok((stream, peer)) => {
                shared.accepted.fetch_add(1, Ordering::Relaxed);
                if shared.conns.load(Ordering::Relaxed) >= cfg.max_connections {
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    refuse(stream);
                    continue;
                }
                shared.conns.fetch_add(1, Ordering::Relaxed);
                let shared = Arc::clone(&shared);
                let tx = tx.clone();
                let (queue_cap, pipeline) = (cfg.queue_cap, cfg.conn_pipeline);
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &shared, &tx, seq, queue_cap, pipeline)
                    {
                        // Loud per-connection error; the accept loop and
                        // every other connection keep going.
                        eprintln!("[listen] connection {peer}: {e:#}");
                        shared.conn_errors.fetch_add(1, Ordering::Relaxed);
                    }
                    shared.conns.fetch_sub(1, Ordering::Relaxed);
                }));
                handles.retain(|h| !h.is_finished());
            }
            Err(e) if would_block(&e) => {
                handles.retain(|h| !h.is_finished());
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("[listen] accept error: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    for h in handles {
        h.join().ok();
    }
    // `tx` drops here: once every handler clone is gone too, the serving
    // loop sees the channel disconnect and finishes its drain.
}

/// Best-effort shed answer for a connection refused at the accept gate
/// (protocol unknown at this point, so it gets a shed frame).
fn refuse(mut stream: TcpStream) {
    // lint: allow(hot_path) -- refusal path for a connection being dropped, off the serving path.
    let mut out = Vec::new();
    wire::encode_response(&mut out, 0, Status::Shed, &[]);
    let _ = stream.write_all(&out);
}

fn handle_conn(
    mut stream: TcpStream,
    shared: &Shared,
    tx: &mpsc::SyncSender<IngestItem>,
    seq: usize,
    queue_cap: usize,
    pipeline: usize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .context("setting the read timeout")?;
    // First byte picks the protocol: the framed magic, or HTTP.
    let mut first = [0u8; 1];
    loop {
        match stream.peek(&mut first) {
            Ok(0) => return Ok(()), // closed without sending anything
            Ok(_) => break,
            Err(e) if would_block(&e) => {
                if shared.is_shutdown() {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    if first[0] == wire::REQ_MAGIC {
        handle_framed(stream, shared, tx, seq, queue_cap, pipeline)
    } else {
        handle_http(stream, shared, tx, seq, queue_cap)
    }
}

/// Like `wire::read_frame`, but over a socket with a read timeout so the
/// handler notices shutdown: a timeout before any header byte is a quiesce
/// point (and exits cleanly on shutdown); a timeout mid-frame keeps waiting
/// for the slow client unless shutdown cuts it off.
fn read_frame_polled(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    max_payload: usize,
    shared: &Shared,
) -> Result<Option<u8>> {
    let mut header = [0u8; wire::HEADER_LEN];
    let mut got = 0usize;
    while got < wire::HEADER_LEN {
        match stream.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                bail!("truncated frame: EOF after {got} header bytes");
            }
            Ok(n) => got += n,
            Err(e) if would_block(&e) => {
                if shared.is_shutdown() {
                    if got == 0 {
                        return Ok(None);
                    }
                    bail!("shutdown mid-frame");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    ensure!(
        header[0] == wire::REQ_MAGIC,
        "bad frame magic 0x{:02x} (not a framed-protocol stream)",
        header[0]
    );
    ensure!(header[1] == wire::VERSION, "unsupported frame version {}", header[1]);
    let len =
        u32::from_le_bytes([header[2], header[3], header[4], header[5]]) as usize;
    ensure!(
        len <= max_payload,
        "frame length prefix {len} exceeds the {max_payload}-byte limit"
    );
    buf.clear();
    buf.resize(len, 0); // within the reserved capacity — no allocation
    let mut at = 0usize;
    while at < len {
        match stream.read(&mut buf[at..]) {
            Ok(0) => bail!("truncated frame: EOF {at}/{len} payload bytes in"),
            Ok(n) => at += n,
            Err(e) if would_block(&e) => {
                if shared.is_shutdown() {
                    bail!("shutdown mid-frame");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(header[0]))
}

/// Write one token-free response frame directly (shed / error answers the
/// reader issues itself).  The write half is mutex-shared with the
/// connection's writer thread so frames never interleave.
fn respond_now(
    write_half: &Mutex<TcpStream>,
    out: &mut Vec<u8>,
    id: u64,
    status: Status,
) -> Result<()> {
    out.clear();
    wire::encode_response(out, id, status, &[]);
    let mut s = write_half.lock().unwrap_or_else(|p| p.into_inner());
    s.write_all(out)?;
    Ok(())
}

/// Framed-protocol connection: pipelined requests, id-tagged responses.
fn handle_framed(
    mut stream: TcpStream,
    shared: &Shared,
    tx: &mpsc::SyncSender<IngestItem>,
    seq: usize,
    queue_cap: usize,
    pipeline: usize,
) -> Result<()> {
    let write_half = Arc::new(Mutex::new(stream.try_clone().context("cloning the socket")?));
    // Serving replies for this connection (unbounded, but never holds more
    // than `pipeline` replies — each Ok reply carries a pool buffer).
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    // The fixed token-buffer pool: `pipeline` buffers cycle request →
    // reply → writer → back here.  Waiting on `recv` when the pool is
    // empty is the connection's pipelining backpressure.
    let (pool_tx, pool_rx) = mpsc::sync_channel::<Vec<i32>>(pipeline);
    for _ in 0..pipeline {
        // The receiver is local and alive, so the only way this fails is a
        // closed channel — report it instead of panicking the handler.
        if pool_tx.send(Vec::with_capacity(seq)).is_err() {
            bail!("connection buffer pool closed before startup");
        }
    }

    let writer = {
        let write_half = Arc::clone(&write_half);
        let pool_tx = pool_tx.clone();
        std::thread::spawn(move || writer_loop(reply_rx, write_half, pool_tx))
    };

    let max_payload = wire::REQ_FIXED + 4 * seq;
    let mut payload: Vec<u8> = Vec::with_capacity(max_payload);
    let mut out: Vec<u8> = Vec::with_capacity(wire::HEADER_LEN + 16);
    let mut slot = wire::RequestSlot::with_capacity(seq);
    let mut fingerprint: Option<(usize, usize)> = None;

    let result = (|| -> Result<()> {
        loop {
            if read_frame_polled(&mut stream, &mut payload, max_payload, shared)?.is_none() {
                return Ok(()); // clean EOF, or shutdown quiesce
            }
            if let Err(e) = wire::decode_request(&payload, seq, &mut slot) {
                // A malformed frame poisons the stream (framing is lost) —
                // answer and drop the connection loudly.
                let _ = respond_now(&write_half, &mut out, slot.id, Status::Error);
                bail!("malformed request frame: {e}");
            }
            match fingerprint {
                None => fingerprint = Some(slot.fingerprint()),
                Some(fp) if fp != slot.fingerprint() => {
                    shared.fingerprint_drift.fetch_add(1, Ordering::Relaxed);
                    fingerprint = Some(slot.fingerprint());
                }
                Some(_) => {}
            }
            if let Err(e) = validate_contract(&slot, seq) {
                // Well-framed but out of contract: per-request error, the
                // connection (and its other pipelined requests) live on.
                eprintln!("[listen] rejected request: {e:#}");
                respond_now(&write_half, &mut out, slot.id, Status::Error)?;
                continue;
            }
            // A recycled buffer (blocks at `pipeline` outstanding).
            let replacement = loop {
                match pool_rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(v) => break v,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if shared.is_shutdown() {
                            shared.shed.fetch_add(1, Ordering::Relaxed);
                            respond_now(&write_half, &mut out, slot.id, Status::Shed)?;
                            return Ok(());
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        bail!("connection writer exited early")
                    }
                }
            };
            if shared.is_shutdown() || !try_admit(shared, queue_cap) {
                // Draining, or the global in-flight bound is saturated:
                // explicit shed, never unbounded queueing.
                shared.shed.fetch_add(1, Ordering::Relaxed);
                let _ = pool_tx.send(replacement);
                respond_now(&write_half, &mut out, slot.id, Status::Shed)?;
                if shared.is_shutdown() {
                    return Ok(());
                }
                continue;
            }
            // Zero allocations since `read()`: the slot's buffer moves into
            // the Request, the recycled one takes its place.
            let req = slot.take_request(0.0, replacement);
            if tx.send(IngestItem { req, reply: reply_tx.clone() }).is_err() {
                shared.inflight.fetch_sub(1, Ordering::Relaxed);
                bail!("serving loop closed the ingest channel");
            }
        }
    })();
    // Let the writer drain every in-flight reply, then exit: it ends when
    // the last reply sender (ours here, the serving loop's per request)
    // drops.
    drop(reply_tx);
    drop(pool_rx);
    writer.join().ok();
    result
}

/// Connection writer: encodes serving replies, recycles token buffers.
fn writer_loop(
    reply_rx: mpsc::Receiver<Reply>,
    write_half: Arc<Mutex<TcpStream>>,
    pool_tx: mpsc::SyncSender<Vec<i32>>,
) {
    // lint: allow(hot_path) -- per-connection writer scratch, reused across every reply.
    let mut out: Vec<u8> = Vec::new();
    while let Ok(mut reply) = reply_rx.recv() {
        out.clear();
        wire::encode_response(&mut out, reply.id, reply.status, &reply.tokens);
        {
            let mut s = write_half.lock().unwrap_or_else(|p| p.into_inner());
            // A dead client can't cancel completed work; keep draining so
            // buffers still recycle and the reader can finish cleanly.
            let _ = s.write_all(&out);
        }
        if reply.tokens.capacity() > 0 {
            reply.tokens.clear();
            // Reader gone (pool receiver dropped) is fine — keep draining.
            let _ = pool_tx.send(reply.tokens);
        }
    }
}

/// HTTP/1.1 fallback: one `POST` with a JSON body per connection.
fn handle_http(
    mut stream: TcpStream,
    shared: &Shared,
    tx: &mpsc::SyncSender<IngestItem>,
    seq: usize,
    queue_cap: usize,
) -> Result<()> {
    const HEAD_CAP: usize = 16 * 1024;
    let mut head: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 512];
    let body_start = loop {
        match stream.read(&mut chunk) {
            Ok(0) => bail!("http: connection closed before the headers completed"),
            Ok(n) => {
                head.extend_from_slice(&chunk[..n]);
                if let Some(pos) = find_subslice(&head, b"\r\n\r\n") {
                    break pos + 4;
                }
                ensure!(head.len() <= HEAD_CAP, "http: headers exceed {HEAD_CAP} bytes");
            }
            Err(e) if would_block(&e) => {
                if shared.is_shutdown() {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    };
    let head_txt = std::str::from_utf8(&head[..body_start])
        .map_err(|_| anyhow::anyhow!("http: non-UTF-8 request head"))?;
    let mut lines = head_txt.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    if !request_line.starts_with("POST ") {
        http_respond(&mut stream, 400, br#"{"error":"only POST is supported"}"#)?;
        bail!("http: unsupported request line '{request_line}'");
    }
    let mut content_len: Option<usize> = None;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = Some(
                    v.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("http: bad content-length: {e}"))?,
                );
            }
        }
    }
    let Some(clen) = content_len else {
        http_respond(&mut stream, 400, br#"{"error":"content-length required"}"#)?;
        bail!("http: missing content-length");
    };
    let max_body = wire::REQ_FIXED + 16 * seq + 1024;
    if clen > max_body {
        http_respond(&mut stream, 400, br#"{"error":"body too large"}"#)?;
        bail!("http: {clen}-byte body exceeds the {max_body}-byte limit");
    }
    let mut body: Vec<u8> = Vec::with_capacity(clen);
    body.extend_from_slice(&head[body_start..]);
    while body.len() < clen {
        match stream.read(&mut chunk) {
            Ok(0) => bail!("http: EOF {}/{clen} body bytes in", body.len()),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if would_block(&e) => {
                if shared.is_shutdown() {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    body.truncate(clen);

    let mut req_slot = wire::RequestSlot::with_capacity(seq);
    if let Err(e) = wire::decode_request_json(&body, seq, &mut req_slot)
        .and_then(|()| validate_contract(&req_slot, seq))
    {
        // lint: allow(hot_path) -- HTTP fallback error body; the fallback path is documented as non-zero-alloc.
        let msg = json::to_string(&json::obj(vec![(
            "error",
            // lint: allow(hot_path) -- HTTP fallback error body (see above).
            Value::Str(format!("{e:#}")),
        )]));
        http_respond(&mut stream, 400, msg.as_bytes())?;
        bail!("http: rejected request: {e:#}");
    }
    if shared.is_shutdown() || !try_admit(shared, queue_cap) {
        shared.shed.fetch_add(1, Ordering::Relaxed);
        http_respond(&mut stream, 503, br#"{"error":"overloaded, retry later"}"#)?;
        return Ok(());
    }
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    // lint: allow(hot_path) -- empty budget-token seed; an empty Vec never allocates.
    let req = req_slot.take_request(0.0, Vec::new());
    if tx.send(IngestItem { req, reply: reply_tx }).is_err() {
        shared.inflight.fetch_sub(1, Ordering::Relaxed);
        http_respond(&mut stream, 503, br#"{"error":"server is stopping"}"#)?;
        bail!("http: serving loop closed the ingest channel");
    }
    // Admitted requests always complete (the drain finishes them), so this
    // only waits.
    let reply = loop {
        match reply_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(r) => break r,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                bail!("http: serving loop dropped the reply")
            }
        }
    };
    let status_txt = match reply.status {
        Status::Ok => "ok",
        Status::Shed => "shed",
        Status::Error => "error",
    };
    // lint: allow(hot_path) -- HTTP fallback response body; the fallback path is documented as non-zero-alloc.
    let body = json::to_string(&json::obj(vec![
        ("id", Value::Num(reply.id as f64)),
        ("status", Value::Str(status_txt.to_string())),
        ("tokens", json::arr_i32(&reply.tokens)),
    ]));
    let code = match reply.status {
        Status::Ok => 200,
        Status::Shed => 503,
        Status::Error => 400,
    };
    http_respond(&mut stream, code, body.as_bytes())
}

fn http_respond(stream: &mut TcpStream, code: u16, body: &[u8]) -> Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        503 => "Service Unavailable",
        _ => "Error",
    };
    // lint: allow(hot_path) -- HTTP fallback response head (see above).
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    Ok(())
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_waits_scale_tight_to_lenient() {
        let w = tier_waits(Duration::from_millis(8), 4);
        assert_eq!(
            w,
            vec![
                Duration::from_millis(2),
                Duration::from_millis(4),
                Duration::from_millis(6),
                Duration::from_millis(8),
            ]
        );
        assert_eq!(tier_waits(Duration::from_millis(5), 1), vec![Duration::from_millis(5)]);
    }

    #[test]
    fn try_admit_is_a_strict_bound() {
        let shared = Shared::new();
        for _ in 0..4 {
            assert!(try_admit(&shared, 4));
        }
        assert!(!try_admit(&shared, 4));
        shared.inflight.fetch_sub(1, Ordering::Relaxed);
        assert!(try_admit(&shared, 4));
        assert!(!try_admit(&shared, 4));
    }

    #[test]
    fn contract_validation_matches_serve_trace_decode() {
        let mut slot = wire::RequestSlot::with_capacity(16);
        slot.id = 3;
        slot.tokens.extend_from_slice(&[1, 2, 3]);
        slot.gen_len = 2;
        assert!(validate_contract(&slot, 16).is_ok());
        slot.budget = Some(f64::NAN);
        assert!(validate_contract(&slot, 16).unwrap_err().to_string().contains("(0, 1]"));
        slot.budget = Some(0.5);
        assert!(validate_contract(&slot, 16).is_ok());
        slot.gen_len = 14;
        assert!(validate_contract(&slot, 16)
            .unwrap_err()
            .to_string()
            .contains("positional"));
        slot.gen_len = 0;
        slot.tokens.clear();
        assert!(validate_contract(&slot, 16).unwrap_err().to_string().contains("empty"));
    }

    #[test]
    fn listen_report_json_reparses() {
        let report = ListenReport {
            accepted_conns: 3,
            rejected_conns: 1,
            requests_done: 40,
            shed: 2,
            conn_errors: 1,
            ingest_fingerprint_drift: 0,
            steps: 9,
            tokens_prefilled: 100,
            tokens_generated: 50,
            wall_s: f64::INFINITY, // degenerate timing must still be JSON
            latency_ms: vec![1.0, 2.0],
            tier_requests: vec![30, 10],
            demotions: 4,
            tier_switches: 3,
        };
        let parsed = crate::json::parse(&report.to_json()).expect("must re-parse");
        assert_eq!(parsed.get("requests").unwrap().as_f64().unwrap(), 40.0);
        assert_eq!(parsed.get("wall_s").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(parsed.get("demotions").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(parsed.get("tier_switches").unwrap().as_f64().unwrap(), 3.0);
    }
}
