//! Dynamic batcher: per-tier queues with max-batch-size / deadline flushing.
//!
//! Pure logic (no engine dependency) so invariants are property-testable:
//! a batch flushes when it reaches `max_batch` or when its oldest request
//! has waited that tier's deadline; fairness is oldest-first within a tier.
//! Deadlines are per tier so SLO classes feed `max_wait` directly: the
//! interactive tier (0) can flush on a tight deadline while the quality
//! tier batches longer (see [`DynamicBatcher::with_tier_waits`]); the plain
//! constructor keeps one uniform wait.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::data::trace::Request;

/// A request waiting in a tier queue.  `tag` is an opaque caller token
/// (the network listener uses it to index its reply-context slab; the
/// trace-replay paths leave it 0) — carrying it through the queue keeps the
/// ingest path free of side-table insertions.
#[derive(Debug)]
pub struct Pending {
    pub req: Request,
    pub enqueued: Instant,
    pub tag: u64,
}

/// Per-tier dynamic batching queues.
pub struct DynamicBatcher {
    queues: Vec<VecDeque<Pending>>,
    pub max_batch: usize,
    /// Per-tier flush deadline (indexed like the queues).
    waits: Vec<Duration>,
}

impl DynamicBatcher {
    pub fn new(n_tiers: usize, max_batch: usize, max_wait: Duration) -> Self {
        // lint: allow(hot_path) -- one allocation at batcher construction.
        Self::with_tier_waits(max_batch, vec![max_wait; n_tiers])
    }

    /// Per-tier deadlines: `waits[t]` is how long tier `t`'s oldest request
    /// may sit before the tier is flush-ready.
    pub fn with_tier_waits(max_batch: usize, waits: Vec<Duration>) -> Self {
        assert!(max_batch >= 1);
        DynamicBatcher {
            queues: (0..waits.len()).map(|_| VecDeque::new()).collect(),
            max_batch,
            waits,
        }
    }

    /// A tier's flush deadline.
    pub fn wait(&self, tier: usize) -> Duration {
        self.waits[tier]
    }

    pub fn push(&mut self, tier: usize, req: Request, now: Instant) {
        self.push_tagged(tier, req, now, 0);
    }

    /// Push with a caller tag (see [`Pending::tag`]).
    pub fn push_tagged(&mut self, tier: usize, req: Request, now: Instant, tag: u64) {
        self.queues[tier].push_back(Pending { req, enqueued: now, tag });
    }

    /// Total queued requests across tiers.
    pub fn depth(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn tier_depth(&self, tier: usize) -> usize {
        self.queues[tier].len()
    }

    /// The one fairness rule: among the queues `keep` admits (empty queues
    /// never qualify), the tier whose front request has waited longest.
    /// Every selection path — full-batch, expired-deadline, shutdown drain
    /// — routes through here so they can't diverge.
    fn oldest_head_among(&self, keep: impl Fn(usize, &VecDeque<Pending>) -> bool) -> Option<usize> {
        self.queues
            .iter()
            .enumerate()
            .filter(|(i, q)| !q.is_empty() && keep(*i, q))
            .min_by_key(|(_, q)| q.front().map(|p| p.enqueued))
            .map(|(i, _)| i)
    }

    /// Is any tier ready to flush at `now`?  Ready = full batch available OR
    /// oldest entry has exceeded the deadline.
    pub fn ready_tier(&self, now: Instant) -> Option<usize> {
        // Full batches first (throughput), then expired deadlines (latency).
        // Among multiple full queues, prefer the one with the oldest head —
        // the lowest-index scan this replaced starved higher tiers whenever
        // a low tier refilled faster than it drained.
        if let Some(i) = self.oldest_head_among(|_, q| q.len() >= self.max_batch) {
            return Some(i);
        }
        self.oldest_head_among(|t, q| {
            q.front()
                .map(|p| now.duration_since(p.enqueued) >= self.waits[t])
                .unwrap_or(false)
        })
    }

    /// Tier whose queue head has waited longest (None if all queues are
    /// empty) — the same fairness rule `ready_tier` applies among
    /// full/expired queues, exposed for the shutdown drain so forced
    /// flushes pop the longest-waiting requests first instead of the
    /// deepest queue.
    pub fn oldest_head_tier(&self) -> Option<usize> {
        self.oldest_head_among(|_, _| true)
    }

    /// Time until the next deadline expiry (None if all queues empty).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .enumerate()
            .filter_map(|(t, q)| q.front().map(|p| (t, p)))
            .map(|(t, p)| {
                let waited = now.duration_since(p.enqueued);
                self.waits[t].saturating_sub(waited)
            })
            .min()
    }

    /// Pop up to `max_batch` oldest requests from a tier.
    pub fn take_batch(&mut self, tier: usize) -> Vec<Pending> {
        let q = &mut self.queues[tier];
        let n = q.len().min(self.max_batch);
        q.drain(..n).collect()
    }

    /// The head of a tier queue, without removing it — the continuous
    /// batching loop inspects the head's K/V demand before committing a
    /// slot + page reservation to it.
    pub fn peek_head(&self, tier: usize) -> Option<&Pending> {
        self.queues[tier].front()
    }

    /// Pop a single request — the head of a tier queue.  The continuous
    /// batching loop admits requests one at a time (each admission is gated
    /// on a slot + page reservation), so it pulls heads instead of whole
    /// batches.
    pub fn pop_head(&mut self, tier: usize) -> Option<Pending> {
        self.queues[tier].pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::trace::Slo;

    fn req(id: u64) -> Request {
        Request { id, arrival_s: 0.0, slo: Slo::Standard, tokens: vec![], gen_len: 0, budget: None }
    }

    #[test]
    fn flushes_on_full_batch() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(2, 3, Duration::from_millis(100));
        for i in 0..3 {
            b.push(1, req(i), now);
        }
        assert_eq!(b.ready_tier(now), Some(1));
        let batch = b.take_batch(1);
        assert_eq!(batch.len(), 3);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn full_batch_fairness_prefers_oldest_head() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(3, 2, Duration::from_millis(100));
        // Tier 2 fills first (older head), tier 0 fills later.  The old
        // lowest-index scan would pick tier 0 and starve tier 2 forever
        // under sustained low-tier load.
        b.push(2, req(1), now);
        b.push(2, req(2), now + Duration::from_millis(1));
        b.push(0, req(3), now + Duration::from_millis(5));
        b.push(0, req(4), now + Duration::from_millis(6));
        assert_eq!(b.ready_tier(now + Duration::from_millis(7)), Some(2));
        // After draining tier 2, tier 0 is next.
        b.take_batch(2);
        assert_eq!(b.ready_tier(now + Duration::from_millis(7)), Some(0));
    }

    #[test]
    fn drain_picks_oldest_head_not_deepest_queue() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(3, 8, Duration::from_millis(100));
        // Tier 2 holds the single oldest request; tier 0 holds the deepest
        // queue.  The shutdown drain used to pick tier 0 (deepest), leaving
        // the longest-waiting request for last.
        b.push(2, req(1), now);
        for i in 2..6 {
            b.push(0, req(i), now + Duration::from_millis(i));
        }
        assert_eq!(b.oldest_head_tier(), Some(2));
        b.take_batch(2);
        assert_eq!(b.oldest_head_tier(), Some(0));
        b.take_batch(0);
        assert_eq!(b.oldest_head_tier(), None);
    }

    #[test]
    fn flushes_on_deadline_only_after_wait() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(1, 8, Duration::from_millis(10));
        b.push(0, req(1), now);
        assert_eq!(b.ready_tier(now), None);
        let later = now + Duration::from_millis(11);
        assert_eq!(b.ready_tier(later), Some(0));
    }

    #[test]
    fn per_tier_deadlines_flush_independently() {
        let now = Instant::now();
        let mut b = DynamicBatcher::with_tier_waits(
            8,
            vec![Duration::from_millis(5), Duration::from_millis(50)],
        );
        assert_eq!(b.wait(0), Duration::from_millis(5));
        b.push(1, req(1), now); // older, but on the lenient tier
        b.push(0, req(2), now + Duration::from_millis(1));
        // At t=7ms tier 0's head (waited 6ms) is past its 5ms deadline while
        // tier 1's head (waited 7ms) is still inside its 50ms deadline.
        let t = now + Duration::from_millis(7);
        assert_eq!(b.ready_tier(t), Some(0));
        b.take_batch(0);
        assert_eq!(b.ready_tier(t), None);
        assert_eq!(b.ready_tier(now + Duration::from_millis(51)), Some(1));
        // next_deadline tracks the per-tier wait, not a global one.
        let d = b.next_deadline(t).unwrap();
        assert!(d <= Duration::from_millis(43), "{d:?}");
    }

    #[test]
    fn tags_survive_the_queue() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(1, 4, Duration::from_millis(1));
        b.push_tagged(0, req(1), now, 41);
        b.push(0, req(2), now);
        let batch = b.take_batch(0);
        assert_eq!(batch.iter().map(|p| p.tag).collect::<Vec<_>>(), vec![41, 0]);
    }

    #[test]
    fn oldest_first_order() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(1, 2, Duration::from_millis(1));
        for i in 0..5 {
            b.push(0, req(i), now + Duration::from_millis(i as u64));
        }
        let ids: Vec<u64> = b.take_batch(0).iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1]);
        let ids: Vec<u64> = b.take_batch(0).iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![2, 3]);
    }

    #[test]
    fn next_deadline_reflects_oldest() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(2, 8, Duration::from_millis(20));
        assert_eq!(b.next_deadline(now), None);
        b.push(0, req(1), now);
        b.push(1, req(2), now + Duration::from_millis(5));
        let d = b.next_deadline(now + Duration::from_millis(10)).unwrap();
        assert!(d <= Duration::from_millis(10), "{d:?}");
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        crate::prop::forall(
            151,
            50,
            |rng| {
                let n_tiers = 1 + rng.below(4);
                let max_batch = 1 + rng.below(6);
                let ops: Vec<(usize, u64)> =
                    (0..rng.below(60)).map(|i| (rng.below(n_tiers), i as u64)).collect();
                (n_tiers, max_batch, ops)
            },
            |(n_tiers, max_batch, ops)| {
                let now = Instant::now();
                let mut b = DynamicBatcher::new(*n_tiers, *max_batch, Duration::from_secs(1));
                for (tier, id) in ops {
                    b.push(*tier, req(*id), now);
                }
                let mut seen = std::collections::HashSet::new();
                let mut drained = 0;
                for t in 0..*n_tiers {
                    loop {
                        let batch = b.take_batch(t);
                        if batch.is_empty() {
                            break;
                        }
                        if batch.len() > *max_batch {
                            return Err("batch exceeds max".into());
                        }
                        for p in &batch {
                            if !seen.insert(p.req.id) {
                                return Err(format!("dup id {}", p.req.id));
                            }
                        }
                        drained += batch.len();
                    }
                }
                if drained != ops.len() {
                    return Err(format!("drained {} of {}", drained, ops.len()));
                }
                Ok(())
            },
        );
    }
}
