//! Tier-selection policy: map a request's SLO (and current load) to a
//! serving tier.
//!
//! * **Static** — fixed SLO→tier map (quality→largest, interactive→smallest).
//! * **Adaptive** — starts from the static map, then downgrades under queue
//!   pressure: the budget-conditioned inference the paper's elasticity
//!   enables (Sec. 7 "budget-conditioned or input-adaptive inference").
//!
//! The pressure thresholds are **stateless**: every request is classified
//! independently from the queue depth observed at its arrival.  There is no
//! hysteresis — nothing remembers whether the policy was recently shedding,
//! so a depth oscillating around a threshold flips the decision per request.

use crate::data::trace::{Request, Slo};

/// Which policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Static,
    Adaptive,
}

/// Tier-selection policy over `n_tiers` tiers (ascending budget order).
#[derive(Debug, Clone)]
pub struct Policy {
    pub kind: PolicyKind,
    pub n_tiers: usize,
    /// Queue depth (requests) at or above which the adaptive policy
    /// downgrades every request a step, quality included (stateless
    /// threshold, re-evaluated per request).  In the intermediate band
    /// `pressure_lo..pressure_hi` only non-quality requests are demoted.
    pub pressure_hi: usize,
    /// Queue depth at or below which the adaptive policy serves the plain
    /// SLO tier (stateless threshold, re-evaluated per request).
    pub pressure_lo: usize,
}

impl Policy {
    pub fn new(kind: PolicyKind, n_tiers: usize) -> Self {
        Policy { kind, n_tiers, pressure_hi: 24, pressure_lo: 4 }
    }

    /// Base tier from the SLO class alone.
    pub fn base_tier(&self, slo: Slo) -> usize {
        match slo {
            Slo::Interactive => 0,
            Slo::Standard => (self.n_tiers.saturating_sub(1)) / 2,
            Slo::Quality => self.n_tiers - 1,
        }
    }

    /// Tier for a request given current total queue depth.
    ///
    /// An explicit `req.budget` must satisfy the (0, 1] contract — the
    /// serving loop rejects violations at trace ingest before routing
    /// (`serve_trace`), because the ceil/clamp arithmetic below would
    /// silently map NaN or out-of-range values into a valid tier.
    pub fn select(&self, req: &Request, queue_depth: usize) -> usize {
        if let Some(b) = req.budget {
            // Explicit budget override: smallest tier index covering it.
            let idx = ((b * self.n_tiers as f64).ceil() as usize).clamp(1, self.n_tiers) - 1;
            return idx;
        }
        let base = self.base_tier(req.slo);
        match self.kind {
            PolicyKind::Static => base,
            PolicyKind::Adaptive => {
                if queue_depth >= self.pressure_hi {
                    // Shed load: drop everything one tier (floor at 0).
                    base.saturating_sub(1)
                } else if queue_depth <= self.pressure_lo {
                    base
                } else {
                    // Intermediate pressure: only quality keeps its tier.
                    if req.slo == Slo::Quality {
                        base
                    } else {
                        base.saturating_sub(1)
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(slo: Slo) -> Request {
        Request { id: 0, arrival_s: 0.0, slo, tokens: vec![], gen_len: 0, budget: None }
    }

    #[test]
    fn static_map_monotone_in_slo() {
        let p = Policy::new(PolicyKind::Static, 4);
        let i = p.select(&req(Slo::Interactive), 0);
        let s = p.select(&req(Slo::Standard), 0);
        let q = p.select(&req(Slo::Quality), 0);
        assert!(i <= s && s <= q);
        assert_eq!(q, 3);
        assert_eq!(i, 0);
    }

    #[test]
    fn adaptive_downgrades_under_pressure() {
        let p = Policy::new(PolicyKind::Adaptive, 4);
        let quality = req(Slo::Quality);
        assert_eq!(p.select(&quality, 0), 3);
        assert_eq!(p.select(&quality, 100), 2);
        let standard = req(Slo::Standard);
        let calm = p.select(&standard, 0);
        let busy = p.select(&standard, 100);
        assert!(busy <= calm);
    }

    #[test]
    fn explicit_budget_override() {
        let p = Policy::new(PolicyKind::Static, 4);
        let mut r = req(Slo::Quality);
        r.budget = Some(0.25);
        assert_eq!(p.select(&r, 0), 0);
        r.budget = Some(1.0);
        assert_eq!(p.select(&r, 0), 3);
    }

    #[test]
    fn property_tier_always_valid() {
        crate::prop::forall(
            141,
            100,
            |rng| {
                let n = 1 + rng.below(6);
                let slo = crate::data::trace::Slo::ALL[rng.below(3)];
                let depth = rng.below(200);
                let budget = if rng.f64() < 0.3 { Some(rng.f64().max(0.01)) } else { None };
                let kind = if rng.f64() < 0.5 { PolicyKind::Static } else { PolicyKind::Adaptive };
                (n, slo, depth, budget, kind)
            },
            |(n, slo, depth, budget, kind)| {
                let p = Policy::new(*kind, *n);
                let mut r = req(*slo);
                r.budget = *budget;
                let t = p.select(&r, *depth);
                if t >= *n {
                    return Err(format!("tier {t} out of range {n}"));
                }
                Ok(())
            },
        );
    }
}
