//! Tier-selection policy: map a request's SLO (and current load) to a
//! serving tier.
//!
//! * **Static** — fixed SLO→tier map (quality→largest, interactive→smallest).
//! * **Adaptive** — starts from the static map, then downgrades under queue
//!   pressure: the budget-conditioned inference the paper's elasticity
//!   enables (Sec. 7 "budget-conditioned or input-adaptive inference").
//! * **Elastic** — handled one layer up by
//!   [`crate::coordinator::TierRouter`]: the same SLO map (or the
//!   difficulty-signal router when tier calibration errors are available)
//!   plus a stateful hysteresis controller instead of the per-request
//!   threshold check.
//!
//! The Static/Adaptive pressure thresholds are **stateless**: every request
//! is classified independently from the queue depth observed at its
//! arrival.  There is no hysteresis — nothing remembers whether the policy
//! was recently shedding, so a depth oscillating around a threshold flips
//! the decision per request.  That flapping is exactly what the Elastic
//! controller's dwell-gated level machine exists to fix (and what the
//! property tests in `tests/routing_controller.rs` pin).

use anyhow::{ensure, Result};

use crate::data::trace::{Request, Slo};

/// Which policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Static,
    Adaptive,
    /// Difficulty-routed base tier + stateful hysteresis demotion
    /// ([`crate::coordinator::ElasticController`]).
    Elastic,
}

impl PolicyKind {
    /// Parse a CLI/config spelling ("static" | "adaptive" | "elastic").
    pub fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "static" => Ok(PolicyKind::Static),
            "adaptive" => Ok(PolicyKind::Adaptive),
            "elastic" => Ok(PolicyKind::Elastic),
            other => anyhow::bail!("unknown policy {other:?} (static|adaptive|elastic)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Static => "static",
            PolicyKind::Adaptive => "adaptive",
            PolicyKind::Elastic => "elastic",
        }
    }
}

/// Queue-depth demotion band: pressure enters at `hi`, exits at `lo`.
///
/// The two thresholds are what make hysteresis possible at all — a single
/// threshold (or an inverted band, `lo >= hi`) degenerates into the
/// per-request flapping the stateless policy admits to.  Construction is
/// therefore validating: an inverted or degenerate band is a config error,
/// never something to route with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PressureBand {
    hi: usize,
    lo: usize,
}

impl PressureBand {
    /// Validated construction: requires `lo < hi` and `hi >= 1`.
    pub fn new(hi: usize, lo: usize) -> Result<PressureBand> {
        ensure!(hi >= 1, "pressure_hi must be >= 1, got {hi}");
        ensure!(
            lo < hi,
            "inverted pressure band: pressure_lo ({lo}) must be strictly below \
             pressure_hi ({hi})"
        );
        Ok(PressureBand { hi, lo })
    }

    /// Derive the band from the admission bound instead of magic numbers:
    /// enter pressure at 3/8 of `queue_cap`, exit at 1/16 — demotion kicks
    /// in well before the CAS admission check starts answering `Shed`, and
    /// releases only once the queue has genuinely drained.  `queue_cap == 0`
    /// (unbounded replay queue) falls back to the listener's default cap so
    /// the band stays finite.
    pub fn from_queue_cap(queue_cap: usize) -> PressureBand {
        let cap = if queue_cap == 0 { 64 } else { queue_cap };
        let hi = (cap * 3 / 8).max(2);
        let lo = (cap / 16).min(hi - 1);
        PressureBand { hi, lo }
    }

    /// Depth at/above which pressure is entered.
    pub fn hi(&self) -> usize {
        self.hi
    }

    /// Depth at/below which pressure is exited.
    pub fn lo(&self) -> usize {
        self.lo
    }
}

/// Tier-selection policy over `n_tiers` tiers (ascending budget order).
#[derive(Debug, Clone)]
pub struct Policy {
    pub kind: PolicyKind,
    pub n_tiers: usize,
    /// Demotion band for the adaptive policy (stateless thresholds,
    /// re-evaluated per request).  At/above `band.hi()` every request is
    /// downgraded a step, quality included; in the intermediate band only
    /// non-quality requests are demoted; at/below `band.lo()` the plain SLO
    /// tier is served.
    pub band: PressureBand,
}

impl Policy {
    /// Policy with the band derived from the default admission bound
    /// (`PressureBand::from_queue_cap(64)` — the listener's default
    /// `queue_cap`, reproducing the historical 24/4 thresholds).
    pub fn new(kind: PolicyKind, n_tiers: usize) -> Self {
        Policy { kind, n_tiers, band: PressureBand::from_queue_cap(64) }
    }

    /// Policy with an explicit (already validated) demotion band.
    pub fn with_band(kind: PolicyKind, n_tiers: usize, band: PressureBand) -> Self {
        Policy { kind, n_tiers, band }
    }

    /// Base tier from the SLO class alone.
    pub fn base_tier(&self, slo: Slo) -> usize {
        match slo {
            Slo::Interactive => 0,
            Slo::Standard => (self.n_tiers.saturating_sub(1)) / 2,
            Slo::Quality => self.n_tiers - 1,
        }
    }

    /// Smallest tier index covering an explicit budget fraction in (0, 1].
    pub fn budget_tier(&self, budget: f64) -> usize {
        ((budget * self.n_tiers as f64).ceil() as usize).clamp(1, self.n_tiers) - 1
    }

    /// Tier for a request given current total queue depth.
    ///
    /// An explicit `req.budget` must satisfy the (0, 1] contract — the
    /// serving loop rejects violations at trace ingest before routing
    /// (`serve_trace`), because the ceil/clamp arithmetic below would
    /// silently map NaN or out-of-range values into a valid tier.
    pub fn select(&self, req: &Request, queue_depth: usize) -> usize {
        if let Some(b) = req.budget {
            // Explicit budget override: smallest tier index covering it.
            return self.budget_tier(b);
        }
        let base = self.base_tier(req.slo);
        match self.kind {
            PolicyKind::Static => base,
            // Elastic is routed through TierRouter; when constructed with
            // kind Elastic but driven through the bare stateless entry
            // point, behave like the static map (no hidden state here).
            PolicyKind::Elastic => base,
            PolicyKind::Adaptive => {
                if queue_depth >= self.band.hi() {
                    // Shed load: drop everything one tier (floor at 0).
                    base.saturating_sub(1)
                } else if queue_depth <= self.band.lo() {
                    base
                } else {
                    // Intermediate pressure: only quality keeps its tier.
                    if req.slo == Slo::Quality {
                        base
                    } else {
                        base.saturating_sub(1)
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(slo: Slo) -> Request {
        Request { id: 0, arrival_s: 0.0, slo, tokens: vec![], gen_len: 0, budget: None }
    }

    #[test]
    fn static_map_monotone_in_slo() {
        let p = Policy::new(PolicyKind::Static, 4);
        let i = p.select(&req(Slo::Interactive), 0);
        let s = p.select(&req(Slo::Standard), 0);
        let q = p.select(&req(Slo::Quality), 0);
        assert!(i <= s && s <= q);
        assert_eq!(q, 3);
        assert_eq!(i, 0);
    }

    #[test]
    fn adaptive_downgrades_under_pressure() {
        let p = Policy::new(PolicyKind::Adaptive, 4);
        let quality = req(Slo::Quality);
        assert_eq!(p.select(&quality, 0), 3);
        assert_eq!(p.select(&quality, 100), 2);
        let standard = req(Slo::Standard);
        let calm = p.select(&standard, 0);
        let busy = p.select(&standard, 100);
        assert!(busy <= calm);
    }

    #[test]
    fn explicit_budget_override() {
        let p = Policy::new(PolicyKind::Static, 4);
        let mut r = req(Slo::Quality);
        r.budget = Some(0.25);
        assert_eq!(p.select(&r, 0), 0);
        r.budget = Some(1.0);
        assert_eq!(p.select(&r, 0), 3);
    }

    #[test]
    fn default_band_matches_legacy_thresholds() {
        // The historical hardcoded 24/4 must fall out of the derivation at
        // the listener's default queue_cap = 64 — same behaviour, no magic.
        let band = PressureBand::from_queue_cap(64);
        assert_eq!(band.hi(), 24);
        assert_eq!(band.lo(), 4);
        let p = Policy::new(PolicyKind::Adaptive, 4);
        assert_eq!(p.band, band);
        // Unbounded (replay) queues reuse the same reference cap.
        assert_eq!(PressureBand::from_queue_cap(0), band);
    }

    #[test]
    fn inverted_band_rejected() {
        // Regression: pressure_lo >= pressure_hi used to silently invert
        // the intermediate demotion band; now it's a construction error.
        assert!(PressureBand::new(4, 24).is_err());
        assert!(PressureBand::new(8, 8).is_err());
        assert!(PressureBand::new(0, 0).is_err());
        let b = PressureBand::new(24, 4).unwrap();
        assert_eq!((b.hi(), b.lo()), (24, 4));
        // Tight-but-valid band: lo = hi - 1.
        assert!(PressureBand::new(2, 1).is_ok());
    }

    #[test]
    fn derived_band_always_valid() {
        crate::prop::forall(
            142,
            200,
            |rng| rng.below(4096),
            |cap| {
                let band = PressureBand::from_queue_cap(*cap);
                if band.lo() >= band.hi() {
                    return Err(format!("cap {cap}: inverted derived band {band:?}"));
                }
                if *cap >= 8 && band.hi() >= *cap {
                    return Err(format!("cap {cap}: band {band:?} enters at/above the cap"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn policy_parse_round_trips() {
        for kind in [PolicyKind::Static, PolicyKind::Adaptive, PolicyKind::Elastic] {
            assert_eq!(PolicyKind::parse(kind.label()).unwrap(), kind);
        }
        assert!(PolicyKind::parse("bogus").is_err());
    }

    #[test]
    fn property_tier_always_valid() {
        crate::prop::forall(
            141,
            100,
            |rng| {
                let n = 1 + rng.below(6);
                let slo = crate::data::trace::Slo::ALL[rng.below(3)];
                let depth = rng.below(200);
                let budget = if rng.f64() < 0.3 { Some(rng.f64().max(0.01)) } else { None };
                let kind = match rng.below(3) {
                    0 => PolicyKind::Static,
                    1 => PolicyKind::Adaptive,
                    _ => PolicyKind::Elastic,
                };
                (n, slo, depth, budget, kind)
            },
            |(n, slo, depth, budget, kind)| {
                let p = Policy::new(*kind, *n);
                let mut r = req(*slo);
                r.budget = *budget;
                let t = p.select(&r, *depth);
                if t >= *n {
                    return Err(format!("tier {t} out of range {n}"));
                }
                Ok(())
            },
        );
    }
}
