//! Elastic routing layer: input-difficulty router + hysteresis load
//! controller.
//!
//! This is the stateful half of tier selection that `coordinator::policy`
//! admits it lacks.  Two cooperating pieces sit behind one facade,
//! [`TierRouter`]:
//!
//! * **Input-difficulty router** — when per-tier calibration errors are
//!   available (the `error` field written next to each tier in
//!   `profiles.json` by the DP chain, or the backend's budget proxy), each
//!   SLO class gets a quality bar interpolated across the tier error range
//!   and a request routes to the *smallest* tier meeting its bar.  Without
//!   a signal it falls back to the positional SLO map of
//!   [`Policy::base_tier`].  The explicit-budget override is preserved
//!   verbatim — a budget-contracted request is **never** demoted.
//!
//! * **Elastic load controller** — [`ElasticController`], a dwell-gated
//!   level machine over the queue-depth [`PressureBand`] plus a fixed-size
//!   latency ring (fraction of recent request latencies over the SLO
//!   deadline).  Sustained pressure raises the demotion level one tier per
//!   dwell window; sustained calm lowers it.  Distinct enter/exit
//!   thresholds + the minimum dwell time are the hysteresis: a depth
//!   oscillating around one threshold changes the level at most once per
//!   dwell window instead of flapping per request.  Demotion engages well
//!   below `queue_cap` (see [`PressureBand::from_queue_cap`]), so traffic
//!   degrades to lower-rank profiles *before* the CAS admission bound ever
//!   answers `Shed` — demote-before-shed, pinned in ROADMAP §Invariants.
//!
//! This module is on the per-request routing path and therefore in the R2
//! `hot_path` lint set: no panics, no allocation after construction.

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::data::trace::{Request, Slo};

use super::policy::{Policy, PolicyKind, PressureBand};

/// Recent-latency window (requests) feeding the controller's SLO signal.
const LAT_WINDOW: usize = 64;
/// Fraction of the latency window over the deadline that counts as
/// pressure — a tail-heavy proxy for "p99 is violating the SLO" that needs
/// neither a sort nor an allocation on the hot path.
const LAT_HOT_FRAC: f64 = 0.25;

/// Per-SLO quality bar as a fraction of the tier error range:
/// `bar = err_best + frac · (err_worst - err_best)`.  Interactive accepts
/// the full range (smallest tier), Quality essentially demands the best.
const SLO_ERROR_FRAC: [f64; 3] = [1.0, 0.4, 0.05];

/// Routing outcome for one request: the tier its SLO/difficulty/budget
/// mapping asked for, and the tier it is actually served on after any
/// load-based demotion.  `requested != served` is a demotion, surfaced by
/// `Metrics::demotion_rate`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub requested: usize,
    pub served: usize,
}

/// Stateful hysteresis controller: demotion level in `0..n_tiers`, raised
/// under sustained pressure and lowered under sustained calm, with at most
/// one level change per dwell window.
#[derive(Debug, Clone)]
pub struct ElasticController {
    band: PressureBand,
    dwell: Duration,
    n_tiers: usize,
    level: usize,
    last_change: Option<Instant>,
    switches: u64,
    /// Preallocated latency ring (ms); `lat_len` valid samples, cursor at
    /// `lat_pos`.  Zero-length when the deadline signal is disabled.
    lat_ring: Vec<f64>,
    lat_len: usize,
    lat_pos: usize,
    lat_over: usize,
    /// SLO deadline (ms) for the latency signal; `<= 0` disables it.
    deadline_ms: f64,
}

impl ElasticController {
    pub fn new(
        n_tiers: usize,
        band: PressureBand,
        dwell: Duration,
        deadline_ms: f64,
    ) -> Result<ElasticController> {
        ensure!(n_tiers >= 1, "controller needs at least one tier");
        let cap = if deadline_ms > 0.0 { LAT_WINDOW } else { 0 };
        let mut lat_ring = Vec::with_capacity(cap);
        lat_ring.resize(cap, 0.0);
        Ok(ElasticController {
            band,
            dwell,
            n_tiers,
            level: 0,
            last_change: None,
            switches: 0,
            lat_ring,
            lat_len: 0,
            lat_pos: 0,
            lat_over: 0,
            deadline_ms,
        })
    }

    /// Current demotion level (0 = serving requested tiers).
    pub fn level(&self) -> usize {
        self.level
    }

    /// Total level changes since construction (the flapping metric).
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Record one finished request's latency into the ring.
    pub fn observe_latency(&mut self, ms: f64) {
        if self.lat_ring.is_empty() {
            return;
        }
        if self.lat_len == self.lat_ring.len() {
            // Evict the sample the cursor is about to overwrite.
            if self.lat_ring[self.lat_pos] > self.deadline_ms {
                self.lat_over -= 1;
            }
        } else {
            self.lat_len += 1;
        }
        self.lat_ring[self.lat_pos] = ms;
        if ms > self.deadline_ms {
            self.lat_over += 1;
        }
        self.lat_pos = (self.lat_pos + 1) % self.lat_ring.len();
    }

    /// Whether the latency window currently signals SLO pressure.
    fn latency_hot(&self) -> bool {
        self.lat_len > 0 && (self.lat_over as f64) > LAT_HOT_FRAC * self.lat_len as f64
    }

    fn dwell_elapsed(&self, now: Instant) -> bool {
        match self.last_change {
            None => true,
            Some(t) => now.saturating_duration_since(t) >= self.dwell,
        }
    }

    /// Feed one load observation; at most one level change per dwell
    /// window.  Depth at/above the band's `hi` (or a hot latency window)
    /// raises the demotion level; depth at/below `lo` with a cool latency
    /// window lowers it.  In between the level holds — that dead band plus
    /// the dwell gate is the hysteresis.
    pub fn observe(&mut self, now: Instant, queue_depth: usize) {
        let lat_hot = self.latency_hot();
        let hot = queue_depth >= self.band.hi() || lat_hot;
        let calm = queue_depth <= self.band.lo() && !lat_hot;
        if !self.dwell_elapsed(now) {
            return;
        }
        if hot && self.level + 1 < self.n_tiers {
            self.level += 1;
            self.switches += 1;
            self.last_change = Some(now);
        } else if calm && self.level > 0 {
            self.level -= 1;
            self.switches += 1;
            self.last_change = Some(now);
        }
    }
}

/// One facade over all three policies.  Static/Adaptive delegate to the
/// stateless [`Policy`]; Elastic routes the base tier by difficulty signal
/// and demotes by the controller's level.
#[derive(Debug, Clone)]
pub struct TierRouter {
    policy: Policy,
    controller: ElasticController,
    /// Per-SLO base tier from the difficulty signal; mirrors
    /// `Policy::base_tier` when no signal was supplied.
    difficulty_base: [usize; 3],
    /// Whether a real difficulty signal (tier calibration errors) backs
    /// `difficulty_base`.
    routed_by_difficulty: bool,
}

impl TierRouter {
    /// Build a router.  `tier_errors` is the per-tier calibration error in
    /// ascending-budget tier order (empty slice = no signal, positional SLO
    /// map); `dwell` and `deadline_ms` configure the elastic controller
    /// (ignored for Static/Adaptive).
    pub fn new(
        kind: PolicyKind,
        n_tiers: usize,
        band: PressureBand,
        dwell: Duration,
        deadline_ms: f64,
        tier_errors: &[f64],
    ) -> Result<TierRouter> {
        ensure!(n_tiers >= 1, "router needs at least one tier");
        let policy = Policy::with_band(kind, n_tiers, band);
        let controller = ElasticController::new(n_tiers, band, dwell, deadline_ms)?;
        let use_signal = !tier_errors.is_empty();
        if use_signal {
            ensure!(
                tier_errors.len() == n_tiers,
                "{} tier errors for {} tiers",
                tier_errors.len(),
                n_tiers
            );
            ensure!(
                tier_errors.iter().all(|e| e.is_finite() && *e >= 0.0),
                "tier errors must be finite and non-negative"
            );
        }
        let mut difficulty_base = [0usize; 3];
        for (si, slo) in Slo::ALL.iter().enumerate() {
            difficulty_base[si] = if use_signal {
                Self::bar_tier(tier_errors, SLO_ERROR_FRAC[si])
            } else {
                policy.base_tier(*slo)
            };
        }
        Ok(TierRouter { policy, controller, difficulty_base, routed_by_difficulty: use_signal })
    }

    /// Convenience: SLO-map router with the band derived from `queue_cap`
    /// (see [`PressureBand::from_queue_cap`]).
    pub fn from_queue_cap(
        kind: PolicyKind,
        n_tiers: usize,
        queue_cap: usize,
        dwell: Duration,
        deadline_ms: f64,
        tier_errors: &[f64],
    ) -> Result<TierRouter> {
        let band = PressureBand::from_queue_cap(queue_cap);
        TierRouter::new(kind, n_tiers, band, dwell, deadline_ms, tier_errors)
    }

    /// Smallest tier whose error meets `bar = best + frac·(worst - best)`.
    fn bar_tier(errors: &[f64], frac: f64) -> usize {
        let n = errors.len();
        let mut worst = errors[0];
        let mut best = errors[0];
        for e in errors.iter() {
            if *e > worst {
                worst = *e;
            }
            if *e < best {
                best = *e;
            }
        }
        let bar = best + frac * (worst - best);
        for (t, e) in errors.iter().enumerate() {
            if *e <= bar {
                return t;
            }
        }
        n - 1
    }

    /// The base tier a request of this SLO class asks for, before any
    /// load-based demotion.
    pub fn base_tier(&self, slo: Slo) -> usize {
        self.difficulty_base[slo.code() as usize]
    }

    /// Whether the base map came from a real calibration-error signal.
    pub fn routed_by_difficulty(&self) -> bool {
        self.routed_by_difficulty
    }

    /// Feed a load observation to the elastic controller (no-op for
    /// Static/Adaptive).  Call once per scheduling step so the controller
    /// sees queue depth even between arrivals.
    pub fn observe(&mut self, now: Instant, queue_depth: usize) {
        if self.policy.kind == PolicyKind::Elastic {
            self.controller.observe(now, queue_depth);
        }
    }

    /// Feed one finished request's latency (ms) to the controller.
    pub fn observe_latency(&mut self, ms: f64) {
        if self.policy.kind == PolicyKind::Elastic {
            self.controller.observe_latency(ms);
        }
    }

    /// Route one request.  Observes the queue depth first (Elastic), then
    /// maps budget/SLO to a requested tier and applies demotion.
    pub fn route(&mut self, req: &Request, queue_depth: usize, now: Instant) -> RouteDecision {
        if let Some(b) = req.budget {
            // Explicit budget contract: requested == served, never demoted.
            let t = self.policy.budget_tier(b);
            return RouteDecision { requested: t, served: t };
        }
        match self.policy.kind {
            PolicyKind::Static | PolicyKind::Adaptive => {
                let requested = self.policy.base_tier(req.slo);
                let served = self.policy.select(req, queue_depth);
                RouteDecision { requested, served }
            }
            PolicyKind::Elastic => {
                self.controller.observe(now, queue_depth);
                let requested = self.base_tier(req.slo);
                let served = requested.saturating_sub(self.controller.level());
                RouteDecision { requested, served }
            }
        }
    }

    /// Total controller level changes (0 for Static/Adaptive).
    pub fn tier_switches(&self) -> u64 {
        self.controller.switches()
    }

    /// Current demotion level (0 for Static/Adaptive).
    pub fn level(&self) -> usize {
        if self.policy.kind == PolicyKind::Elastic {
            self.controller.level()
        } else {
            0
        }
    }

    pub fn kind(&self) -> PolicyKind {
        self.policy.kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn now0() -> Instant {
        Instant::now()
    }

    fn req(slo: Slo) -> Request {
        Request { id: 0, arrival_s: 0.0, slo, tokens: vec![], gen_len: 0, budget: None }
    }

    fn ctl(n_tiers: usize, dwell_ms: u64) -> ElasticController {
        ElasticController::new(
            n_tiers,
            PressureBand::new(24, 4).unwrap(),
            Duration::from_millis(dwell_ms),
            0.0,
        )
        .unwrap()
    }

    #[test]
    fn level_climbs_one_step_per_dwell_window() {
        let mut c = ctl(4, 10);
        let t0 = now0();
        // Sustained overload: depth pinned above hi.  First observation
        // moves immediately (no prior change), then one step per window.
        c.observe(t0, 100);
        assert_eq!(c.level(), 1);
        c.observe(t0 + Duration::from_millis(1), 100);
        assert_eq!(c.level(), 1, "dwell must gate the second step");
        c.observe(t0 + Duration::from_millis(11), 100);
        assert_eq!(c.level(), 2);
        c.observe(t0 + Duration::from_millis(22), 100);
        assert_eq!(c.level(), 3);
        // Saturates below n_tiers.
        c.observe(t0 + Duration::from_millis(40), 100);
        assert_eq!(c.level(), 3);
        assert_eq!(c.switches(), 3);
    }

    #[test]
    fn level_drains_under_sustained_calm() {
        let mut c = ctl(4, 10);
        let t0 = now0();
        c.observe(t0, 100);
        c.observe(t0 + Duration::from_millis(11), 100);
        assert_eq!(c.level(), 2);
        // Dead band: depth between lo and hi holds the level forever.
        for k in 0..20 {
            c.observe(t0 + Duration::from_millis(22 + k * 11), 10);
        }
        assert_eq!(c.level(), 2);
        // Calm drains one per window.
        c.observe(t0 + Duration::from_millis(500), 0);
        assert_eq!(c.level(), 1);
        c.observe(t0 + Duration::from_millis(511), 0);
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn latency_signal_raises_pressure() {
        let mut c = ElasticController::new(
            4,
            PressureBand::new(24, 4).unwrap(),
            Duration::from_millis(10),
            5.0,
        )
        .unwrap();
        let t0 = now0();
        // Queue calm but latencies blowing the 5ms deadline.
        for _ in 0..LAT_WINDOW {
            c.observe_latency(50.0);
        }
        c.observe(t0, 0);
        assert_eq!(c.level(), 1, "hot latency window must demote");
        // Deadline-respecting window cools it back down.
        for _ in 0..LAT_WINDOW {
            c.observe_latency(1.0);
        }
        c.observe(t0 + Duration::from_millis(11), 0);
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn budget_requests_never_demoted() {
        let mut r = TierRouter::from_queue_cap(
            PolicyKind::Elastic,
            4,
            64,
            Duration::from_millis(0),
            0.0,
            &[],
        )
        .unwrap();
        let t0 = now0();
        // Drive the controller to max demotion.
        for k in 0..10 {
            r.observe(t0 + Duration::from_millis(k), 1000);
        }
        assert_eq!(r.level(), 3);
        let mut q = req(Slo::Quality);
        q.budget = Some(1.0);
        let d = r.route(&q, 1000, t0 + Duration::from_millis(20));
        assert_eq!(d, RouteDecision { requested: 3, served: 3 });
    }

    #[test]
    fn difficulty_signal_routes_smallest_adequate_tier() {
        // DP-style descending chain errors: tier 0 worst, tier 3 best.
        let errors = [0.9, 0.4, 0.15, 0.05];
        let r = TierRouter::from_queue_cap(
            PolicyKind::Elastic,
            4,
            64,
            Duration::from_millis(0),
            0.0,
            &errors,
        )
        .unwrap();
        assert!(r.routed_by_difficulty());
        // Interactive accepts the whole range → smallest tier.
        assert_eq!(r.base_tier(Slo::Interactive), 0);
        // Quality's bar is 0.05 + 0.05·0.85 ≈ 0.0925 → only tier 3.
        assert_eq!(r.base_tier(Slo::Quality), 3);
        // Standard sits between, and never above quality.
        let s = r.base_tier(Slo::Standard);
        assert!(s >= r.base_tier(Slo::Interactive) && s <= r.base_tier(Slo::Quality));
    }

    #[test]
    fn no_signal_falls_back_to_slo_map() {
        let r = TierRouter::from_queue_cap(
            PolicyKind::Elastic,
            4,
            64,
            Duration::from_millis(0),
            0.0,
            &[],
        )
        .unwrap();
        assert!(!r.routed_by_difficulty());
        assert_eq!(r.base_tier(Slo::Interactive), 0);
        assert_eq!(r.base_tier(Slo::Standard), 1);
        assert_eq!(r.base_tier(Slo::Quality), 3);
    }

    #[test]
    fn bad_signal_rejected() {
        let mk = |errs: &[f64]| {
            TierRouter::from_queue_cap(
                PolicyKind::Elastic,
                4,
                64,
                Duration::from_millis(0),
                0.0,
                errs,
            )
        };
        assert!(mk(&[0.5, 0.4]).is_err(), "wrong length");
        assert!(mk(&[0.5, 0.4, f64::NAN, 0.1]).is_err(), "NaN");
        assert!(mk(&[0.5, 0.4, -0.1, 0.0]).is_err(), "negative");
    }

    #[test]
    fn static_and_adaptive_delegate_to_stateless_policy() {
        let mut r = TierRouter::from_queue_cap(
            PolicyKind::Adaptive,
            4,
            64,
            Duration::from_millis(0),
            0.0,
            &[],
        )
        .unwrap();
        let p = Policy::new(PolicyKind::Adaptive, 4);
        let t0 = now0();
        for depth in [0usize, 10, 30, 100] {
            for slo in Slo::ALL {
                let d = r.route(&req(slo), depth, t0);
                assert_eq!(d.served, p.select(&req(slo), depth));
                assert_eq!(d.requested, p.base_tier(slo));
            }
        }
        assert_eq!(r.tier_switches(), 0);
    }

    #[test]
    fn property_settled_level_monotone_in_sustained_load() {
        // Monotonicity: a strictly heavier sustained load never settles at
        // a lower demotion level.
        crate::prop::forall(
            143,
            60,
            |rng| {
                let n = 2 + rng.below(4);
                let d1 = rng.below(120);
                let d2 = d1 + rng.below(120);
                (n, d1, d2)
            },
            |(n, d1, d2)| {
                let settle = |depth: usize| {
                    let mut c = ctl(*n, 5);
                    let t0 = now0();
                    for k in 0..32u64 {
                        c.observe(t0 + Duration::from_millis(k * 6), depth);
                    }
                    c.level()
                };
                let (l1, l2) = (settle(*d1), settle(*d2));
                if l1 > l2 {
                    return Err(format!(
                        "depth {d1}→level {l1} but heavier depth {d2}→level {l2} (n={n})"
                    ));
                }
                Ok(())
            },
        );
    }
}
