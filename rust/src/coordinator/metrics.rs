//! Serving metrics: per-tier latency distributions + throughput.

use std::time::Duration;

/// Latency summary over a set of samples.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencyStats {
    pub fn from_samples(samples: &[f64]) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        // Nearest-rank percentile: the ⌈count·p⌉-th smallest sample,
        // 1-indexed.  The old `(count·p) as usize` truncation indexed one
        // rank too high (p50 of 1..=100 reported 51) and saturated small
        // tier sample counts straight to the max.
        let pct = |p: f64| {
            let rank = ((s.len() as f64 * p).ceil() as usize).max(1);
            s[rank.min(s.len()) - 1]
        };
        LatencyStats {
            count: s.len(),
            mean_ms: s.iter().sum::<f64>() / s.len() as f64,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            max_ms: *s.last().unwrap(),
        }
    }
}

/// Accumulates per-tier samples during a serving run.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// End-to-end latency samples (ms) per tier: queueing + execution.
    pub latency_ms: Vec<Vec<f64>>,
    /// Execution-only samples (ms) per tier.
    pub exec_ms: Vec<Vec<f64>>,
    /// Batch occupancy (filled slots / batch size) per executed batch.
    pub occupancy: Vec<f64>,
    pub batches: usize,
    pub requests_done: usize,
    /// Requests whose routing *asked* for each tier (SLO/difficulty/budget
    /// mapping, before load-based demotion).
    pub requested_by_tier: Vec<usize>,
    /// Requests actually *served* on each tier after demotion.
    pub served_by_tier: Vec<usize>,
    /// Requests served below their requested tier — the demotion count the
    /// old served-tier-only attribution made invisible.
    pub demotions: usize,
}

impl Metrics {
    pub fn new(n_tiers: usize) -> Metrics {
        Metrics {
            latency_ms: vec![Vec::new(); n_tiers],
            exec_ms: vec![Vec::new(); n_tiers],
            occupancy: Vec::new(),
            batches: 0,
            requests_done: 0,
            requested_by_tier: vec![0; n_tiers],
            served_by_tier: vec![0; n_tiers],
            demotions: 0,
        }
    }

    pub fn record_batch(
        &mut self,
        tier: usize,
        batch_fill: usize,
        batch_cap: usize,
        exec: Duration,
        per_request_latency: &[Duration],
    ) {
        self.batches += 1;
        self.requests_done += batch_fill;
        // A zero-capacity batch carries no occupancy information; pushing
        // `fill / 0` would feed NaN straight into mean_occupancy.
        if batch_cap > 0 {
            self.occupancy.push(batch_fill as f64 / batch_cap as f64);
        }
        self.exec_ms[tier].push(exec.as_secs_f64() * 1e3);
        for l in per_request_latency {
            self.latency_ms[tier].push(l.as_secs_f64() * 1e3);
        }
    }

    /// Record one routing decision: the tier the request asked for and the
    /// tier it was placed on.  `served < requested` counts as a demotion.
    pub fn record_route(&mut self, requested: usize, served: usize) {
        if let Some(c) = self.requested_by_tier.get_mut(requested) {
            *c += 1;
        }
        if let Some(c) = self.served_by_tier.get_mut(served) {
            *c += 1;
        }
        if served < requested {
            self.demotions += 1;
        }
    }

    /// Total routed requests (route decisions observed at arrival — may
    /// exceed `requests_done` while requests are still in flight).
    pub fn routed(&self) -> usize {
        self.requested_by_tier.iter().sum()
    }

    /// Fraction of routed requests served below their requested tier.
    pub fn demotion_rate(&self) -> f64 {
        let routed = self.routed();
        if routed == 0 {
            0.0
        } else {
            self.demotions as f64 / routed as f64
        }
    }

    pub fn tier_latency(&self, tier: usize) -> LatencyStats {
        LatencyStats::from_samples(&self.latency_ms[tier])
    }

    pub fn tier_exec(&self, tier: usize) -> LatencyStats {
        LatencyStats::from_samples(&self.exec_ms[tier])
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.occupancy.is_empty() {
            0.0
        } else {
            self.occupancy.iter().sum::<f64>() / self.occupancy.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ms, 50.0);
        assert_eq!(s.p95_ms, 95.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.max_ms, 100.0);
    }

    #[test]
    fn small_sample_percentiles_use_nearest_rank() {
        // 10 samples: p50 = ⌈5.0⌉ = 5th smallest, p99 = ⌈9.9⌉ = 10th.
        let samples: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.p50_ms, 5.0);
        assert_eq!(s.p95_ms, 10.0);
        assert_eq!(s.p99_ms, 10.0);
        // Two samples: the median must be the 1st, not degenerate to max.
        let s = LatencyStats::from_samples(&[3.0, 9.0]);
        assert_eq!(s.p50_ms, 3.0);
        assert_eq!(s.p99_ms, 9.0);
        // One sample: every percentile is that sample.
        let s = LatencyStats::from_samples(&[7.0]);
        assert_eq!(s.p50_ms, 7.0);
        assert_eq!(s.p99_ms, 7.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::from_samples(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_ms, 0.0);
    }

    #[test]
    fn record_accumulates() {
        let mut m = Metrics::new(2);
        m.record_batch(
            1,
            3,
            4,
            Duration::from_millis(10),
            &[Duration::from_millis(12), Duration::from_millis(14), Duration::from_millis(11)],
        );
        assert_eq!(m.requests_done, 3);
        assert_eq!(m.batches, 1);
        assert!((m.mean_occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(m.tier_latency(1).count, 3);
        assert_eq!(m.tier_latency(0).count, 0);
    }

    #[test]
    fn zero_batch_cap_does_not_poison_occupancy() {
        // Regression: batch_fill / 0 pushed NaN into the occupancy series,
        // and NaN propagates through mean_occupancy forever after.
        let mut m = Metrics::new(1);
        m.record_batch(0, 2, 0, Duration::from_millis(1), &[]);
        assert!(m.mean_occupancy().is_finite());
        assert_eq!(m.mean_occupancy(), 0.0);
        m.record_batch(0, 2, 4, Duration::from_millis(1), &[]);
        assert!((m.mean_occupancy() - 0.5).abs() < 1e-12);
        assert_eq!(m.batches, 2);
        assert_eq!(m.requests_done, 4);
    }

    #[test]
    fn route_records_requested_vs_served() {
        let mut m = Metrics::new(4);
        m.record_route(3, 3); // served where asked
        m.record_route(3, 1); // demoted two tiers
        m.record_route(0, 0);
        m.record_route(2, 1); // demoted one tier
        assert_eq!(m.requested_by_tier, vec![1, 0, 1, 2]);
        assert_eq!(m.served_by_tier, vec![1, 2, 0, 1]);
        assert_eq!(m.demotions, 2);
        assert_eq!(m.routed(), 4);
        assert!((m.demotion_rate() - 0.5).abs() < 1e-12);
        // Promotion (served above requested) is not a demotion.
        m.record_route(0, 3);
        assert_eq!(m.demotions, 2);
        // Out-of-range tiers are ignored rather than panicking.
        m.record_route(99, 99);
        assert_eq!(m.routed(), 5, "out-of-range decision must not count");
    }

    #[test]
    fn empty_metrics_demotion_rate_is_zero() {
        let m = Metrics::new(2);
        assert_eq!(m.demotion_rate(), 0.0);
        assert_eq!(m.routed(), 0);
    }
}
