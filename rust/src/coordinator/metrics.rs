//! Serving metrics: per-tier latency distributions + throughput.

use std::time::Duration;

/// Latency summary over a set of samples.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencyStats {
    pub fn from_samples(samples: &[f64]) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        // Nearest-rank percentile: the ⌈count·p⌉-th smallest sample,
        // 1-indexed.  The old `(count·p) as usize` truncation indexed one
        // rank too high (p50 of 1..=100 reported 51) and saturated small
        // tier sample counts straight to the max.
        let pct = |p: f64| {
            let rank = ((s.len() as f64 * p).ceil() as usize).max(1);
            s[rank.min(s.len()) - 1]
        };
        LatencyStats {
            count: s.len(),
            mean_ms: s.iter().sum::<f64>() / s.len() as f64,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            max_ms: *s.last().unwrap(),
        }
    }
}

/// Accumulates per-tier samples during a serving run.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// End-to-end latency samples (ms) per tier: queueing + execution.
    pub latency_ms: Vec<Vec<f64>>,
    /// Execution-only samples (ms) per tier.
    pub exec_ms: Vec<Vec<f64>>,
    /// Batch occupancy (filled slots / batch size) per executed batch.
    pub occupancy: Vec<f64>,
    pub batches: usize,
    pub requests_done: usize,
}

impl Metrics {
    pub fn new(n_tiers: usize) -> Metrics {
        Metrics {
            latency_ms: vec![Vec::new(); n_tiers],
            exec_ms: vec![Vec::new(); n_tiers],
            occupancy: Vec::new(),
            batches: 0,
            requests_done: 0,
        }
    }

    pub fn record_batch(
        &mut self,
        tier: usize,
        batch_fill: usize,
        batch_cap: usize,
        exec: Duration,
        per_request_latency: &[Duration],
    ) {
        self.batches += 1;
        self.requests_done += batch_fill;
        self.occupancy.push(batch_fill as f64 / batch_cap as f64);
        self.exec_ms[tier].push(exec.as_secs_f64() * 1e3);
        for l in per_request_latency {
            self.latency_ms[tier].push(l.as_secs_f64() * 1e3);
        }
    }

    pub fn tier_latency(&self, tier: usize) -> LatencyStats {
        LatencyStats::from_samples(&self.latency_ms[tier])
    }

    pub fn tier_exec(&self, tier: usize) -> LatencyStats {
        LatencyStats::from_samples(&self.exec_ms[tier])
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.occupancy.is_empty() {
            0.0
        } else {
            self.occupancy.iter().sum::<f64>() / self.occupancy.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ms, 50.0);
        assert_eq!(s.p95_ms, 95.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.max_ms, 100.0);
    }

    #[test]
    fn small_sample_percentiles_use_nearest_rank() {
        // 10 samples: p50 = ⌈5.0⌉ = 5th smallest, p99 = ⌈9.9⌉ = 10th.
        let samples: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.p50_ms, 5.0);
        assert_eq!(s.p95_ms, 10.0);
        assert_eq!(s.p99_ms, 10.0);
        // Two samples: the median must be the 1st, not degenerate to max.
        let s = LatencyStats::from_samples(&[3.0, 9.0]);
        assert_eq!(s.p50_ms, 3.0);
        assert_eq!(s.p99_ms, 9.0);
        // One sample: every percentile is that sample.
        let s = LatencyStats::from_samples(&[7.0]);
        assert_eq!(s.p50_ms, 7.0);
        assert_eq!(s.p99_ms, 7.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::from_samples(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_ms, 0.0);
    }

    #[test]
    fn record_accumulates() {
        let mut m = Metrics::new(2);
        m.record_batch(
            1,
            3,
            4,
            Duration::from_millis(10),
            &[Duration::from_millis(12), Duration::from_millis(14), Duration::from_millis(11)],
        );
        assert_eq!(m.requests_done, 3);
        assert_eq!(m.batches, 1);
        assert!((m.mean_occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(m.tier_latency(1).count, 3);
        assert_eq!(m.tier_latency(0).count, 0);
    }
}
