//! Submodel registry: one re-gauged GAR submodel per budget tier.
//!
//! The default backend is [`crate::runtime::native`]: tiers share a single
//! preallocated [`Scratch`], so the serving hot path performs zero heap
//! allocations per request once loaded.  The PJRT-executable variant
//! ([`PjrtRegistry`]) survives behind the `pjrt` feature for machines with
//! the XLA toolchain.

use anyhow::{ensure, Context, Result};

use crate::flexrank::masks::gar_layer_params;
use crate::json;
use crate::linalg::quant::Precision;
use crate::runtime::native::{uniform_budget_rank, DecodeScratch, GarSubmodel, Scratch};
use crate::runtime::{ModelConfig, PagedKvCache, ServingBackend};
use crate::training::params::{ParamSet, LAYER_KINDS};

/// Full-model GAR parameter cost of a student's factor set (what the
/// pipeline records as `full_cost` in profiles.json): Σ per factorized
/// layer `gar_layer_params(n, m, r_full)` with dims read off the stored
/// `_u (m, r_full)` / `_v (n, r_full)` tensors.
fn student_full_cost(cfg: &ModelConfig, student: &ParamSet) -> Result<u64> {
    let mut cost = 0u64;
    for b in 0..cfg.n_blocks {
        for kind in LAYER_KINDS {
            let u = student.get(&format!("blocks.{b}.{kind}_u"))?.shape().to_vec();
            let v = student.get(&format!("blocks.{b}.{kind}_v"))?.shape().to_vec();
            ensure!(
                u.len() == 2 && v.len() == 2 && u[1] == v[1],
                "student factor blocks.{b}.{kind} has shapes {u:?}/{v:?}"
            );
            cost += gar_layer_params(v[0], u[0], u[1]) as u64;
        }
    }
    Ok(cost)
}

/// DP-selected serving artifacts loaded from `profiles.json`: one rank
/// profile per tier plus the chain's measured per-tier calibration error —
/// the difficulty signal the input-adaptive router's quality bars
/// interpolate over.
#[derive(Debug, Clone)]
pub struct TierProfiles {
    pub profiles: Vec<Vec<usize>>,
    /// Per-tier calibration error (`error` field; lower = closer to the
    /// teacher).  Files predating the field get the `1 - budget` proxy.
    pub errors: Vec<f64>,
}

/// Load the DP-selected per-tier profiles the native pipeline persisted as
/// `training::stage_dir()/profiles.json` (see the schema in ROADMAP.md).
///
/// Returns `Ok(None)` when no file exists, or when it was written for a
/// different model config / tier set / student (a stale artifact — serving
/// falls back to uniform budget profiles with a warning).  Staleness checks
/// cover the config name, tier count, tier budgets, the recorded
/// `full_cost` against the *loaded* student's GAR parameter count (catches
/// a same-named config whose checkpoint/student changed **shape**), and
/// the `params_fp` content fingerprint against
/// [`ParamSet::content_fingerprint`] — which catches the case the
/// dimensional check cannot: a **re-trained** student with identical
/// shapes whose values changed (the DP probe errors, and with them the
/// selected profiles, no longer describe what is being served).  A
/// profiles.json without a `params_fp` predates the fingerprint schema and
/// is treated as stale (rerun `repro profiles`).
/// A file that claims to match but is malformed is a hard error: serving
/// silently wrong submodels is never acceptable.
pub fn load_tier_profiles(cfg: &ModelConfig, student: &ParamSet) -> Result<Option<TierProfiles>> {
    let path = crate::training::stage_dir().join("profiles.json");
    if !path.exists() {
        return Ok(None);
    }
    let doc = json::parse_file(&path)
        .with_context(|| format!("parsing {}", path.display()))?;
    let name = doc.req("config")?.as_str()?;
    if name != cfg.name {
        eprintln!(
            "[serve] {} was written for config '{name}', serving '{}' — \
             falling back to uniform profiles",
            path.display(),
            cfg.name
        );
        return Ok(None);
    }
    let stored_cost = doc.req("full_cost")?.as_f64()? as u64;
    let expect_cost = student_full_cost(cfg, student)?;
    if stored_cost != expect_cost {
        eprintln!(
            "[serve] {}: recorded full_cost {stored_cost} but the loaded student \
             costs {expect_cost} — profiles were DP'd for a different \
             checkpoint/student; falling back to uniform profiles \
             (rerun `repro profiles`)",
            path.display()
        );
        return Ok(None);
    }
    let expect_fp = format!("{:016x}", student.content_fingerprint());
    match doc.get("params_fp").map(|v| v.as_str()).transpose()? {
        Some(fp) if fp == expect_fp => {}
        Some(fp) => {
            eprintln!(
                "[serve] {}: params fingerprint {fp} but the loaded student \
                 fingerprints to {expect_fp} — profiles were DP'd for a \
                 re-trained student (same shapes, different values); falling \
                 back to uniform profiles (rerun `repro profiles`)",
                path.display()
            );
            return Ok(None);
        }
        None => {
            eprintln!(
                "[serve] {}: no params_fp recorded (written by a pre-fingerprint \
                 pipeline) — cannot verify the profiles match this student; \
                 falling back to uniform profiles (rerun `repro profiles`)",
                path.display()
            );
            return Ok(None);
        }
    }
    let tiers = doc.req("tiers")?.as_arr()?;
    if tiers.len() != cfg.serve_tiers.len() {
        eprintln!(
            "[serve] {} has {} tiers but the config serves {} — \
             falling back to uniform profiles (rerun `repro profiles`)",
            path.display(),
            tiers.len(),
            cfg.serve_tiers.len()
        );
        return Ok(None);
    }
    let mut out = Vec::with_capacity(tiers.len());
    let mut errors = Vec::with_capacity(tiers.len());
    for (i, t) in tiers.iter().enumerate() {
        let budget = t.req("budget")?.as_f64()?;
        if (budget - cfg.serve_tiers[i]).abs() > 1e-9 {
            // Same staleness class as a changed tier count: the config's
            // budgets moved since the pipeline ran.
            eprintln!(
                "[serve] {}: tier {i} budget {budget} != config budget {} — \
                 falling back to uniform profiles (rerun `repro profiles`)",
                path.display(),
                cfg.serve_tiers[i]
            );
            return Ok(None);
        }
        // Per-tier storage precision (schema v3): absent means f32 (older
        // files predate quantized tiers and still describe the ranks
        // correctly); a recorded precision that contradicts the config is
        // the same staleness class as a changed budget.
        let stored_prec = match t.get("precision").map(|p| p.as_str()).transpose()? {
            Some(ps) => Precision::parse(ps)
                .with_context(|| format!("{}: tier {i} precision", path.display()))?,
            None => Precision::F32,
        };
        let want_prec = cfg.tier_precision.get(i).copied().unwrap_or(Precision::F32);
        if stored_prec != want_prec {
            eprintln!(
                "[serve] {}: tier {i} recorded precision {} but the config \
                 serves {} — falling back to uniform profiles (rerun \
                 `repro profiles`)",
                path.display(),
                stored_prec.label(),
                want_prec.label()
            );
            return Ok(None);
        }
        let profile = t.req("profile")?.as_usize_vec()?;
        ensure!(
            profile.len() == cfg.n_fact_layers(),
            "{}: tier {i} profile has {} ranks but the model has {} \
             factorized layers",
            path.display(),
            profile.len(),
            cfg.n_fact_layers()
        );
        // Out-of-range ranks would be silently clamped downstream by
        // GarSubmodel::from_student — serve nothing rather than the wrong
        // submodel.
        for (l, &r) in profile.iter().enumerate() {
            ensure!(
                (1..=cfg.rank_full()).contains(&r),
                "{}: tier {i} layer {l} rank {r} outside [1, {}]",
                path.display(),
                cfg.rank_full()
            );
        }
        // Difficulty signal: the DP chain's measured calibration error.
        // Absent (pre-signal schema) falls back to the budget proxy — the
        // profiles themselves are still valid — but a present-yet-broken
        // value is a hard error, not something to route quality bars with.
        let error = match t.get("error").map(|e| e.as_f64()).transpose()? {
            Some(e) => {
                ensure!(
                    e.is_finite() && e >= 0.0,
                    "{}: tier {i} error {e} is not a usable difficulty signal \
                     (must be finite and non-negative)",
                    path.display()
                );
                e
            }
            None => (1.0 - budget).max(0.0),
        };
        errors.push(error);
        out.push(profile);
    }
    Ok(Some(TierProfiles { profiles: out, errors }))
}

/// One deployable tier.
pub struct Tier {
    pub idx: usize,
    /// Budget fraction in (0, 1].
    pub budget: f64,
    /// Rank profile baked into the submodel.
    pub profile: Vec<usize>,
    /// Inference parameter count (GAR form, elements — precision-free).
    pub params: usize,
    /// Factor storage precision the submodel was quantized to.
    pub precision: Precision,
    /// Calibration error (difficulty signal) — the DP chain's measured
    /// value when loaded from `profiles.json`, else the `1 - budget` proxy.
    pub error: f64,
    model: GarSubmodel,
}

/// Registry over all serving tiers, ordered by ascending budget.
pub struct SubmodelRegistry {
    pub tiers: Vec<Tier>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    scratch: Scratch,
    /// Per-request paged K/V state for the incremental seam.  One cache is
    /// shared by every tier: K/V shapes depend only on (d, heads), which
    /// the rank profiles don't touch, and a request stays pinned to one
    /// tier for its lifetime.
    cache: PagedKvCache,
    decode_scratch: DecodeScratch,
}

impl SubmodelRegistry {
    /// Re-gauge the student's factors at every serving tier.  `profiles`
    /// supplies one rank profile per tier (e.g. from DP selection); when
    /// `None`, each tier gets the uniform budget profile.
    pub fn load_native(
        cfg: &ModelConfig,
        student: &ParamSet,
        profiles: Option<&TierProfiles>,
    ) -> Result<SubmodelRegistry> {
        ensure!(!cfg.serve_tiers.is_empty(), "no serving tiers configured");
        // The rank-collision bump below (and every consumer of tier order)
        // assumes budgets ascend; reject a shuffled config instead of
        // assigning ranks unrelated to their budgets.
        ensure!(
            cfg.serve_tiers.windows(2).all(|w| w[0] < w[1]),
            "serve_tiers must be strictly ascending, got {:?}",
            cfg.serve_tiers
        );
        if let Some(ps) = profiles {
            ensure!(
                ps.profiles.len() == cfg.serve_tiers.len(),
                "{} profiles for {} tiers",
                ps.profiles.len(),
                cfg.serve_tiers.len()
            );
            ensure!(
                ps.errors.len() == ps.profiles.len(),
                "{} tier errors for {} profiles",
                ps.errors.len(),
                ps.profiles.len()
            );
        }
        let mut tiers = Vec::with_capacity(cfg.serve_tiers.len());
        let mut prev_rank: Option<usize> = None;
        for (i, &budget) in cfg.serve_tiers.iter().enumerate() {
            let profile = match profiles {
                Some(ps) => ps.profiles[i].clone(),
                None => {
                    // Nearby budgets can round to the same uniform rank (and
                    // with it identical submodels), silently collapsing two
                    // tiers and breaking the strictly-ascending-params
                    // invariant — bump past the previous tier's rank.
                    let mut r = uniform_budget_rank(cfg, budget);
                    if let Some(p) = prev_rank {
                        if r <= p {
                            r = p + 1;
                        }
                    }
                    ensure!(
                        r <= cfg.rank_full(),
                        "serve tier {i} (budget {budget}): no rank above the previous \
                         tier's within rank_full {} — too many tiers for this model",
                        cfg.rank_full()
                    );
                    prev_rank = Some(r);
                    vec![r; cfg.n_fact_layers()]
                }
            };
            // Factor storage precision comes from the config's per-tier
            // list; a registry loaded with fewer entries than tiers (tests
            // mutate serve_tiers in place) pads with f32.
            let prec = cfg.tier_precision.get(i).copied().unwrap_or(Precision::F32);
            let model = GarSubmodel::from_student_prec(cfg, student, &profile, prec)?;
            let error = match profiles {
                Some(ps) => ps.errors[i],
                None => (1.0 - budget).max(0.0),
            };
            tiers.push(Tier {
                idx: i,
                budget,
                profile,
                params: model.n_params,
                precision: prec,
                error,
                model,
            });
        }
        // Covers the explicit-profiles path too: duplicate or shrinking
        // tiers are a selection bug, never something to serve silently.
        ensure!(
            tiers.windows(2).all(|w| w[0].params < w[1].params),
            "tier params must be strictly ascending, got {:?}",
            tiers.iter().map(|t| t.params).collect::<Vec<_>>()
        );
        // Attention path resolves from the config's crossover knobs:
        // streaming (no (t, t) score matrix) at/above attn_streaming_min_seq.
        let scratch = Scratch::for_config(cfg, cfg.batch_serve * cfg.seq_len);
        // Incremental-decode state: batch_serve concurrent request slots of
        // up to seq_len tokens each, page pool sized by the kv_* knobs.
        let cache = PagedKvCache::new(
            cfg.kv_page_size,
            cfg.n_blocks,
            cfg.n_heads,
            cfg.d_model / cfg.n_heads,
            cfg.batch_serve,
            cfg.seq_len,
            cfg.kv_max_pages,
        );
        let decode_scratch = DecodeScratch::for_config(cfg);
        Ok(SubmodelRegistry {
            tiers,
            batch: cfg.batch_serve,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            scratch,
            cache,
            decode_scratch,
        })
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Run one batch (row-major `(batch, seq_len)` tokens, padded to the
    /// fixed serving batch) on a tier; returns the logits
    /// `(batch·seq_len, vocab)` borrowed from the shared scratch.
    pub fn infer(&mut self, tier: usize, tokens: &[i32]) -> Result<&[f32]> {
        ensure!(tier < self.tiers.len(), "tier {tier} out of range");
        ensure!(tokens.len() == self.batch * self.seq_len, "bad batch size");
        let (batch, seq_len, vocab) = (self.batch, self.seq_len, self.vocab);
        let Self { tiers, scratch, .. } = self;
        tiers[tier].model.forward(tokens, batch, scratch)?;
        Ok(scratch.logits(batch * seq_len, vocab))
    }

    /// Scratch buffer identity (tests assert it never reallocates).
    pub fn scratch_fingerprint(&self) -> Vec<usize> {
        self.scratch.fingerprint()
    }

    /// Incremental-path buffer identity (cache pool + decode scratch) —
    /// the decode loop's zero-allocation pin.
    pub fn decode_fingerprint(&self) -> Vec<usize> {
        let mut fp = self.cache.fingerprint();
        fp.extend(self.decode_scratch.fingerprint());
        fp
    }
}

impl ServingBackend for SubmodelRegistry {
    fn n_tiers(&self) -> usize {
        self.tiers.len()
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn tier_budget(&self, tier: usize) -> f64 {
        self.tiers[tier].budget
    }
    fn tier_params(&self, tier: usize) -> usize {
        self.tiers[tier].params
    }
    fn tier_error(&self, tier: usize) -> f64 {
        self.tiers[tier].error
    }
    fn infer(&mut self, tier: usize, tokens: &[i32]) -> Result<&[f32]> {
        SubmodelRegistry::infer(self, tier, tokens)
    }
    fn attn_path_label(&self) -> String {
        self.scratch.attn_path_label()
    }
    fn tier_precision_label(&self, tier: usize) -> &'static str {
        self.tiers[tier].precision.label()
    }
    fn supports_decode(&self) -> bool {
        true
    }
    fn decode_slots(&self) -> usize {
        self.cache.max_slots()
    }
    fn acquire_slot(&mut self, need_tokens: usize) -> Option<usize> {
        self.cache.try_acquire(need_tokens)
    }
    fn release_slot(&mut self, slot: usize) {
        self.cache.release(slot);
    }
    fn prefill(&mut self, tier: usize, slot: usize, tokens: &[i32]) -> Result<&[f32]> {
        ensure!(tier < self.tiers.len(), "tier {tier} out of range");
        let vocab = self.vocab;
        let rows = tokens.len();
        let Self { tiers, cache, decode_scratch, .. } = self;
        tiers[tier].model.prefill(tokens, slot, cache, decode_scratch)?;
        Ok(decode_scratch.logits(rows, vocab))
    }
    fn decode_step(&mut self, tier: usize, slots: &[usize], tokens: &[i32]) -> Result<&[f32]> {
        ensure!(tier < self.tiers.len(), "tier {tier} out of range");
        let vocab = self.vocab;
        let rows = slots.len();
        let Self { tiers, cache, decode_scratch, .. } = self;
        tiers[tier].model.decode_step(tokens, slots, cache, decode_scratch)?;
        Ok(decode_scratch.logits(rows, vocab))
    }
}

/// PJRT-backed registry: one compiled GAR executable + device-resident
/// weights per tier (requires `make artifacts` and the `xla` crate).
#[cfg(feature = "pjrt")]
pub struct PjrtRegistry {
    pub tiers: Vec<PjrtTier>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

#[cfg(feature = "pjrt")]
pub struct PjrtTier {
    pub idx: usize,
    pub budget: f64,
    pub profile: Vec<usize>,
    pub params: usize,
    exe: std::sync::Arc<crate::runtime::Executable>,
    weights: Vec<crate::runtime::DeviceTensor>,
}

#[cfg(feature = "pjrt")]
impl PjrtRegistry {
    /// Load every `serve_gar_t{i}` artifact, re-gauge the student's factors
    /// per tier profile, and pin the weights on device.
    pub fn load(engine: &crate::runtime::Engine, student: &ParamSet) -> Result<PjrtRegistry> {
        use crate::training::params::gar_params_for;
        let cfg = engine.manifest.config.clone();
        let mut tiers = Vec::new();
        for (i, &budget) in cfg.serve_tiers.iter().enumerate() {
            let name = format!("serve_gar_t{i}");
            let exe = engine.load(&name)?;
            let spec = exe.spec.clone();
            let host = gar_params_for(&cfg, student, &spec)?;
            let params = host.iter().map(|t| t.len()).sum();
            let weights = engine.to_device_all(&host)?;
            tiers.push(PjrtTier {
                idx: i,
                budget,
                profile: spec.profile.clone().unwrap_or_default(),
                params,
                exe,
                weights,
            });
        }
        ensure!(!tiers.is_empty(), "no serving tiers in manifest");
        Ok(PjrtRegistry {
            tiers,
            batch: cfg.batch_serve,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
        })
    }

    /// Run one batch on a tier; returns logits as a host tensor.
    pub fn infer(
        &self,
        engine: &crate::runtime::Engine,
        tier: usize,
        tokens: Vec<i32>,
    ) -> Result<crate::runtime::Tensor> {
        use crate::runtime::Tensor;
        let t = &self.tiers[tier];
        ensure!(tokens.len() == self.batch * self.seq_len, "bad batch size");
        let tok = engine.to_device(&Tensor::i32(vec![self.batch, self.seq_len], tokens))?;
        let mut refs: Vec<&xla::PjRtBuffer> = t.weights.iter().map(|d| d.buffer()).collect();
        refs.push(tok.buffer());
        let out = t.exe.run_b(&refs)?;
        Tensor::from_literal(&out[0])
    }
}

/// PJRT registry + engine bundled behind the one serving seam, so the
/// coordinator/bench/CLI stack drives the XLA executables through the same
/// [`ServingBackend`] calls as the native kernels.
#[cfg(feature = "pjrt")]
pub struct PjrtServing {
    pub engine: crate::runtime::Engine,
    pub registry: PjrtRegistry,
    /// Host copy of the last batch's logits (`infer` returns a borrow).
    logits: Vec<f32>,
}

#[cfg(feature = "pjrt")]
impl PjrtServing {
    pub fn new(engine: crate::runtime::Engine, registry: PjrtRegistry) -> PjrtServing {
        PjrtServing { engine, registry, logits: Vec::new() }
    }
}

#[cfg(feature = "pjrt")]
impl ServingBackend for PjrtServing {
    fn n_tiers(&self) -> usize {
        self.registry.tiers.len()
    }
    fn batch(&self) -> usize {
        self.registry.batch
    }
    fn seq_len(&self) -> usize {
        self.registry.seq_len
    }
    fn tier_budget(&self, tier: usize) -> f64 {
        self.registry.tiers[tier].budget
    }
    fn tier_params(&self, tier: usize) -> usize {
        self.registry.tiers[tier].params
    }
    fn infer(&mut self, tier: usize, tokens: &[i32]) -> Result<&[f32]> {
        let out = self.registry.infer(&self.engine, tier, tokens.to_vec())?;
        self.logits.clear();
        self.logits.extend_from_slice(out.as_f32()?);
        Ok(&self.logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::training::params::{decompose_teacher, random_teacher, student_from_factors};

    #[test]
    fn native_registry_loads_and_infers_all_tiers() {
        let cfg = crate::config::load_model_config("tiny").unwrap();
        let teacher = random_teacher(&cfg, 3);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let mut reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
        assert_eq!(reg.n_tiers(), cfg.serve_tiers.len());
        // Params strictly increase with budget.
        for w in reg.tiers.windows(2) {
            assert!(w[0].params < w[1].params, "tier params must ascend");
        }
        let tokens = vec![1i32; cfg.batch_serve * cfg.seq_len];
        let fp = reg.scratch_fingerprint();
        for tier in 0..reg.n_tiers() {
            let logits = reg.infer(tier, &tokens).unwrap();
            assert_eq!(logits.len(), cfg.batch_serve * cfg.seq_len * cfg.vocab);
            assert!(logits.iter().all(|x| x.is_finite()));
        }
        // The shared scratch never reallocated across tiers/requests.
        assert_eq!(reg.scratch_fingerprint(), fp);
    }

    #[test]
    fn close_budget_tiers_do_not_collapse() {
        let mut cfg = crate::config::load_model_config("tiny").unwrap();
        // 0.50 and 0.51 both round to rank 16 of rank_full 32; load_native
        // must bump the middle tier so params stay strictly ascending.
        cfg.serve_tiers = vec![0.50, 0.51, 1.0];
        let teacher = random_teacher(&cfg, 5);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
        assert_eq!(reg.n_tiers(), 3);
        for w in reg.tiers.windows(2) {
            assert!(w[0].params < w[1].params, "tier params must ascend");
        }
        assert_eq!(reg.tiers[0].profile[0], 16);
        assert_eq!(reg.tiers[1].profile[0], 17, "colliding tier must bump its rank");

        // And when no distinct rank is available the load fails loudly
        // instead of serving duplicate tiers (0.99 and 1.0 both round to
        // rank_full, and there is nothing above to bump to).
        cfg.serve_tiers = vec![0.99, 1.0];
        let err = SubmodelRegistry::load_native(&cfg, &student, None).unwrap_err();
        assert!(err.to_string().contains("too many tiers"), "{err}");

        // Out-of-order budgets are a config error, not a silent re-rank.
        cfg.serve_tiers = vec![0.9, 0.1];
        let err = SubmodelRegistry::load_native(&cfg, &student, None).unwrap_err();
        assert!(err.to_string().contains("ascending"), "{err}");
    }

    #[test]
    fn tier_errors_flow_from_profiles_to_backend_seam() {
        let cfg = crate::config::load_model_config("tiny").unwrap();
        let teacher = random_teacher(&cfg, 7);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        // Without profiles, the difficulty signal is the 1 - budget proxy.
        let reg = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
        for (i, &b) in cfg.serve_tiers.iter().enumerate() {
            assert!((reg.tier_error(i) - (1.0 - b).max(0.0)).abs() < 1e-12);
        }
        // With profiles, the DP chain's measured errors reach the seam.
        let n_layers = cfg.n_fact_layers();
        let profiles = TierProfiles {
            profiles: vec![vec![16; n_layers], vec![32; n_layers]],
            errors: vec![0.42, 0.07],
        };
        let reg = SubmodelRegistry::load_native(&cfg, &student, Some(&profiles)).unwrap();
        assert_eq!(reg.tier_error(0), 0.42);
        assert_eq!(reg.tier_error(1), 0.07);
        // A length mismatch between errors and profiles is a load error.
        let broken = TierProfiles {
            profiles: vec![vec![16; n_layers], vec![32; n_layers]],
            errors: vec![0.42],
        };
        let err = SubmodelRegistry::load_native(&cfg, &student, Some(&broken)).unwrap_err();
        assert!(err.to_string().contains("tier errors"), "{err}");
    }
}
