//! Submodel registry: one compiled GAR executable + device-resident weights
//! per budget tier.

use anyhow::{ensure, Result};

use crate::runtime::{DeviceTensor, Engine, Executable, Tensor};
use crate::training::params::{gar_params_for, ParamSet};

/// One deployable tier.
pub struct Tier {
    pub idx: usize,
    /// Budget fraction in (0, 1].
    pub budget: f64,
    /// Rank profile baked into the executable.
    pub profile: Vec<usize>,
    /// Inference parameter count (GAR form).
    pub params: usize,
    exe: std::sync::Arc<Executable>,
    weights: Vec<DeviceTensor>,
}

/// Registry over all serving tiers, ordered by ascending budget.
pub struct SubmodelRegistry {
    pub tiers: Vec<Tier>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

impl SubmodelRegistry {
    /// Load every `serve_gar_t{i}` artifact, re-gauge the student's factors
    /// per tier profile, and pin the weights on device.
    pub fn load(engine: &Engine, student: &ParamSet) -> Result<SubmodelRegistry> {
        let cfg = engine.manifest.config.clone();
        let mut tiers = Vec::new();
        for (i, &budget) in cfg.serve_tiers.iter().enumerate() {
            let name = format!("serve_gar_t{i}");
            let exe = engine.load(&name)?;
            let spec = exe.spec.clone();
            let host = gar_params_for(&cfg, student, &spec)?;
            let params = host.iter().map(|t| t.len()).sum();
            let weights = engine.to_device_all(&host)?;
            tiers.push(Tier {
                idx: i,
                budget,
                profile: spec.profile.clone().unwrap_or_default(),
                params,
                exe,
                weights,
            });
        }
        ensure!(!tiers.is_empty(), "no serving tiers in manifest");
        Ok(SubmodelRegistry {
            tiers,
            batch: cfg.batch_serve,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
        })
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Run one batch (row-major `(batch, seq_len)` tokens) on a tier;
    /// returns logits as a host tensor `(batch, seq_len, vocab)`.
    pub fn infer(&self, engine: &Engine, tier: usize, tokens: Vec<i32>) -> Result<Tensor> {
        let t = &self.tiers[tier];
        ensure!(tokens.len() == self.batch * self.seq_len, "bad batch size");
        let tok = engine.to_device(&Tensor::i32(vec![self.batch, self.seq_len], tokens))?;
        let mut refs: Vec<&xla::PjRtBuffer> = t.weights.iter().map(|d| d.buffer()).collect();
        refs.push(tok.buffer());
        let out = t.exe.run_b(&refs)?;
        Tensor::from_literal(&out[0])
    }
}
