//! The serving loop: ingest thread replays the trace; the main loop routes,
//! batches, executes on whatever [`ServingBackend`] is loaded (native
//! kernels by default, PJRT behind its feature), and records metrics.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::data::trace::Request;
use crate::json::{self, Value};
use crate::runtime::ServingBackend;

use super::batcher::{DynamicBatcher, Pending};
use super::controller::TierRouter;
use super::metrics::{LatencyStats, Metrics};
use super::policy::{PolicyKind, PressureBand};

/// Serving-run configuration.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    pub policy: PolicyKind,
    /// Batch deadline (ms): a partial batch flushes after this wait.
    pub max_wait_ms: f64,
    /// Replay speed: 1.0 = real-time per the trace, 0.0 = as-fast-as-possible.
    pub replay_speed: f64,
    /// Queue bound for the replay paths: an arrival seeing this many queued
    /// requests is shed explicitly (counted in the report, never served).
    /// `0` (the default) keeps the legacy unbounded replay queue — every
    /// trace request is served.  The listener has its own `queue_cap`.
    pub queue_cap: usize,
    /// Elastic controller: minimum dwell between tier-level changes (ms).
    pub dwell_ms: f64,
    /// Elastic controller: SLO latency deadline (ms) feeding the latency
    /// pressure signal; `0` disables it (queue depth only).
    pub deadline_ms: f64,
    /// Demotion band override; `None` derives it from `queue_cap` via
    /// [`PressureBand::from_queue_cap`] so demotion always engages below
    /// the shed bound (demote-before-shed).
    pub pressure: Option<PressureBand>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            policy: PolicyKind::Static,
            max_wait_ms: 4.0,
            replay_speed: 1.0,
            queue_cap: 0,
            dwell_ms: 25.0,
            deadline_ms: 0.0,
            pressure: None,
        }
    }
}

impl ServeCfg {
    /// The demotion band in effect: the explicit override, else derived
    /// from `queue_cap`.
    pub fn band(&self) -> PressureBand {
        match self.pressure {
            Some(b) => b,
            None => PressureBand::from_queue_cap(self.queue_cap),
        }
    }

    /// Build the routing layer for a backend with `n_tiers` tiers.
    /// `tier_errors` is the per-tier difficulty signal (empty = positional
    /// SLO map).
    pub fn router(&self, n_tiers: usize, tier_errors: &[f64]) -> Result<TierRouter> {
        TierRouter::new(
            self.policy,
            n_tiers,
            self.band(),
            Duration::from_secs_f64(self.dwell_ms.max(0.0) / 1e3),
            self.deadline_ms,
            tier_errors,
        )
    }
}

/// Per-tier difficulty signal off the backend seam (calibration error, or
/// its `1 - budget` proxy) — what the router's quality bars interpolate.
pub(super) fn backend_tier_errors<B: ServingBackend + ?Sized>(backend: &B) -> Vec<f64> {
    (0..backend.n_tiers()).map(|t| backend.tier_error(t)).collect()
}

/// Capacity of the bounded ingest channel, sized off the batcher: enough to
/// keep every tier's next batch fed, clamped so a tiny config still
/// overlaps replay with execution and a huge one can't buffer the whole
/// trace (each `Request` carries its token Vec — the unbounded channel this
/// replaced held the entire trace in memory on a fast replay).
pub fn ingest_bound(n_tiers: usize, max_batch: usize) -> usize {
    (n_tiers * max_batch).clamp(8, 1024)
}

/// Replay a trace's arrivals onto a bounded channel on its own timeline.
/// `send` on a full channel blocks — that backpressure is the point: a slow
/// consumer stalls the replayer instead of ballooning the queue.
fn spawn_replay(
    trace: Vec<Request>,
    replay: f64,
    tx: mpsc::SyncSender<Request>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let t0 = Instant::now();
        for req in trace {
            if replay > 0.0 {
                let due = Duration::from_secs_f64(req.arrival_s / replay);
                if let Some(sleep) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep);
                }
            }
            if tx.send(req).is_err() {
                break;
            }
        }
    })
}

/// Final report of a serving run.
pub struct ServeReport {
    pub metrics: Metrics,
    pub tier_budgets: Vec<f64>,
    pub tier_params: Vec<usize>,
    pub tier_requests: Vec<usize>,
    /// Per-tier difficulty signal the run routed with (calibration error
    /// or budget proxy) — feeds `eval_loss_proxy`.
    pub tier_errors: Vec<f64>,
    /// Arrivals shed at the replay queue bound (only with `queue_cap > 0`).
    pub shed: usize,
    /// Elastic controller level changes over the run (0 for Static/Adaptive).
    pub tier_switches: u64,
    pub wall_s: f64,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.metrics.requests_done as f64 / self.wall_s.max(1e-9)
    }

    /// Served-traffic quality proxy: request-weighted mean tier error.
    /// Lower is better; demotions push it up, which is exactly the
    /// quality-vs-load trade the Pareto rows plot.
    pub fn eval_loss_proxy(&self) -> f64 {
        let total: usize = self.tier_requests.iter().sum();
        if total == 0 {
            return 0.0;
        }
        self.tier_requests
            .iter()
            .zip(self.tier_errors.iter())
            .map(|(&n, &e)| n as f64 * e)
            .sum::<f64>()
            / total as f64
    }

    /// Fraction of arrivals shed at the queue bound.
    pub fn shed_rate(&self) -> f64 {
        let arrivals = self.metrics.routed() + self.shed;
        if arrivals == 0 {
            0.0
        } else {
            self.shed as f64 / arrivals as f64
        }
    }

    pub fn print(&self) {
        println!("== serving report ==");
        println!(
            "requests {}  batches {}  wall {:.2}s  throughput {:.1} req/s  occupancy {:.0}%",
            self.metrics.requests_done,
            self.metrics.batches,
            self.wall_s,
            self.throughput_rps(),
            self.metrics.mean_occupancy() * 100.0
        );
        println!(
            "routing: shed {} ({:.1}%)  demotions {} ({:.1}%)  tier switches {}  \
             loss proxy {:.4}",
            self.shed,
            self.shed_rate() * 100.0,
            self.metrics.demotions,
            self.metrics.demotion_rate() * 100.0,
            self.tier_switches,
            self.eval_loss_proxy()
        );
        for (i, &b) in self.tier_budgets.iter().enumerate() {
            let l = self.metrics.tier_latency(i);
            let e = self.metrics.tier_exec(i);
            println!(
                "tier {i} (budget {b:.2}, {:.2}M params, {} reqs): \
                 latency p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms | exec p50 {:.1}ms",
                self.tier_params[i] as f64 / 1e6,
                self.tier_requests[i],
                l.p50_ms,
                l.p95_ms,
                l.p99_ms,
                e.p50_ms,
            );
        }
    }

    pub fn to_json(&self) -> String {
        // Ratio fields route through `finite_num`: on a ~0-elapsed tiny
        // trace `throughput_rps` divides by ~nothing, and a bare inf/NaN is
        // not valid JSON — it would poison every downstream bench parse.
        let tiers: Vec<Value> = self
            .tier_budgets
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                let l = self.metrics.tier_latency(i);
                json::obj(vec![
                    ("tier", Value::Num(i as f64)),
                    ("budget", json::finite_num(b)),
                    ("params", Value::Num(self.tier_params[i] as f64)),
                    ("requests", Value::Num(self.tier_requests[i] as f64)),
                    ("latency_p50_ms", json::finite_num(l.p50_ms)),
                    ("latency_p95_ms", json::finite_num(l.p95_ms)),
                    ("latency_p99_ms", json::finite_num(l.p99_ms)),
                    ("exec_p50_ms", json::finite_num(self.metrics.tier_exec(i).p50_ms)),
                ])
            })
            .collect();
        json::to_string(&json::obj(vec![
            ("requests", Value::Num(self.metrics.requests_done as f64)),
            ("batches", Value::Num(self.metrics.batches as f64)),
            ("wall_s", json::finite_num(self.wall_s)),
            ("throughput_rps", json::finite_num(self.throughput_rps())),
            ("mean_occupancy", json::finite_num(self.metrics.mean_occupancy())),
            ("shed", Value::Num(self.shed as f64)),
            ("shed_rate", json::finite_num(self.shed_rate())),
            ("demotions", Value::Num(self.metrics.demotions as f64)),
            ("demotion_rate", json::finite_num(self.metrics.demotion_rate())),
            ("tier_switches", Value::Num(self.tier_switches as f64)),
            ("eval_loss_proxy", json::finite_num(self.eval_loss_proxy())),
            ("tiers", Value::Arr(tiers)),
        ]))
    }
}

/// Execute one batch on a tier: full-window requests pack into the reusable
/// buffer for one `infer` call; shorter prompts route padding-free through
/// the backend's prefill seam (they used to hard-error here and abort the
/// whole replay).  Shared by the steady-state and drain paths (they were
/// previously copy-pasted).
fn run_batch<B: ServingBackend + ?Sized>(
    backend: &mut B,
    metrics: &mut Metrics,
    tokens: &mut Vec<i32>,
    lats: &mut Vec<Duration>,
    tier: usize,
    batch: &[Pending],
) -> Result<()> {
    let (cap, seq) = (backend.batch(), backend.seq_len());
    tokens.clear();
    let mut full = 0usize;
    for p in batch {
        // An over-long window fits neither the packed batch nor a K/V
        // stream; in the packed batch it would shift every later request's
        // rows and silently corrupt whose logits are whose — reject loudly.
        ensure!(
            p.req.tokens.len() <= seq,
            "request {} carries {} tokens but the serving seq_len is {seq}; \
             refusing to pack a misaligned batch",
            p.req.id,
            p.req.tokens.len()
        );
        ensure!(
            !p.req.tokens.is_empty(),
            "request {} carries an empty token window; refusing to pack it",
            p.req.id
        );
        if p.req.tokens.len() == seq {
            tokens.extend_from_slice(&p.req.tokens);
            full += 1;
        } else {
            ensure!(
                backend.supports_decode(),
                "request {} carries {} tokens but the serving seq_len is {seq} \
                 and this backend has no prefill seam; refusing to pack a \
                 misaligned batch",
                p.req.id,
                p.req.tokens.len()
            );
        }
    }

    if full > 0 {
        tokens.resize(cap * seq, 0);
        let exec_t0 = Instant::now();
        let _logits = backend.infer(tier, tokens)?;
        let exec = exec_t0.elapsed();
        let done = Instant::now();
        lats.clear();
        lats.extend(
            batch
                .iter()
                .filter(|p| p.req.tokens.len() == seq)
                .map(|p| done.duration_since(p.enqueued)),
        );
        metrics.record_batch(tier, full, cap, exec, lats);
    }

    let short = batch.len() - full;
    if short > 0 {
        // Short prompts run one at a time through prefill — no padding, no
        // row-shifting risk — and release their pages immediately since the
        // one-shot path keeps no decode state.
        let exec_t0 = Instant::now();
        for p in batch.iter().filter(|p| p.req.tokens.len() < seq) {
            let Some(slot) = backend.acquire_slot(p.req.tokens.len()) else {
                bail!("no K/V slot free to prefill request {}", p.req.id)
            };
            let res = backend.prefill(tier, slot, &p.req.tokens).map(|_| ());
            backend.release_slot(slot);
            res?;
        }
        let exec = exec_t0.elapsed();
        let done = Instant::now();
        lats.clear();
        lats.extend(
            batch
                .iter()
                .filter(|p| p.req.tokens.len() < seq)
                .map(|p| done.duration_since(p.enqueued)),
        );
        metrics.record_batch(tier, short, short, exec, lats);
    }
    Ok(())
}

/// Serve a trace to completion over a loaded serving backend (native
/// registry, PJRT registry, …) — the coordinator stack is backend-agnostic
/// above the [`ServingBackend`] seam.
pub fn serve_trace<B: ServingBackend + ?Sized>(
    backend: &mut B,
    trace: Vec<Request>,
    cfg: &ServeCfg,
) -> Result<ServeReport> {
    let n_tiers = backend.n_tiers();
    let tier_errors = backend_tier_errors(backend);
    let mut router = cfg.router(n_tiers, &tier_errors)?;
    let mut batcher = DynamicBatcher::new(
        n_tiers,
        backend.batch(),
        Duration::from_secs_f64(cfg.max_wait_ms / 1e3),
    );
    let mut metrics = Metrics::new(n_tiers);
    let mut tier_requests = vec![0usize; n_tiers];
    let mut shed = 0usize;
    // Reused across batches so the hot path stays allocation-free.
    let mut tokens: Vec<i32> = Vec::with_capacity(backend.batch() * backend.seq_len());
    let mut lats: Vec<Duration> = Vec::with_capacity(backend.batch());

    // Budget-override contract: finite, in (0, 1].  A NaN or out-of-range
    // budget used to be silently mapped into some tier by the select
    // arithmetic — reject it loudly, and do it up front, before the ingest
    // thread spawns, so the abort leaves no detached replay thread behind.
    for req in &trace {
        if let Some(b) = req.budget {
            ensure!(
                b.is_finite() && b > 0.0 && b <= 1.0,
                "request {} carries budget {b} outside the (0, 1] \
                 contract; refusing to route it",
                req.id
            );
        }
    }

    // Ingest thread: replays arrivals on the trace's timeline, through a
    // bounded channel so a slow consumer backpressures the replayer.
    let (tx, rx) = mpsc::sync_channel::<Request>(ingest_bound(n_tiers, backend.batch()));
    let ingest = spawn_replay(trace, cfg.replay_speed, tx);

    let start = Instant::now();
    let mut open = true;
    while open || batcher.depth() > 0 {
        // Drain arrivals (blocking briefly when idle so we don't spin).
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    let now = Instant::now();
                    let depth = batcher.depth();
                    // Route before the shed check: the elastic controller
                    // observes every arrival's depth, so demotion pressure
                    // builds *before* the bound starts refusing work
                    // (demote-before-shed).
                    let d = router.route(&req, depth, now);
                    if cfg.queue_cap > 0 && depth >= cfg.queue_cap {
                        shed += 1;
                        continue;
                    }
                    metrics.record_route(d.requested, d.served);
                    tier_requests[d.served] += 1;
                    batcher.push(d.served, req, now);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        let now = Instant::now();
        router.observe(now, batcher.depth());
        if let Some(tier) = batcher.ready_tier(now) {
            let batch = batcher.take_batch(tier);
            run_batch(backend, &mut metrics, &mut tokens, &mut lats, tier, &batch)?;
            for l in lats.iter() {
                router.observe_latency(l.as_secs_f64() * 1e3);
            }
        } else if open {
            // Idle: wait for the next deadline or a short poll tick.
            let wait = batcher
                .next_deadline(now)
                .unwrap_or(Duration::from_millis(1))
                .min(Duration::from_millis(2));
            std::thread::sleep(wait.max(Duration::from_micros(100)));
        } else if batcher.depth() > 0 {
            // Channel closed; force-flush what remains.  Drain oldest head
            // first — the same fairness rule `ready_tier` applies in steady
            // state — so shutdown tail-latency accounting is consistent
            // (the old deepest-queue-first pick left the longest-waiting
            // requests for last).
            let Some(tier) = batcher.oldest_head_tier() else { break };
            let batch = batcher.take_batch(tier);
            run_batch(backend, &mut metrics, &mut tokens, &mut lats, tier, &batch)?;
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    ingest.join().ok();

    Ok(ServeReport {
        metrics,
        tier_budgets: (0..n_tiers).map(|t| backend.tier_budget(t)).collect(),
        tier_params: (0..n_tiers).map(|t| backend.tier_params(t)).collect(),
        tier_requests,
        tier_errors,
        shed,
        tier_switches: router.tier_switches(),
        wall_s,
    })
}

/// Final report of a continuous-batching decode run.
pub struct DecodeReport {
    pub requests_done: usize,
    /// Executed `decode_step` calls (each advances a whole tier group).
    pub steps: usize,
    pub tokens_prefilled: usize,
    pub tokens_generated: usize,
    pub wall_s: f64,
    /// Per-call decode-step execution samples (ms).
    pub decode_step_ms: Vec<f64>,
    /// Per-request prefill execution samples (ms).
    pub prefill_ms: Vec<f64>,
    /// End-to-end request latency samples (ms): queueing + prefill + decode.
    pub latency_ms: Vec<f64>,
    pub tier_requests: Vec<usize>,
    /// Per-tier difficulty signal the run routed with.
    pub tier_errors: Vec<f64>,
    /// Arrivals shed at the replay queue bound (only with `queue_cap > 0`).
    pub shed: usize,
    /// Requests served below the tier their routing asked for.
    pub demotions: usize,
    /// Elastic controller level changes over the run.
    pub tier_switches: u64,
}

impl DecodeReport {
    /// End-to-end token throughput (prefilled + generated per wall second).
    pub fn tokens_per_sec(&self) -> f64 {
        (self.tokens_prefilled + self.tokens_generated) as f64 / self.wall_s.max(1e-9)
    }

    pub fn decode_latency(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.decode_step_ms)
    }

    pub fn prefill_latency(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.prefill_ms)
    }

    pub fn request_latency(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.latency_ms)
    }

    /// Served-traffic quality proxy (see [`ServeReport::eval_loss_proxy`]).
    pub fn eval_loss_proxy(&self) -> f64 {
        let total: usize = self.tier_requests.iter().sum();
        if total == 0 {
            return 0.0;
        }
        self.tier_requests
            .iter()
            .zip(self.tier_errors.iter())
            .map(|(&n, &e)| n as f64 * e)
            .sum::<f64>()
            / total as f64
    }

    /// Fraction of arrivals shed at the queue bound.
    pub fn shed_rate(&self) -> f64 {
        let arrivals = self.tier_requests.iter().sum::<usize>() + self.shed;
        if arrivals == 0 {
            0.0
        } else {
            self.shed as f64 / arrivals as f64
        }
    }

    pub fn print(&self) {
        println!("== decode serving report ==");
        println!(
            "requests {}  steps {}  prefill {} tok  generated {} tok  \
             wall {:.2}s  throughput {:.1} tok/s",
            self.requests_done,
            self.steps,
            self.tokens_prefilled,
            self.tokens_generated,
            self.wall_s,
            self.tokens_per_sec()
        );
        let d = self.decode_latency();
        let p = self.prefill_latency();
        let l = self.request_latency();
        println!(
            "decode step p50 {:.3}ms p99 {:.3}ms | prefill p50 {:.3}ms \
             p99 {:.3}ms | request p50 {:.1}ms p99 {:.1}ms",
            d.p50_ms, d.p99_ms, p.p50_ms, p.p99_ms, l.p50_ms, l.p99_ms
        );
        println!(
            "routing: shed {} ({:.1}%)  demotions {}  tier switches {}  loss proxy {:.4}",
            self.shed,
            self.shed_rate() * 100.0,
            self.demotions,
            self.tier_switches,
            self.eval_loss_proxy()
        );
        for (i, &n) in self.tier_requests.iter().enumerate() {
            println!("tier {i}: {n} reqs");
        }
    }

    pub fn to_json(&self) -> String {
        // Same inf/NaN guard as `ServeReport::to_json` — `tokens_per_sec`
        // and the latency percentiles are ratios over elapsed time.
        let d = self.decode_latency();
        let p = self.prefill_latency();
        let l = self.request_latency();
        json::to_string(&json::obj(vec![
            ("requests", Value::Num(self.requests_done as f64)),
            ("steps", Value::Num(self.steps as f64)),
            ("tokens_prefilled", Value::Num(self.tokens_prefilled as f64)),
            ("tokens_generated", Value::Num(self.tokens_generated as f64)),
            ("wall_s", json::finite_num(self.wall_s)),
            ("tokens_per_sec", json::finite_num(self.tokens_per_sec())),
            ("decode_p50_ms", json::finite_num(d.p50_ms)),
            ("decode_p99_ms", json::finite_num(d.p99_ms)),
            ("prefill_p50_ms", json::finite_num(p.p50_ms)),
            ("prefill_p99_ms", json::finite_num(p.p99_ms)),
            ("latency_p50_ms", json::finite_num(l.p50_ms)),
            ("latency_p99_ms", json::finite_num(l.p99_ms)),
            ("shed", Value::Num(self.shed as f64)),
            ("shed_rate", json::finite_num(self.shed_rate())),
            ("demotions", Value::Num(self.demotions as f64)),
            ("tier_switches", Value::Num(self.tier_switches as f64)),
            ("eval_loss_proxy", json::finite_num(self.eval_loss_proxy())),
        ]))
    }
}

/// Greedy (deterministic) token choice from one logits row.
fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Serve a trace through the incremental prefill/decode seam with
/// continuous batching: new requests join the running batch between decode
/// steps as soon as a slot plus an eager (prompt + generation) page
/// reservation is free, and a finished request's pages free immediately —
/// no flush barriers, no padding.
pub fn serve_trace_decode<B: ServingBackend + ?Sized>(
    backend: &mut B,
    trace: Vec<Request>,
    cfg: &ServeCfg,
) -> Result<DecodeReport> {
    ensure!(
        backend.supports_decode() && backend.decode_slots() > 0,
        "this backend has no incremental decode seam"
    );
    let n_tiers = backend.n_tiers();
    let seq = backend.seq_len();
    let tier_errors = backend_tier_errors(backend);
    let mut router = cfg.router(n_tiers, &tier_errors)?;
    let mut batcher = DynamicBatcher::new(
        n_tiers,
        backend.batch(),
        Duration::from_secs_f64(cfg.max_wait_ms / 1e3),
    );
    let mut tier_requests = vec![0usize; n_tiers];
    let mut shed = 0usize;
    let mut demotions = 0usize;

    // Same ingest contracts as `serve_trace`, checked before the replay
    // thread spawns so an abort leaves no detached thread behind.  The
    // extra decode-path contract: a stream (prompt + generation) must fit
    // the positional table, and eager reservation needs at least one token.
    for req in &trace {
        if let Some(b) = req.budget {
            ensure!(
                b.is_finite() && b > 0.0 && b <= 1.0,
                "request {} carries budget {b} outside the (0, 1] \
                 contract; refusing to route it",
                req.id
            );
        }
        ensure!(!req.tokens.is_empty(), "request {} carries an empty prompt", req.id);
        ensure!(
            req.total_tokens() <= seq,
            "request {} needs {} tokens (prompt {} + gen {}) but the \
             positional table holds {seq}; refusing to admit it",
            req.id,
            req.total_tokens(),
            req.tokens.len(),
            req.gen_len
        );
    }

    // Ingest thread: replays arrivals on the trace's timeline, through a
    // bounded channel so a slow consumer backpressures the replayer.
    let (tx, rx) = mpsc::sync_channel::<Request>(ingest_bound(n_tiers, backend.batch()));
    let ingest = spawn_replay(trace, cfg.replay_speed, tx);

    /// One admitted, still-generating request.
    struct Active {
        tier: usize,
        slot: usize,
        last_token: i32,
        remaining: usize,
        enqueued: Instant,
    }

    let mut active: Vec<Active> = Vec::with_capacity(backend.decode_slots());
    // Reused across steps so the decode loop stays allocation-free.
    let mut step_slots: Vec<usize> = Vec::with_capacity(backend.decode_slots());
    let mut step_tokens: Vec<i32> = Vec::with_capacity(backend.decode_slots());

    let mut requests_done = 0usize;
    let mut steps = 0usize;
    let mut tokens_prefilled = 0usize;
    let mut tokens_generated = 0usize;
    let mut decode_step_ms: Vec<f64> = Vec::new();
    let mut prefill_ms: Vec<f64> = Vec::new();
    let mut latency_ms: Vec<f64> = Vec::new();

    let start = Instant::now();
    let mut open = true;
    while open || batcher.depth() > 0 || !active.is_empty() {
        // Drain arrivals.  Route-then-shed ordering as in `serve_trace`:
        // the controller sees every arrival's depth, so demotion engages
        // before the bound refuses work.
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    let now = Instant::now();
                    let depth = batcher.depth();
                    let d = router.route(&req, depth, now);
                    if cfg.queue_cap > 0 && depth >= cfg.queue_cap {
                        shed += 1;
                        continue;
                    }
                    if d.served < d.requested {
                        demotions += 1;
                    }
                    tier_requests[d.served] += 1;
                    batcher.push(d.served, req, now);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        router.observe(Instant::now(), batcher.depth());

        // Admission: between steps, queued requests join the running batch
        // as long as a slot plus a full eager page reservation is free;
        // oldest queue head first — the batcher's one fairness rule.
        loop {
            let Some(tier) = batcher.oldest_head_tier() else { break };
            let need = match batcher.peek_head(tier) {
                Some(p) => p.req.total_tokens(),
                None => break,
            };
            let Some(slot) = backend.acquire_slot(need) else { break };
            // The head can only vanish if the queue was drained between
            // peek and pop (a bookkeeping bug); give the slot back and
            // stop admitting rather than panic the serving loop.
            let Some(p) = batcher.pop_head(tier) else {
                backend.release_slot(slot);
                break;
            };
            let t0 = Instant::now();
            let first = {
                let logits = backend.prefill(tier, slot, &p.req.tokens)?;
                let vocab = logits.len() / p.req.tokens.len();
                argmax(&logits[(p.req.tokens.len() - 1) * vocab..])
            };
            prefill_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            tokens_prefilled += p.req.tokens.len();
            if p.req.gen_len <= 1 {
                // Prefill-only, or the single generated token came straight
                // off the prompt logits — complete without entering decode.
                tokens_generated += p.req.gen_len;
                backend.release_slot(slot);
                let ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
                latency_ms.push(ms);
                router.observe_latency(ms);
                requests_done += 1;
                continue;
            }
            tokens_generated += 1;
            active.push(Active {
                tier,
                slot,
                last_token: first,
                remaining: p.req.gen_len - 1,
                enqueued: p.enqueued,
            });
        }

        if active.is_empty() {
            if open {
                // Idle: wait for the next deadline or a short poll tick.
                let wait = batcher
                    .next_deadline(Instant::now())
                    .unwrap_or(Duration::from_millis(1))
                    .min(Duration::from_millis(2));
                std::thread::sleep(wait.max(Duration::from_micros(100)));
            }
            continue;
        }

        // One decode step per tier group: feed each request's last sampled
        // token, append its K/V row, sample the next token greedily.
        for tier in 0..n_tiers {
            step_slots.clear();
            step_tokens.clear();
            for a in active.iter().filter(|a| a.tier == tier) {
                step_slots.push(a.slot);
                step_tokens.push(a.last_token);
            }
            if step_slots.is_empty() {
                continue;
            }
            let n_rows = step_slots.len();
            let t0 = Instant::now();
            {
                let logits = backend.decode_step(tier, &step_slots, &step_tokens)?;
                let vocab = logits.len() / n_rows;
                step_tokens.clear();
                for r in 0..n_rows {
                    step_tokens.push(argmax(&logits[r * vocab..(r + 1) * vocab]));
                }
            }
            decode_step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            steps += 1;
            let mut r = 0;
            for a in active.iter_mut().filter(|a| a.tier == tier) {
                a.last_token = step_tokens[r];
                a.remaining -= 1;
                tokens_generated += 1;
                r += 1;
            }
        }

        // Retire finished requests; their pages free immediately so queued
        // requests can admit on the very next iteration.
        let mut i = 0;
        while i < active.len() {
            if active[i].remaining == 0 {
                let a = active.swap_remove(i);
                backend.release_slot(a.slot);
                let ms = a.enqueued.elapsed().as_secs_f64() * 1e3;
                latency_ms.push(ms);
                router.observe_latency(ms);
                requests_done += 1;
            } else {
                i += 1;
            }
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    ingest.join().ok();

    Ok(DecodeReport {
        requests_done,
        steps,
        tokens_prefilled,
        tokens_generated,
        wall_s,
        decode_step_ms,
        prefill_ms,
        latency_ms,
        tier_requests,
        tier_errors,
        shed,
        demotions,
        tier_switches: router.tier_switches(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::SubmodelRegistry;
    use crate::data::trace::{Request, Slo};
    use crate::training::params::{decompose_teacher, random_teacher, student_from_factors};

    fn tiny_registry(seed: u64) -> (crate::runtime::ModelConfig, SubmodelRegistry) {
        let cfg = crate::config::load_model_config("tiny").unwrap();
        let teacher = random_teacher(&cfg, seed);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let registry = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
        (cfg, registry)
    }

    #[test]
    fn invalid_budget_override_fails_loudly() {
        // The select arithmetic used to map NaN to tier 0 and budgets > 1
        // to the top tier silently; ingest must reject anything outside the
        // documented (0, 1] contract, naming the offending request.
        let (cfg, mut registry) = tiny_registry(19);
        let req = |id: u64, budget: Option<f64>| Request {
            id,
            arrival_s: 0.0,
            slo: Slo::Standard,
            tokens: vec![1; cfg.seq_len],
            gen_len: 0,
            budget,
        };
        let scfg = ServeCfg {
            policy: PolicyKind::Static,
            max_wait_ms: 1.0,
            replay_speed: 0.0,
            ..Default::default()
        };
        for bad in [f64::NAN, 0.0, -0.5, 1.5, f64::INFINITY] {
            let err = serve_trace(&mut registry, vec![req(7, Some(bad))], &scfg).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("request 7"), "must name the request ({bad}): {msg}");
            assert!(msg.contains("(0, 1]"), "must state the contract ({bad}): {msg}");
        }
        // In-contract budgets still serve.
        let report = serve_trace(
            &mut registry,
            vec![req(1, Some(0.3)), req(2, Some(1.0)), req(3, None)],
            &scfg,
        )
        .unwrap();
        assert_eq!(report.metrics.requests_done, 3);
    }

    #[test]
    fn overlong_request_fails_loudly_short_routes_through_prefill() {
        let (cfg, mut registry) = tiny_registry(9);
        let good = |id: u64| Request {
            id,
            arrival_s: 0.0,
            slo: Slo::Standard,
            tokens: vec![1; cfg.seq_len],
            gen_len: 0,
            budget: None,
        };
        let scfg = ServeCfg {
            policy: PolicyKind::Static,
            max_wait_ms: 1.0,
            replay_speed: 0.0,
            ..Default::default()
        };

        // An over-long window fits neither the packed batch nor a K/V
        // stream: the run must abort naming the offender.
        let mut long = good(2);
        long.tokens.extend_from_slice(&[1, 1, 1]);
        let err = serve_trace(&mut registry, vec![good(1), long, good(3)], &scfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("request 2"), "error must name the request: {msg}");
        assert!(msg.contains("seq_len"), "error must explain the mismatch: {msg}");

        // A truncated window used to abort the whole replay here; it now
        // routes padding-free through the prefill seam and the replay
        // completes, serving every request.
        let mut short = good(2);
        short.tokens.truncate(cfg.seq_len - 3);
        let report =
            serve_trace(&mut registry, vec![good(1), short, good(3)], &scfg).unwrap();
        assert_eq!(report.metrics.requests_done, 3);
        // The one-shot prefill released its slot and pages.
        assert!(registry.acquire_slot(cfg.seq_len).is_some());
    }

    #[test]
    fn continuous_decode_serves_variable_length_trace() {
        use crate::data::trace::{TraceCfg, TraceGen};
        let (cfg, mut registry) = tiny_registry(23);
        let n = 12;
        let tcfg = TraceCfg {
            n_requests: n,
            rate: 1000.0,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            seed: 41,
            prompt_len_min: 2,
            prompt_len_max: cfg.seq_len - 2,
            gen_len_min: 1,
            gen_len_max: cfg.seq_len / 2,
            ..Default::default()
        };
        let trace = TraceGen::new(tcfg, b"decode trace source text for the tiny registry")
            .unwrap()
            .generate();
        let want_gen: usize = trace.iter().map(|r| r.gen_len).sum();
        let want_prefill: usize = trace.iter().map(|r| r.tokens.len()).sum();
        let scfg = ServeCfg {
            policy: PolicyKind::Static,
            max_wait_ms: 1.0,
            replay_speed: 0.0,
            ..Default::default()
        };
        let report = serve_trace_decode(&mut registry, trace, &scfg).unwrap();
        assert_eq!(report.requests_done, n);
        assert_eq!(report.tokens_prefilled, want_prefill);
        assert_eq!(report.tokens_generated, want_gen);
        assert_eq!(report.latency_ms.len(), n);
        assert_eq!(report.tier_requests.iter().sum::<usize>(), n);
        assert!(report.tokens_per_sec() > 0.0);
        // Every slot and page came back to the pool.
        for _ in 0..registry.decode_slots() {
            assert!(registry.acquire_slot(cfg.seq_len).is_some(), "slots or pages leaked");
        }
    }

    #[test]
    fn slow_consumer_blocks_replayer_instead_of_buffering_trace() {
        // The ingest channel is bounded: with a consumer that never drains,
        // the replay thread must stall at the bound instead of buffering
        // every Request (tokens included) in memory.
        let bound = ingest_bound(2, 4);
        let n = bound + 64;
        let trace: Vec<Request> = (0..n as u64)
            .map(|id| Request {
                id,
                arrival_s: 0.0,
                slo: Slo::Standard,
                tokens: vec![1; 8],
                gen_len: 0,
                budget: None,
            })
            .collect();
        let (tx, rx) = mpsc::sync_channel::<Request>(bound);
        let replayer = spawn_replay(trace, 0.0, tx);
        // Give it ample time: if the channel were unbounded it would finish
        // the whole trace in well under this.
        std::thread::sleep(Duration::from_millis(200));
        assert!(
            !replayer.is_finished(),
            "replayer drained {n} requests through a bound-{bound} channel \
             with no consumer — ingest is not backpressured"
        );
        // Draining the channel releases it; nothing is lost or reordered.
        let ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        replayer.join().unwrap();
    }

    #[test]
    fn reports_reparse_even_on_degenerate_timings() {
        // A ~0-elapsed run makes the ratio fields divide by ~nothing; the
        // serializers must still emit valid JSON (inf/NaN are not tokens
        // json::parse accepts).  Build reports with poisoned floats
        // directly so the guard is exercised regardless of timer grain.
        let serve = ServeReport {
            metrics: Metrics::new(2),
            tier_budgets: vec![0.5, f64::NAN],
            tier_params: vec![1000, 2000],
            tier_requests: vec![0, 0],
            tier_errors: vec![0.5, f64::NAN],
            shed: 0,
            tier_switches: 0,
            wall_s: f64::INFINITY,
        };
        let parsed = json::parse(&serve.to_json()).expect("ServeReport JSON must re-parse");
        assert_eq!(parsed.get("wall_s").unwrap().as_f64().unwrap(), 0.0);

        let decode = DecodeReport {
            requests_done: 1,
            steps: 1,
            tokens_prefilled: 4,
            tokens_generated: 2,
            wall_s: 0.0,
            decode_step_ms: vec![f64::NAN],
            prefill_ms: vec![],
            latency_ms: vec![f64::INFINITY],
            tier_requests: vec![1],
            tier_errors: vec![f64::NAN],
            shed: 0,
            demotions: 0,
            tier_switches: 0,
        };
        let parsed = json::parse(&decode.to_json()).expect("DecodeReport JSON must re-parse");
        assert!(parsed.get("decode_p50_ms").unwrap().as_f64().unwrap().is_finite());

        // And a real tiny run's report re-parses too.
        let (cfg, mut registry) = tiny_registry(3);
        let req = Request {
            id: 1,
            arrival_s: 0.0,
            slo: Slo::Standard,
            tokens: vec![1; cfg.seq_len],
            gen_len: 0,
            budget: None,
        };
        let scfg = ServeCfg {
            policy: PolicyKind::Static,
            max_wait_ms: 1.0,
            replay_speed: 0.0,
            ..Default::default()
        };
        let report = serve_trace(&mut registry, vec![req], &scfg).unwrap();
        json::parse(&report.to_json()).expect("live ServeReport JSON must re-parse");
    }

    #[test]
    fn decode_rejects_streams_that_outgrow_the_positional_table() {
        let (cfg, mut registry) = tiny_registry(31);
        let req = Request {
            id: 5,
            arrival_s: 0.0,
            slo: Slo::Standard,
            tokens: vec![1; cfg.seq_len - 2],
            gen_len: 5,
            budget: None,
        };
        let scfg = ServeCfg {
            policy: PolicyKind::Static,
            max_wait_ms: 1.0,
            replay_speed: 0.0,
            ..Default::default()
        };
        let err = serve_trace_decode(&mut registry, vec![req], &scfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("request 5"), "error must name the request: {msg}");
        assert!(msg.contains("positional table"), "error must explain: {msg}");
    }
}
