//! The serving loop: ingest thread replays the trace; the main loop routes,
//! batches, executes on whatever [`ServingBackend`] is loaded (native
//! kernels by default, PJRT behind its feature), and records metrics.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::data::trace::Request;
use crate::json::{self, Value};
use crate::runtime::ServingBackend;

use super::batcher::{DynamicBatcher, Pending};
use super::metrics::Metrics;
use super::policy::{Policy, PolicyKind};

/// Serving-run configuration.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    pub policy: PolicyKind,
    /// Batch deadline (ms): a partial batch flushes after this wait.
    pub max_wait_ms: f64,
    /// Replay speed: 1.0 = real-time per the trace, 0.0 = as-fast-as-possible.
    pub replay_speed: f64,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { policy: PolicyKind::Static, max_wait_ms: 4.0, replay_speed: 1.0 }
    }
}

/// Final report of a serving run.
pub struct ServeReport {
    pub metrics: Metrics,
    pub tier_budgets: Vec<f64>,
    pub tier_params: Vec<usize>,
    pub tier_requests: Vec<usize>,
    pub wall_s: f64,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.metrics.requests_done as f64 / self.wall_s.max(1e-9)
    }

    pub fn print(&self) {
        println!("== serving report ==");
        println!(
            "requests {}  batches {}  wall {:.2}s  throughput {:.1} req/s  occupancy {:.0}%",
            self.metrics.requests_done,
            self.metrics.batches,
            self.wall_s,
            self.throughput_rps(),
            self.metrics.mean_occupancy() * 100.0
        );
        for (i, &b) in self.tier_budgets.iter().enumerate() {
            let l = self.metrics.tier_latency(i);
            let e = self.metrics.tier_exec(i);
            println!(
                "tier {i} (budget {b:.2}, {:.2}M params, {} reqs): \
                 latency p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms | exec p50 {:.1}ms",
                self.tier_params[i] as f64 / 1e6,
                self.tier_requests[i],
                l.p50_ms,
                l.p95_ms,
                l.p99_ms,
                e.p50_ms,
            );
        }
    }

    pub fn to_json(&self) -> String {
        let tiers: Vec<Value> = self
            .tier_budgets
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                let l = self.metrics.tier_latency(i);
                json::obj(vec![
                    ("tier", Value::Num(i as f64)),
                    ("budget", Value::Num(b)),
                    ("params", Value::Num(self.tier_params[i] as f64)),
                    ("requests", Value::Num(self.tier_requests[i] as f64)),
                    ("latency_p50_ms", Value::Num(l.p50_ms)),
                    ("latency_p95_ms", Value::Num(l.p95_ms)),
                    ("latency_p99_ms", Value::Num(l.p99_ms)),
                    ("exec_p50_ms", Value::Num(self.metrics.tier_exec(i).p50_ms)),
                ])
            })
            .collect();
        json::to_string(&json::obj(vec![
            ("requests", Value::Num(self.metrics.requests_done as f64)),
            ("batches", Value::Num(self.metrics.batches as f64)),
            ("wall_s", Value::Num(self.wall_s)),
            ("throughput_rps", Value::Num(self.throughput_rps())),
            ("mean_occupancy", Value::Num(self.metrics.mean_occupancy())),
            ("tiers", Value::Arr(tiers)),
        ]))
    }
}

/// Execute one batch on a tier: pad tokens into the reusable buffer, run
/// the backend forward, record metrics.  Shared by the steady-state and
/// drain paths (they were previously copy-pasted).
fn run_batch<B: ServingBackend + ?Sized>(
    backend: &mut B,
    metrics: &mut Metrics,
    tokens: &mut Vec<i32>,
    lats: &mut Vec<Duration>,
    tier: usize,
    batch: &[Pending],
) -> Result<()> {
    let fill = batch.len();
    let (cap, seq) = (backend.batch(), backend.seq_len());
    tokens.clear();
    for p in batch {
        // A request with a wrong-length token window would shift every
        // later request's rows in the packed batch and silently corrupt
        // whose logits are whose — reject it loudly instead.
        ensure!(
            p.req.tokens.len() == seq,
            "request {} carries {} tokens but the serving seq_len is {seq}; \
             refusing to pack a misaligned batch",
            p.req.id,
            p.req.tokens.len()
        );
        tokens.extend_from_slice(&p.req.tokens);
    }
    tokens.resize(cap * seq, 0);
    let exec_t0 = Instant::now();
    let _logits = backend.infer(tier, tokens)?;
    let exec = exec_t0.elapsed();
    let done = Instant::now();
    lats.clear();
    lats.extend(batch.iter().map(|p| done.duration_since(p.enqueued)));
    metrics.record_batch(tier, fill, cap, exec, lats);
    Ok(())
}

/// Serve a trace to completion over a loaded serving backend (native
/// registry, PJRT registry, …) — the coordinator stack is backend-agnostic
/// above the [`ServingBackend`] seam.
pub fn serve_trace<B: ServingBackend + ?Sized>(
    backend: &mut B,
    trace: Vec<Request>,
    cfg: &ServeCfg,
) -> Result<ServeReport> {
    let n_tiers = backend.n_tiers();
    let policy = Policy::new(cfg.policy, n_tiers);
    let mut batcher = DynamicBatcher::new(
        n_tiers,
        backend.batch(),
        Duration::from_secs_f64(cfg.max_wait_ms / 1e3),
    );
    let mut metrics = Metrics::new(n_tiers);
    let mut tier_requests = vec![0usize; n_tiers];
    // Reused across batches so the hot path stays allocation-free.
    let mut tokens: Vec<i32> = Vec::with_capacity(backend.batch() * backend.seq_len());
    let mut lats: Vec<Duration> = Vec::with_capacity(backend.batch());

    // Budget-override contract: finite, in (0, 1].  A NaN or out-of-range
    // budget used to be silently mapped into some tier by the select
    // arithmetic — reject it loudly, and do it up front, before the ingest
    // thread spawns, so the abort leaves no detached replay thread behind.
    for req in &trace {
        if let Some(b) = req.budget {
            ensure!(
                b.is_finite() && b > 0.0 && b <= 1.0,
                "request {} carries budget {b} outside the (0, 1] \
                 contract; refusing to route it",
                req.id
            );
        }
    }

    // Ingest thread: replays arrivals on the trace's timeline.
    let (tx, rx) = mpsc::channel::<Request>();
    let replay = cfg.replay_speed;
    let ingest = std::thread::spawn(move || {
        let t0 = Instant::now();
        for req in trace {
            if replay > 0.0 {
                let due = Duration::from_secs_f64(req.arrival_s / replay);
                if let Some(sleep) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep);
                }
            }
            if tx.send(req).is_err() {
                break;
            }
        }
    });

    let start = Instant::now();
    let mut open = true;
    while open || batcher.depth() > 0 {
        // Drain arrivals (blocking briefly when idle so we don't spin).
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    let now = Instant::now();
                    let tier = policy.select(&req, batcher.depth());
                    tier_requests[tier] += 1;
                    batcher.push(tier, req, now);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        let now = Instant::now();
        if let Some(tier) = batcher.ready_tier(now) {
            let batch = batcher.take_batch(tier);
            run_batch(backend, &mut metrics, &mut tokens, &mut lats, tier, &batch)?;
        } else if open {
            // Idle: wait for the next deadline or a short poll tick.
            let wait = batcher
                .next_deadline(now)
                .unwrap_or(Duration::from_millis(1))
                .min(Duration::from_millis(2));
            std::thread::sleep(wait.max(Duration::from_micros(100)));
        } else if batcher.depth() > 0 {
            // Channel closed; force-flush what remains.  Drain oldest head
            // first — the same fairness rule `ready_tier` applies in steady
            // state — so shutdown tail-latency accounting is consistent
            // (the old deepest-queue-first pick left the longest-waiting
            // requests for last).
            let Some(tier) = batcher.oldest_head_tier() else { break };
            let batch = batcher.take_batch(tier);
            run_batch(backend, &mut metrics, &mut tokens, &mut lats, tier, &batch)?;
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    ingest.join().ok();

    Ok(ServeReport {
        metrics,
        tier_budgets: (0..n_tiers).map(|t| backend.tier_budget(t)).collect(),
        tier_params: (0..n_tiers).map(|t| backend.tier_params(t)).collect(),
        tier_requests,
        wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::SubmodelRegistry;
    use crate::data::trace::{Request, Slo};
    use crate::training::params::{decompose_teacher, random_teacher, student_from_factors};

    fn tiny_registry(seed: u64) -> (crate::runtime::ModelConfig, SubmodelRegistry) {
        let cfg = crate::config::load_model_config("tiny").unwrap();
        let teacher = random_teacher(&cfg, seed);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let registry = SubmodelRegistry::load_native(&cfg, &student, None).unwrap();
        (cfg, registry)
    }

    #[test]
    fn invalid_budget_override_fails_loudly() {
        // The select arithmetic used to map NaN to tier 0 and budgets > 1
        // to the top tier silently; ingest must reject anything outside the
        // documented (0, 1] contract, naming the offending request.
        let (cfg, mut registry) = tiny_registry(19);
        let req = |id: u64, budget: Option<f64>| Request {
            id,
            arrival_s: 0.0,
            slo: Slo::Standard,
            tokens: vec![1; cfg.seq_len],
            budget,
        };
        let scfg = ServeCfg { policy: PolicyKind::Static, max_wait_ms: 1.0, replay_speed: 0.0 };
        for bad in [f64::NAN, 0.0, -0.5, 1.5, f64::INFINITY] {
            let err = serve_trace(&mut registry, vec![req(7, Some(bad))], &scfg).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("request 7"), "must name the request ({bad}): {msg}");
            assert!(msg.contains("(0, 1]"), "must state the contract ({bad}): {msg}");
        }
        // In-contract budgets still serve.
        let report = serve_trace(
            &mut registry,
            vec![req(1, Some(0.3)), req(2, Some(1.0)), req(3, None)],
            &scfg,
        )
        .unwrap();
        assert_eq!(report.metrics.requests_done, 3);
    }

    #[test]
    fn malformed_request_length_fails_loudly() {
        let (cfg, mut registry) = tiny_registry(9);
        let good = |id: u64| Request {
            id,
            arrival_s: 0.0,
            slo: Slo::Standard,
            tokens: vec![1; cfg.seq_len],
            budget: None,
        };
        // Request 2 carries a truncated token window: without the length
        // check its rows silently shift request 3's logits in the packed
        // batch; with it the run must abort naming the offender.
        let mut bad = good(2);
        bad.tokens.truncate(cfg.seq_len - 3);
        let trace = vec![good(1), bad, good(3)];
        let err = serve_trace(
            &mut registry,
            trace,
            &ServeCfg { policy: PolicyKind::Static, max_wait_ms: 1.0, replay_speed: 0.0 },
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("request 2"), "error must name the request: {msg}");
        assert!(msg.contains("seq_len"), "error must explain the mismatch: {msg}");
    }
}
