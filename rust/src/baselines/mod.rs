//! Every comparison system in the paper's evaluation, reimplemented:
//!
//! * [`controlled`] — rust-net machinery for the controlled experiments
//!   (Figs. 3, 8, 9): dense digits teacher, DataSVD decomposition of rust
//!   nets with activation capture, independent-submodel training.
//! * [`transformer`] — transformer-scale baselines over the PJRT stack
//!   (Figs. 4, 5): plain weight-SVD, ACIP-like (frozen SVD + LoRA repair),
//!   LLM-Pruner-like (magnitude-criterion rank selection + recovery),
//!   LayerSkip-like (depth elasticity via block-zero profiles), and the
//!   independent-submodels-at-matched-budget baseline.
//!
//! PTS/ASL/NSL (Fig. 2) live in [`crate::flexrank::theory`] since they are
//! the paper's own theory objects.
//!
//! DESIGN.md §substitutions documents where each reimplementation differs
//! from the original system (all baselines run inside this repo's
//! factorized-transformer substrate rather than the authors' checkpoints).

pub mod controlled;
pub mod transformer;
