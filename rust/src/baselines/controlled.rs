//! Controlled-experiment machinery on pure-rust nets (Figs. 3, 8, 9).

use crate::data::Digits;
use crate::flexrank::decompose::{CovAccum, DataSvd};
use crate::flexrank::masks::RankProfile;
use crate::linalg::Mat;
use crate::nn::{accuracy, softmax_xent, Activation, Adam, FactLinear, Layer, LayerKind, Net};
use crate::rng::Rng;

/// Layer widths of the controlled 4-layer net (App. D.1 analogue).
pub const WIDTHS: [usize; 5] = [64, 32, 24, 16, 10];

/// Train a dense 4-layer teacher on digits; returns (net, test accuracy).
pub fn train_dense_teacher(d: &Digits, steps: usize, seed: u64) -> (Net, f64) {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for i in 0..WIDTHS.len() - 1 {
        let act = if i + 2 == WIDTHS.len() { Activation::None } else { Activation::Relu };
        layers.push(Layer::dense(WIDTHS[i], WIDTHS[i + 1], 0.15, act, &mut rng));
    }
    let mut net = Net::new(layers);
    let mut opt = Adam::new(4e-3);
    let batch = 64;
    for _ in 0..steps {
        let rows: Vec<usize> = (0..batch).map(|_| rng.below(d.x.rows)).collect();
        let xb = gather(&d.x, &rows);
        let yb: Vec<usize> = rows.iter().map(|&i| d.y[i]).collect();
        let (out, cache) = net.forward_cached(&xb, &[]);
        let (_l, g) = softmax_xent(&out, &yb);
        let grads = net.backward(&cache, &[], &g);
        opt.step(&mut net, &grads);
    }
    let acc = accuracy(&net.forward(&d.x_test, &[]), &d.y_test);
    (net, acc)
}

fn gather(m: &Mat, rows: &[usize]) -> Mat {
    let mut out = Mat::zeros(rows.len(), m.cols);
    for (dst, &src) in rows.iter().enumerate() {
        out.row_mut(dst).copy_from_slice(m.row(src));
    }
    out
}

/// Capture per-layer input activations of a dense net on `x`.
pub fn layer_inputs(net: &Net, x: &Mat) -> Vec<Mat> {
    let mut acts = vec![x.clone()];
    let mut cur = x.clone();
    for l in &net.layers {
        let (z, _) = match &l.kind {
            LayerKind::Dense { w, b } => {
                let mut z = &cur * w;
                for i in 0..z.rows {
                    for (zj, bj) in z.row_mut(i).iter_mut().zip(b) {
                        *zj += bj;
                    }
                }
                (z, ())
            }
            LayerKind::Fact(f) => {
                let mask = vec![1.0; f.rank()];
                (f.forward(&cur, &mask).0, ())
            }
        };
        let mut a = z;
        l.act.apply(&mut a);
        acts.push(a.clone());
        cur = a;
    }
    acts.pop(); // outputs of last layer are not anyone's input
    acts
}

/// DataSVD-decompose a dense net into a factorized student (same biases),
/// using activation covariances from `x_calib`.  `plain` = weight-SVD.
pub fn decompose_net(teacher: &Net, x_calib: &Mat, plain: bool) -> Net {
    let acts = layer_inputs(teacher, x_calib);
    let layers = teacher
        .layers
        .iter()
        .zip(&acts)
        .map(|(l, a)| match &l.kind {
            LayerKind::Dense { w, b } => {
                let d = if plain {
                    DataSvd::compute_plain(w)
                } else {
                    let mut cov = CovAccum::new(w.rows);
                    cov.add_batch(a);
                    DataSvd::compute(w, &cov, 1e-7)
                };
                Layer {
                    kind: LayerKind::Fact(FactLinear::from_factors(d.u, d.v, b.clone())),
                    act: l.act,
                }
            }
            LayerKind::Fact(_) => l.clone(),
        })
        .collect();
    Net::new(layers)
}

/// Random-init factorized net with the same architecture (Fig. 3 red).
pub fn random_student(seed: u64) -> Net {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for i in 0..WIDTHS.len() - 1 {
        let act = if i + 2 == WIDTHS.len() { Activation::None } else { Activation::Relu };
        let r = WIDTHS[i].min(WIDTHS[i + 1]);
        layers.push(Layer::fact(WIDTHS[i], WIDTHS[i + 1], r, 0.15, act, &mut rng));
    }
    Net::new(layers)
}

/// Train one submodel independently at a fixed profile (classification).
pub fn train_independent(
    mut net: Net,
    d: &Digits,
    profile: &RankProfile,
    steps: usize,
    seed: u64,
) -> (Net, f64, f64) {
    let mut rng = Rng::new(seed);
    let mut opt = Adam::new(4e-3);
    let batch = 64;
    for _ in 0..steps {
        let rows: Vec<usize> = (0..batch).map(|_| rng.below(d.x.rows)).collect();
        let xb = gather(&d.x, &rows);
        let yb: Vec<usize> = rows.iter().map(|&i| d.y[i]).collect();
        let (out, cache) = net.forward_cached(&xb, profile);
        let (_l, g) = softmax_xent(&out, &yb);
        let grads = net.backward(&cache, profile, &g);
        opt.step(&mut net, &grads);
    }
    let test_logits = net.forward(&d.x_test, profile);
    let acc = accuracy(&test_logits, &d.y_test);
    let (loss, _) = softmax_xent(&test_logits, &d.y_test);
    (net, acc, loss)
}

/// Test loss+accuracy at a profile.
pub fn eval_net(net: &Net, d: &Digits, profile: &RankProfile) -> (f64, f64) {
    let logits = net.forward(&d.x_test, profile);
    let (loss, _) = softmax_xent(&logits, &d.y_test);
    (loss, accuracy(&logits, &d.y_test))
}

/// Output-matching probe loss: MSE between the truncated student's logits
/// and reference logits (teacher / full student) on the test inputs — the
/// App. C.3 probing loss (smooth, no label noise).
pub fn eval_probe_mse(net: &Net, x: &Mat, reference: &Mat, profile: &RankProfile) -> f64 {
    let out = net.forward(x, profile);
    crate::nn::mse_loss(&out, reference).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn teacher_learns_digits() {
        let d = Digits::generate(500, 200, 31);
        let (_net, acc) = train_dense_teacher(&d, 250, 32);
        assert!(acc > 0.7, "teacher acc {acc}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn decomposition_preserves_function_at_full_rank() {
        let d = Digits::generate(200, 50, 33);
        let (teacher, _) = train_dense_teacher(&d, 100, 34);
        let student = decompose_net(&teacher, &d.x, false);
        let full: RankProfile = student.fact_ranks();
        let t_out = teacher.forward(&d.x_test, &[]);
        let s_out = student.forward(&d.x_test, &full);
        assert!(
            s_out.close_to(&t_out, 1e-5),
            "full-rank student diverges from teacher"
        );
    }

    #[test]
    fn layer_inputs_have_right_dims() {
        let d = Digits::generate(50, 10, 35);
        let (teacher, _) = train_dense_teacher(&d, 10, 36);
        let acts = layer_inputs(&teacher, &d.x);
        assert_eq!(acts.len(), 4);
        for (a, w) in acts.iter().zip(&WIDTHS) {
            assert_eq!(a.cols, *w);
        }
    }
}
