//! Transformer-scale baselines over the PJRT stack (Figs. 4, 5).
//!
//! Each reimplements the comparison system's *mechanism* inside this repo's
//! factorized substrate at matched training budget (Sec. 5 "comparison at
//! matched training budget"); DESIGN.md §substitutions records the mapping.

use anyhow::Result;

#[cfg(feature = "pjrt")]
use crate::data::TokenBatcher;
use crate::flexrank::masks::{gar_layer_params, RankProfile};
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::runtime::ModelConfig;
#[cfg(feature = "pjrt")]
use crate::training::driver;
use crate::training::params::{fact_layers, ParamSet};
#[cfg(feature = "pjrt")]
use crate::training::params::{decompose_teacher, student_from_factors};

/// Plain weight-SVD student (the "SVD" baseline of Fig. 4).
#[cfg(feature = "pjrt")]
pub fn plain_svd_student(engine: &Engine, teacher: &ParamSet) -> Result<ParamSet> {
    let cfg = engine.manifest.config.clone();
    let factors = decompose_teacher(&cfg, teacher, None)?;
    student_from_factors(&cfg, teacher, &factors)
}

/// LLM-Pruner-like profiles: *magnitude* criterion instead of data+DP.
/// Component importance = ‖u_i‖‖v_i‖ (the singular value of the balanced
/// factors); greedily keep the globally largest components until the budget
/// is filled.  Greedy prefixes are automatically nested.
pub fn magnitude_profiles(
    cfg: &ModelConfig,
    student: &ParamSet,
    budgets: &[f64],
) -> Result<Vec<RankProfile>> {
    let layers = fact_layers(cfg);
    // Collect (importance, layer) per component.
    let mut comps: Vec<(f64, usize)> = Vec::new();
    for (li, (b, kind, _n, _m)) in layers.iter().enumerate() {
        let u = student.mat(&format!("blocks.{b}.{kind}_u"))?;
        let v = student.mat(&format!("blocks.{b}.{kind}_v"))?;
        for c in 0..u.cols {
            let nu: f64 = (0..u.rows).map(|i| u[(i, c)] * u[(i, c)]).sum::<f64>().sqrt();
            let nv: f64 = (0..v.rows).map(|i| v[(i, c)] * v[(i, c)]).sum::<f64>().sqrt();
            comps.push((nu * nv, li));
        }
    }
    comps.sort_by(|a, b| b.0.total_cmp(&a.0));

    let full_cost: usize = layers
        .iter()
        .map(|&(_, _, n, m)| gar_layer_params(n, m, cfg.rank_full()))
        .sum();

    let mut profiles = Vec::with_capacity(budgets.len());
    for &beta in budgets {
        let cap = (beta * full_cost as f64).round() as usize;
        let mut ranks = vec![0usize; layers.len()];
        let mut cost = 0usize;
        for &(_, li) in &comps {
            let (_, _, n, m) = layers[li];
            let new_cost =
                cost - gar_layer_params(n, m, ranks[li]) + gar_layer_params(n, m, ranks[li] + 1);
            if new_cost > cap {
                continue;
            }
            cost = new_cost;
            ranks[li] += 1;
        }
        // Every layer needs at least rank 1 to keep the network connected.
        for (li, r) in ranks.iter_mut().enumerate() {
            if *r == 0 {
                let _ = li;
                *r = 1;
            }
        }
        profiles.push(ranks);
    }
    Ok(profiles)
}

/// LayerSkip-like profiles: depth elasticity — trailing blocks are zeroed
/// entirely (rank 0 on all four surfaces ⇒ the block collapses to its
/// residual path), leading blocks stay full rank.
pub fn layerskip_profiles(cfg: &ModelConfig, budgets: &[f64]) -> Vec<RankProfile> {
    let n_blocks = cfg.n_blocks;
    budgets
        .iter()
        .map(|&beta| {
            let keep = ((beta * n_blocks as f64).ceil() as usize).clamp(1, n_blocks);
            let mut prof = Vec::with_capacity(cfg.n_fact_layers());
            for b in 0..n_blocks {
                let r = if b < keep { cfg.rank_full() } else { 0 };
                prof.extend([r; 4]);
            }
            prof
        })
        .collect()
}

/// Independent-submodels baseline (Fig. 5 dashed): train each budget's
/// submodel separately from the same init, splitting the total step budget
/// evenly.  Returns per-budget (profile, eval loss).
#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
pub fn independent_submodels(
    engine: &Engine,
    student0: &ParamSet,
    teacher: &ParamSet,
    profiles: &[RankProfile],
    total_steps: usize,
    batcher: &mut TokenBatcher,
    eval_batches: &[Vec<i32>],
    seed: u64,
) -> Result<Vec<f64>> {
    let per = (total_steps / profiles.len()).max(1);
    let mut out = Vec::with_capacity(profiles.len());
    for (i, prof) in profiles.iter().enumerate() {
        let run = driver::consolidate(
            engine,
            student0.clone(),
            teacher,
            std::slice::from_ref(prof),
            &[1.0],
            batcher,
            per,
            seed ^ (i as u64 * 0x9e37),
            0,
        )?;
        let loss = driver::eval_student(engine, &run.params, prof, eval_batches)?;
        out.push(loss);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::load_model_config;

    #[test]
    fn layerskip_profiles_shape() {
        let cfg = load_model_config("base").unwrap();
        let profs = layerskip_profiles(&cfg, &[0.25, 0.5, 1.0]);
        assert_eq!(profs.len(), 3);
        // 25% of 4 blocks = 1 block kept.
        assert_eq!(profs[0][..4], [128, 128, 128, 128]);
        assert!(profs[0][4..].iter().all(|&r| r == 0));
        assert!(profs[2].iter().all(|&r| r == 128));
        // Nested in the chain sense.
        assert!(crate::flexrank::masks::is_nested(&profs[0], &profs[1]));
    }
}
