//! Static invariant linter (`repro lint`).
//!
//! A dependency-free lexer + rules engine that walks `rust/src` and
//! machine-checks the invariants the ROADMAP otherwise enforces only by
//! convention and runtime tests:
//!
//! * **R1 `safety`** — every `unsafe` block / fn / impl must be preceded
//!   (within 8 lines) by a `// SAFETY:` comment stating the invariant it
//!   relies on.
//! * **R2 `hot_path`** — hot-path modules (`coordinator::listener`,
//!   `coordinator::batcher`, `json::pull`, `data::trace::wire`,
//!   `runtime::kvcache`, and the decode/infer fns of `runtime::native`)
//!   may not call `unwrap` / `expect` / `panic!` / `Vec::new` / `vec!` /
//!   `Box::new` / `.to_vec` / `format!` / `String::from`.
//! * **R3 `json_value`** — the tree-building `json::Value` is banned from
//!   ingest modules; request bodies go through the pull parser.
//! * **R4 `float_cmp`** — float ordering uses `total_cmp`, never
//!   `partial_cmp(..).unwrap()` (crate-wide outside tests).
//!
//! Escape hatch: an inline marker of the form
//!
//! ```text
//! // lint: allow(<rule>) -- <reason>
//! ```
//!
//! on the offending line or the line above suppresses that one rule there;
//! the reason is mandatory.  Test code (`#[cfg(test)]` modules / `#[test]`
//! fns) is exempt from every rule.  Fixture files under
//! `src/analysis/fixtures/` carry a `// lint: module = <path>` directive so
//! they lint as if they lived in the module they imitate; the default walk
//! skips that directory, and explicit `repro lint <path>` arguments do not.

pub mod lexer;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use self::lexer::{lex, Kind, Tok};

/// Modules under the zero-alloc / no-panic serving contract (R2).
/// A module matches exactly or by `::` prefix.
const HOT_MODULES: &[&str] = &[
    "coordinator::listener",
    "coordinator::batcher",
    "coordinator::controller",
    "json::pull",
    "data::trace::wire",
    "runtime::kvcache",
];

/// In `runtime::native` only the serving forward/decode fns are hot —
/// construction (`from_student`) may allocate freely.
const NATIVE_HOT_FNS: &[&str] =
    &["forward", "forward_window", "forward_into", "prefill", "decode_step", "forward_incremental"];

/// Ingest modules where the tree-building `json::Value` is banned (R3).
const INGEST_MODULES: &[&str] = &["json::pull", "data::trace::wire", "coordinator::listener"];

/// How far above an `unsafe` token a `// SAFETY:` comment may sit (lines).
/// Room for an attribute or a two-line fn signature in between.
const SAFETY_WINDOW: u32 = 8;

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: PathBuf,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.msg)
    }
}

/// An inline `// lint: allow(rule) -- reason` marker.
struct Allow {
    rule: String,
    /// Lines the marker covers: its own line and the next.
    line: u32,
}

/// Scope element pushed at `{`.
struct Scope {
    /// Inline `mod name` segment, if this brace opened one.
    mod_seg: Option<String>,
    /// Fn name, if this brace opened a fn body.
    fn_name: Option<String>,
    /// Inside `#[cfg(test)]` / `#[test]` — every rule is off.
    test: bool,
}

/// Lint one file.  `default_module` is the module path derived from the
/// file's location (overridden by a `// lint: module = …` directive).
pub fn lint_source(src: &str, default_module: &str, file: &Path) -> Vec<Finding> {
    let toks = lex(src);
    let mut findings = Vec::new();

    // ---- comment pass: SAFETY lines, allow markers, module directive ----
    let mut safety_lines: Vec<u32> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    let mut file_module = default_module.to_string();
    for t in &toks {
        if t.kind != Kind::LineComment && t.kind != Kind::BlockComment {
            continue;
        }
        let text = t.text(src);
        if text.contains("SAFETY:") {
            safety_lines.push(t.end_line);
        }
        if let Some(at) = text.find("lint:") {
            let body = text[at + 5..].trim();
            if let Some(rest) = body.strip_prefix("allow(") {
                if let Some(close) = rest.find(')') {
                    let rule = rest[..close].trim().to_string();
                    let reason = rest[close + 1..].trim();
                    if let Some(why) = reason.strip_prefix("--") {
                        if !why.trim().is_empty() {
                            allows.push(Allow { rule, line: t.line });
                            continue;
                        }
                    }
                    findings.push(Finding {
                        file: file.to_path_buf(),
                        line: t.line,
                        rule: "marker",
                        msg: format!(
                            "allow({rule}) marker needs a justification: \
                             `// lint: allow({rule}) -- <reason>`"
                        ),
                    });
                }
            } else if let Some(rest) = body.strip_prefix("module") {
                if let Some(path) = rest.trim_start().strip_prefix('=') {
                    file_module = path.trim().to_string();
                }
            }
        }
    }
    let allowed = |rule: &str, line: u32| {
        allows.iter().any(|a| a.rule == rule && (a.line == line || a.line + 1 == line))
    };
    let mut push = |findings: &mut Vec<Finding>, rule: &'static str, line: u32, msg: String| {
        if !allowed(rule, line)
            && !findings.iter().any(|f: &Finding| f.rule == rule && f.line == line)
        {
            findings.push(Finding { file: file.to_path_buf(), line, rule, msg });
        }
    };

    // ---- code pass: scopes + rules ----
    let code: Vec<&Tok> = toks
        .iter()
        .filter(|t| t.kind != Kind::LineComment && t.kind != Kind::BlockComment)
        .collect();
    let punct = |i: usize, ch: u8| -> bool {
        code.get(i).is_some_and(|t| t.kind == Kind::Punct && src.as_bytes()[t.start] == ch)
    };
    let ident_at = |i: usize| -> Option<&str> {
        code.get(i).and_then(|t| (t.kind == Kind::Ident).then(|| t.text(src)))
    };

    let mut scopes: Vec<Scope> = Vec::new();
    let mut pending_mod: Option<(String, bool)> = None;
    let mut pending_fn: Option<(String, bool)> = None;
    let mut pending_test_attr = false;
    let mut paren_depth = 0i32;

    let mut i = 0usize;
    while i < code.len() {
        let t = code[i];
        let in_test = pending_test_attr || scopes.iter().any(|s| s.test);
        match t.kind {
            Kind::Punct => {
                let c = src.as_bytes()[t.start];
                match c {
                    b'#' => {
                        // Attribute: skip `#[…]` / `#![…]` wholesale, noting
                        // `cfg(test)` / `test`.
                        let mut j = i + 1;
                        if punct(j, b'!') {
                            j += 1;
                        }
                        if punct(j, b'[') {
                            let mut depth = 0i32;
                            let mut is_test = false;
                            while j < code.len() {
                                if punct(j, b'[') {
                                    depth += 1;
                                } else if punct(j, b']') {
                                    depth -= 1;
                                    if depth == 0 {
                                        break;
                                    }
                                } else if ident_at(j) == Some("test") {
                                    is_test = true;
                                }
                                j += 1;
                            }
                            // `#[test]` and `#[cfg(test)]` mark test code;
                            // `#[cfg(not(test))]` also names `test` but gates
                            // *non*-test code, so exclude the negated form.
                            let attr_src = &src[t.start..code[j.min(code.len() - 1)].end];
                            if is_test && !attr_src.contains("not(") {
                                pending_test_attr = true;
                            }
                            i = j + 1;
                            continue;
                        }
                    }
                    b'(' => paren_depth += 1,
                    b')' => paren_depth -= 1,
                    b';' => {
                        if paren_depth == 0 {
                            pending_fn = None;
                            pending_mod = None;
                            pending_test_attr = false;
                        }
                    }
                    b'{' => {
                        let (mod_seg, fn_name, own_test) = if let Some((m, tst)) =
                            pending_mod.take()
                        {
                            (Some(m), None, tst)
                        } else if let Some((f, tst)) = pending_fn.take() {
                            (None, Some(f), tst)
                        } else {
                            // A `#[cfg(test)]` on an impl/const block lands
                            // here: the brace consumes the pending flag.
                            (None, None, std::mem::take(&mut pending_test_attr))
                        };
                        let parent_test = scopes.iter().any(|s| s.test);
                        scopes.push(Scope { mod_seg, fn_name, test: parent_test || own_test });
                    }
                    b'}' => {
                        scopes.pop();
                    }
                    _ => {}
                }
            }
            Kind::Ident => {
                let id = t.text(src);
                match id {
                    "mod" => {
                        if let Some(name) = ident_at(i + 1) {
                            pending_mod = Some((name.to_string(), pending_test_attr));
                            pending_test_attr = false;
                            i += 2;
                            continue;
                        }
                    }
                    "fn" => {
                        if let Some(name) = ident_at(i + 1) {
                            pending_fn = Some((name.to_string(), pending_test_attr));
                            pending_test_attr = false;
                            i += 2;
                            continue;
                        }
                    }
                    _ if in_test => {}
                    "unsafe" => {
                        let lo = t.line.saturating_sub(SAFETY_WINDOW);
                        let covered =
                            safety_lines.iter().any(|&l| l >= lo && l <= t.line);
                        if !covered {
                            push(
                                &mut findings,
                                "safety",
                                t.line,
                                "`unsafe` without a `// SAFETY:` comment stating its \
                                 invariant (within the preceding 8 lines)"
                                    .to_string(),
                            );
                        }
                    }
                    "use" => {
                        // R3 at the import: `use …json…::{…, Value, …}`.
                        let module = module_path(&file_module, &scopes);
                        if is_ingest(&module) {
                            let mut j = i + 1;
                            let mut saw_json = false;
                            while j < code.len() && !punct(j, b';') {
                                match ident_at(j) {
                                    Some("json") => saw_json = true,
                                    Some("Value") if saw_json => {
                                        push(
                                            &mut findings,
                                            "json_value",
                                            code[j].line,
                                            format!(
                                                "`json::Value` imported in ingest module \
                                                 `{module}` — request parsing must stay on \
                                                 the pull parser"
                                            ),
                                        );
                                    }
                                    _ => {}
                                }
                                j += 1;
                            }
                        }
                    }
                    _ => {
                        let module = module_path(&file_module, &scopes);
                        check_code_ident(
                            src, &code, i, t, id, &module, &scopes, &mut findings, &mut push,
                        );
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    findings
}

/// Rules that fire on an ordinary (non-keyword) ident in non-test code.
#[allow(clippy::too_many_arguments)]
fn check_code_ident(
    src: &str,
    code: &[&Tok],
    i: usize,
    t: &Tok,
    id: &str,
    module: &str,
    scopes: &[Scope],
    findings: &mut Vec<Finding>,
    push: &mut impl FnMut(&mut Vec<Finding>, &'static str, u32, String),
) {
    let punct = |i: usize, ch: u8| -> bool {
        code.get(i).is_some_and(|t| t.kind == Kind::Punct && src.as_bytes()[t.start] == ch)
    };
    let ident_at = |i: usize| -> Option<&str> {
        code.get(i).and_then(|t| (t.kind == Kind::Ident).then(|| t.text(src)))
    };
    let prev_dot = i > 0 && punct(i - 1, b'.');
    let next_bang = punct(i + 1, b'!');
    let path_new = |what: &str| -> bool {
        punct(i + 1, b':') && punct(i + 2, b':') && ident_at(i + 3) == Some(what)
    };

    // R4: `.partial_cmp(…).unwrap()` / `.expect(` — crate-wide.
    if id == "partial_cmp" && prev_dot && punct(i + 1, b'(') {
        let mut j = i + 1;
        let mut depth = 0i32;
        while j < code.len() {
            if punct(j, b'(') {
                depth += 1;
            } else if punct(j, b')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            j += 1;
        }
        if punct(j + 1, b'.') && matches!(ident_at(j + 2), Some("unwrap") | Some("expect")) {
            push(
                findings,
                "float_cmp",
                t.line,
                "float ordering via `partial_cmp(..).unwrap()` — use `total_cmp` \
                 (NaN-safe, total order)"
                    .to_string(),
            );
        }
        return;
    }

    // R3 fully-qualified use: `json::Value` anywhere in an ingest module.
    if id == "Value"
        && is_ingest(module)
        && i >= 3
        && punct(i - 1, b':')
        && punct(i - 2, b':')
        && ident_at(i - 3) == Some("json")
    {
        push(
            findings,
            "json_value",
            t.line,
            format!(
                "`json::Value` used in ingest module `{module}` — request parsing \
                 must stay on the pull parser"
            ),
        );
        return;
    }

    // R2: banned calls in hot modules.
    if !is_hot(module, scopes) {
        return;
    }
    let hit: Option<&str> = match id {
        "unwrap" | "expect" if prev_dot && punct(i + 1, b'(') => Some("panics on the hot path"),
        "panic" if next_bang => Some("panics on the hot path"),
        "vec" | "format" if next_bang => Some("allocates on the hot path"),
        "to_vec" if prev_dot => Some("allocates on the hot path"),
        "Vec" | "Box" if path_new("new") => Some("allocates on the hot path"),
        "String" if path_new("from") => Some("allocates on the hot path"),
        _ => None,
    };
    if let Some(why) = hit {
        let what = match id {
            "Vec" => "Vec::new".to_string(),
            "Box" => "Box::new".to_string(),
            "String" => "String::from".to_string(),
            "panic" | "vec" | "format" => format!("{id}!"),
            _ => format!(".{id}()"),
        };
        push(
            findings,
            "hot_path",
            t.line,
            format!(
                "`{what}` in hot module `{module}` — {why}; return an error / reuse a \
                 buffer, or justify with `// lint: allow(hot_path) -- <reason>`"
            ),
        );
    }
}

/// Full module path: file-derived path plus inline `mod` segments.
fn module_path(file_module: &str, scopes: &[Scope]) -> String {
    let mut path = file_module.to_string();
    for s in scopes {
        if let Some(m) = &s.mod_seg {
            if !path.is_empty() {
                path.push_str("::");
            }
            path.push_str(m);
        }
    }
    path
}

fn matches_module(module: &str, pat: &str) -> bool {
    module == pat || module.starts_with(&format!("{pat}::"))
}

fn is_ingest(module: &str) -> bool {
    INGEST_MODULES.iter().any(|m| matches_module(module, m))
}

fn is_hot(module: &str, scopes: &[Scope]) -> bool {
    if HOT_MODULES.iter().any(|m| matches_module(module, m)) {
        return true;
    }
    if matches_module(module, "runtime::native") {
        // Only the serving forward/decode fns; innermost named fn decides.
        if let Some(name) = scopes.iter().rev().find_map(|s| s.fn_name.as_deref()) {
            return NATIVE_HOT_FNS.contains(&name);
        }
    }
    false
}

/// Derive a module path from a file path: everything after the last `src/`
/// component, `lib.rs`/`main.rs` → crate root, `mod.rs` → its directory.
pub fn module_from_path(path: &Path) -> String {
    let mut comps: Vec<String> = Vec::new();
    let mut after_src = false;
    for c in path.components() {
        let s = c.as_os_str().to_string_lossy().to_string();
        if s == "src" {
            after_src = true;
            comps.clear();
            continue;
        }
        if after_src {
            comps.push(s);
        }
    }
    if !after_src {
        return String::new();
    }
    if let Some(last) = comps.last_mut() {
        let trimmed = last.strip_suffix(".rs").map(str::to_string);
        if let Some(t) = trimmed {
            *last = t;
        }
    }
    if comps.last().is_some_and(|l| matches!(l.as_str(), "lib" | "main" | "mod")) {
        comps.pop();
    }
    comps.join("::")
}

/// Lint one file from disk.
pub fn lint_file(path: &Path) -> Result<Vec<Finding>> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("repro lint: reading {}", path.display()))?;
    Ok(lint_source(&src, &module_from_path(path), path))
}

/// Recursively collect `.rs` files under `dir`, skipping the linter's own
/// fixture corpus (those files *seed* violations).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("repro lint: walking {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            if p.file_name().is_some_and(|n| n == "fixtures")
                && p.parent().and_then(|d| d.file_name()).is_some_and(|n| n == "analysis")
            {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `dir`.
pub fn lint_dir(dir: &Path) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(dir, &mut files)?;
    let mut findings = Vec::new();
    for f in &files {
        findings.extend(lint_file(f)?);
    }
    Ok(findings)
}

/// `repro lint [path…]` — lint the crate sources (default `src/` next to
/// the manifest) or explicit files/directories; nonzero exit on findings.
pub fn run_cli(args: &Args) -> Result<()> {
    let targets: Vec<PathBuf> = if args.positional.is_empty() {
        vec![Path::new(env!("CARGO_MANIFEST_DIR")).join("src")]
    } else {
        args.positional.iter().map(PathBuf::from).collect()
    };
    let mut findings = Vec::new();
    let mut n_files = 0usize;
    for t in &targets {
        if t.is_dir() {
            let mut files = Vec::new();
            collect_rs(t, &mut files)?;
            n_files += files.len();
            for f in &files {
                findings.extend(lint_file(f)?);
            }
        } else {
            n_files += 1;
            findings.extend(lint_file(t)?);
        }
    }
    for f in &findings {
        println!("{f}");
    }
    if !findings.is_empty() {
        bail!("repro lint: {} finding(s) across {} file(s)", findings.len(), n_files);
    }
    println!("repro lint: clean ({n_files} files, rules R1 safety / R2 hot_path / R3 json_value / R4 float_cmp)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("src/analysis/fixtures").join(name)
    }

    fn rules(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn fixture_r1_unsafe_without_safety_fires_once() {
        let f = lint_file(&fixture("r1_missing_safety.rs")).unwrap();
        assert_eq!(rules(&f), ["safety"], "{f:?}");
    }

    #[test]
    fn fixture_r2_hot_path_alloc_fires_once() {
        let f = lint_file(&fixture("r2_hot_path_unwrap.rs")).unwrap();
        assert_eq!(rules(&f), ["hot_path"], "{f:?}");
    }

    #[test]
    fn fixture_r3_json_value_fires_once() {
        let f = lint_file(&fixture("r3_json_value_ingest.rs")).unwrap();
        assert_eq!(rules(&f), ["json_value"], "{f:?}");
    }

    #[test]
    fn fixture_r4_partial_cmp_fires_once() {
        let f = lint_file(&fixture("r4_partial_cmp_unwrap.rs")).unwrap();
        assert_eq!(rules(&f), ["float_cmp"], "{f:?}");
    }

    #[test]
    fn whole_crate_is_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let findings = lint_dir(&src).unwrap();
        assert!(
            findings.is_empty(),
            "repro lint found {} violation(s) in the crate:\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn safety_comment_within_window_passes() {
        let src = "// SAFETY: len checked above\npub fn f(x: &[f32]) -> f32 {\n    unsafe { *x.get_unchecked(0) }\n}\n";
        let f = lint_source(src, "linalg::demo", Path::new("demo.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn safety_comment_too_far_fails() {
        let blank = "\n".repeat(10);
        let src = format!(
            "// SAFETY: stale, ten lines up\n{blank}pub fn f(x: &[f32]) -> f32 {{\n    unsafe {{ *x.get_unchecked(0) }}\n}}\n"
        );
        let f = lint_source(&src, "linalg::demo", Path::new("demo.rs"));
        assert_eq!(rules(&f), ["safety"]);
    }

    #[test]
    fn cfg_test_module_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(v: Option<u32>) -> u32 { v.unwrap() }\n}\n";
        let f = lint_source(src, "coordinator::batcher", Path::new("demo.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn unwrap_or_else_is_not_unwrap() {
        let src = "pub fn f(v: Option<u32>) -> u32 { v.unwrap_or_else(|| 0).max(v.unwrap_or(1)) }\n";
        let f = lint_source(src, "coordinator::listener", Path::new("demo.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn allow_marker_suppresses_only_its_rule() {
        let src = "pub fn f() -> Vec<u32> {\n    // lint: allow(hot_path) -- construction-time, not per-request\n    Vec::new()\n}\n";
        let f = lint_source(src, "runtime::kvcache", Path::new("demo.rs"));
        assert!(f.is_empty(), "{f:?}");
        let wrong = src.replace("allow(hot_path)", "allow(float_cmp)");
        let f = lint_source(&wrong, "runtime::kvcache", Path::new("demo.rs"));
        assert_eq!(rules(&f), ["hot_path"]);
    }

    #[test]
    fn allow_marker_requires_reason() {
        let src = "pub fn f() -> Vec<u32> {\n    // lint: allow(hot_path)\n    Vec::new()\n}\n";
        let f = lint_source(src, "runtime::kvcache", Path::new("demo.rs"));
        assert_eq!(rules(&f), ["marker", "hot_path"], "{f:?}");
    }

    #[test]
    fn native_hot_fns_are_scoped() {
        let hot = "impl M {\n    pub fn decode_step(&self) { let v: Vec<u32> = Vec::new(); let _ = v; }\n}\n";
        let f = lint_source(hot, "runtime::native", Path::new("demo.rs"));
        assert_eq!(rules(&f), ["hot_path"]);
        let cold = hot.replace("decode_step", "from_student");
        let f = lint_source(&cold, "runtime::native", Path::new("demo.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn inline_wire_module_is_hot() {
        let src = "pub mod wire {\n    pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n}\n";
        let f = lint_source(src, "data::trace", Path::new("demo.rs"));
        assert_eq!(rules(&f), ["hot_path"]);
    }

    #[test]
    fn module_paths_from_file_paths() {
        assert_eq!(module_from_path(Path::new("rust/src/coordinator/listener.rs")), "coordinator::listener");
        assert_eq!(module_from_path(Path::new("rust/src/lib.rs")), "");
        assert_eq!(module_from_path(Path::new("rust/src/json/mod.rs")), "json");
        assert_eq!(module_from_path(Path::new("src/data/trace.rs")), "data::trace");
    }
}
