// Linter fixture (not compiled into the crate): R2 must fire exactly once —
// a bare `.unwrap()` in a hot-path module with no allow marker.
// lint: module = coordinator::batcher

pub fn head_id(ids: &[u64]) -> u64 {
    ids.first().copied().unwrap()
}
