// Linter fixture (not compiled into the crate): R1 must fire exactly once
// on the unannotated unsafe block below.  The commented invariant keyword
// is deliberately absent everywhere in this file.
// lint: module = linalg::fixture

pub fn first_unchecked(xs: &[f32]) -> f32 {
    unsafe { *xs.get_unchecked(0) }
}
