// Linter fixture (not compiled into the crate): R4 must fire exactly once —
// float ordering through `partial_cmp(..).unwrap()` instead of `total_cmp`.
// lint: module = eval::fixture

pub fn max_val(xs: &[f64]) -> f64 {
    let mut m = f64::NEG_INFINITY;
    for &x in xs {
        if x.partial_cmp(&m).unwrap() == std::cmp::Ordering::Greater {
            m = x;
        }
    }
    m
}
