// Linter fixture (not compiled into the crate): R3 must fire exactly once —
// the tree-building `json::Value` imported into an ingest module.
// lint: module = json::pull

use crate::json::Value;

pub fn stash(v: Value) -> Value {
    v
}
