//! A minimal hand-rolled Rust lexer for the invariant linter.
//!
//! Produces a flat token stream with comments retained (the rules engine
//! reads safety comments and allow markers out of them) and with
//! enough literal-awareness that `unsafe` inside a string, a nested block
//! comment, or a raw string never reads as code.  It is deliberately *not*
//! a full Rust lexer: multi-character operators come out as single `Punct`
//! tokens (`::` is two `:`), and numeric edge cases collapse into whatever
//! neighboring tokens they produce — none of which the rules care about.

/// Token class.  `Punct` is one byte of punctuation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Num,
    Str,
    Char,
    Lifetime,
    LineComment,
    BlockComment,
}

/// One token: byte range into the source plus 1-based line numbers.
#[derive(Debug, Clone, Copy)]
pub struct Tok {
    pub kind: Kind,
    pub start: usize,
    pub end: usize,
    /// Line the token starts on (1-based).
    pub line: u32,
    /// Line the token ends on (equal to `line` except for block
    /// comments and multi-line strings).
    pub end_line: u32,
}

impl Tok {
    pub fn text<'a>(&self, src: &'a str) -> &'a str {
        &src[self.start..self.end]
    }
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic()
}

fn is_ident_continue(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Tokenize `src`.  Never panics on malformed input: an unterminated
/// literal or comment simply swallows the rest of the file.
pub fn lex(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::with_capacity(n / 6 + 16);
    let mut i = 0usize;
    let mut line: u32 = 1;

    // Count newlines in b[from..to), returning the line the range ends on.
    let lines_in = |from: usize, to: usize, start_line: u32| -> u32 {
        let mut l = start_line;
        for &c in &b[from..to] {
            if c == b'\n' {
                l += 1;
            }
        }
        l
    };

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }

        // Comments.
        if c == b'/' && i + 1 < n {
            if b[i + 1] == b'/' {
                let start = i;
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
                toks.push(Tok { kind: Kind::LineComment, start, end: i, line, end_line: line });
                continue;
            }
            if b[i + 1] == b'*' {
                let start = i;
                let start_line = line;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                toks.push(Tok {
                    kind: Kind::BlockComment,
                    start,
                    end: i,
                    line: start_line,
                    end_line: line,
                });
                continue;
            }
        }

        // String-literal prefixes: b" r" c" br" cr" and raw r#"…"#.
        if is_ident_start(c) {
            let rest = &b[i..];
            let mut matched = false;
            for pref in [&b"br"[..], &b"cr"[..], &b"b"[..], &b"c"[..], &b"r"[..]] {
                if rest.len() <= pref.len() || !rest.starts_with(pref) {
                    continue;
                }
                let after = rest[pref.len()];
                // "r", "br", "cr" introduce raw strings; "b", "c" cooked ones.
                let raw_capable = pref[pref.len() - 1] == b'r';
                if after == b'"' && !raw_capable {
                    // Cooked string with escapes.
                    let start = i;
                    let start_line = line;
                    i += pref.len() + 1;
                    while i < n {
                        match b[i] {
                            b'\\' => i = (i + 2).min(n),
                            b'"' => {
                                i += 1;
                                break;
                            }
                            _ => i += 1,
                        }
                    }
                    line = lines_in(start, i, start_line);
                    toks.push(Tok { kind: Kind::Str, start, end: i, line: start_line, end_line: line });
                    matched = true;
                    break;
                }
                if raw_capable && (after == b'"' || after == b'#') {
                    // Raw string: count hashes, then scan for `"` + hashes.
                    let mut j = i + pref.len();
                    let mut hashes = 0usize;
                    while j < n && b[j] == b'#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && b[j] == b'"' {
                        let start = i;
                        let start_line = line;
                        j += 1;
                        'scan: while j < n {
                            if b[j] == b'"' {
                                let mut k = 0usize;
                                while k < hashes && j + 1 + k < n && b[j + 1 + k] == b'#' {
                                    k += 1;
                                }
                                if k == hashes {
                                    j += 1 + hashes;
                                    break 'scan;
                                }
                            }
                            j += 1;
                        }
                        i = j;
                        line = lines_in(start, i, start_line);
                        toks.push(Tok {
                            kind: Kind::Str,
                            start,
                            end: i,
                            line: start_line,
                            end_line: line,
                        });
                        matched = true;
                        break;
                    }
                }
            }
            if matched {
                continue;
            }

            // Plain identifier / keyword.
            let start = i;
            while i < n && is_ident_continue(b[i]) {
                i += 1;
            }
            toks.push(Tok { kind: Kind::Ident, start, end: i, line, end_line: line });
            continue;
        }

        // Plain string literal.
        if c == b'"' {
            let start = i;
            let start_line = line;
            i += 1;
            while i < n {
                match b[i] {
                    b'\\' => i = (i + 2).min(n),
                    b'"' => {
                        i += 1;
                        break;
                    }
                    _ => i += 1,
                }
            }
            line = lines_in(start, i, start_line);
            toks.push(Tok { kind: Kind::Str, start, end: i, line: start_line, end_line: line });
            continue;
        }

        // Char literal vs lifetime.
        if c == b'\'' {
            let start = i;
            if i + 1 < n && b[i + 1] == b'\\' {
                // Escaped char literal: the char after the backslash is the
                // escapee even when it is `\` or `'` (so `'\\'` and `'\''`
                // close correctly); `\u{…}` then runs to the quote.
                i = (i + 3).min(n);
                while i < n && b[i] != b'\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                toks.push(Tok { kind: Kind::Char, start, end: i, line, end_line: line });
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' {
                // 'x' — a one-byte char literal (covers '_' too).
                i += 3;
                toks.push(Tok { kind: Kind::Char, start, end: i, line, end_line: line });
                continue;
            }
            // Lifetime: 'ident (no closing quote).
            i += 1;
            while i < n && is_ident_continue(b[i]) {
                i += 1;
            }
            toks.push(Tok { kind: Kind::Lifetime, start, end: i, line, end_line: line });
            continue;
        }

        // Numeric literal.
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            if i < n && (b[i] == b'x' || b[i] == b'o' || b[i] == b'b') && c == b'0' {
                i += 1;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
            } else {
                while i < n && (b[i].is_ascii_digit() || b[i] == b'_') {
                    i += 1;
                }
                // Fraction only when `.` is followed by a digit (so `1.max`
                // and `0..n` lex as separate tokens).
                if i + 1 < n && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < n && (b[i].is_ascii_digit() || b[i] == b'_') {
                        i += 1;
                    }
                }
                // Exponent.
                if i < n && (b[i] == b'e' || b[i] == b'E') {
                    let mut j = i + 1;
                    if j < n && (b[j] == b'+' || b[j] == b'-') {
                        j += 1;
                    }
                    if j < n && b[j].is_ascii_digit() {
                        i = j;
                        while i < n && (b[i].is_ascii_digit() || b[i] == b'_') {
                            i += 1;
                        }
                    }
                }
                // Type suffix (f32, usize, …).
                while i < n && is_ident_continue(b[i]) {
                    i += 1;
                }
            }
            toks.push(Tok { kind: Kind::Num, start, end: i, line, end_line: line });
            continue;
        }

        // Everything else: one byte of punctuation.
        toks.push(Tok { kind: Kind::Punct, start: i, end: i + 1, line, end_line: line });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).iter().map(|t| (t.kind, t.text(src).to_string())).collect()
    }

    #[test]
    fn idents_and_punct() {
        let ks = kinds("let x = a.unwrap();");
        let idents: Vec<_> =
            ks.iter().filter(|(k, _)| *k == Kind::Ident).map(|(_, s)| s.as_str()).collect();
        assert_eq!(idents, ["let", "x", "a", "unwrap"]);
    }

    #[test]
    fn unsafe_in_string_is_not_an_ident() {
        let ks = kinds(r#"let s = "unsafe { }"; call();"#);
        assert!(!ks.iter().any(|(k, s)| *k == Kind::Ident && s == "unsafe"));
        assert!(ks.iter().any(|(k, s)| *k == Kind::Str && s.contains("unsafe")));
    }

    #[test]
    fn raw_and_byte_strings() {
        let src = r##"let a = r#"quote " inside"#; let b = b"bytes\""; let c = r"\";"##;
        let ks = kinds(src);
        let strs: Vec<_> =
            ks.iter().filter(|(k, _)| *k == Kind::Str).map(|(_, s)| s.as_str()).collect();
        assert_eq!(strs.len(), 3, "{ks:?}");
        assert!(strs[0].contains("quote"));
        assert!(strs[1].starts_with("b\""));
        // In a raw string the backslash does not escape the close quote.
        assert_eq!(strs[2], "r\"\\\"");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let ks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let d = '\\n'; let e = '_'; }");
        let lifetimes: Vec<_> =
            ks.iter().filter(|(k, _)| *k == Kind::Lifetime).map(|(_, s)| s.as_str()).collect();
        assert_eq!(lifetimes, ["'a", "'a"]);
        let chars = ks.iter().filter(|(k, _)| *k == Kind::Char).count();
        assert_eq!(chars, 3);
    }

    #[test]
    fn nested_block_comments_and_line_numbers() {
        let src = "/* outer /* inner */ still comment */\nfn f() {}\n// tail";
        let toks = lex(src);
        assert_eq!(toks[0].kind, Kind::BlockComment);
        assert_eq!((toks[0].line, toks[0].end_line), (1, 1));
        let f = toks.iter().find(|t| t.kind == Kind::Ident && t.text(src) == "fn").unwrap();
        assert_eq!(f.line, 2);
        assert_eq!(toks.last().unwrap().kind, Kind::LineComment);
        assert_eq!(toks.last().unwrap().line, 3);
    }

    #[test]
    fn numbers_do_not_eat_ranges_or_methods() {
        let ks = kinds("for i in 0..n { let y = 1.max(2); let z = 1.0e-10f64; }");
        assert!(ks.iter().any(|(k, s)| *k == Kind::Ident && s == "max"));
        assert!(ks.iter().any(|(k, s)| *k == Kind::Num && s == "1.0e-10f64"));
        assert!(ks.iter().any(|(k, s)| *k == Kind::Num && s == "0"));
    }
}
