//! Optimizers over [`Net`] parameters: SGD(+momentum) and Adam.

use crate::linalg::Mat;

use super::net::{Net, NetGrads};

/// SGD with optional momentum.
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    vel: Option<Vec<(Mat, Option<Mat>, Vec<f64>)>>,
}

impl Sgd {
    pub fn new(lr: f64, momentum: f64) -> Self {
        Sgd { lr, momentum, vel: None }
    }

    pub fn step(&mut self, net: &mut Net, grads: &NetGrads) {
        if self.momentum == 0.0 {
            for ((u, v, b), (du, dv, db)) in net.params_mut().into_iter().zip(&grads.layers) {
                axpy_mat(u, du, -self.lr);
                if let (Some(v), Some(dv)) = (v, dv) {
                    axpy_mat(v, dv, -self.lr);
                }
                axpy_vec(b, db, -self.lr);
            }
            return;
        }
        let vel = self.vel.get_or_insert_with(|| {
            grads
                .layers
                .iter()
                .map(|(du, dv, db)| {
                    (
                        Mat::zeros(du.rows, du.cols),
                        dv.as_ref().map(|d| Mat::zeros(d.rows, d.cols)),
                        vec![0.0; db.len()],
                    )
                })
                .collect()
        });
        for (((u, v, b), (du, dv, db)), (vu, vv, vb)) in
            net.params_mut().into_iter().zip(&grads.layers).zip(vel.iter_mut())
        {
            update_momentum(vu, du, self.momentum);
            axpy_mat(u, vu, -self.lr);
            if let (Some(v), Some(dv), Some(vv)) = (v, dv, vv.as_mut()) {
                update_momentum(vv, dv, self.momentum);
                axpy_mat(v, vv, -self.lr);
            }
            for (vbi, dbi) in vb.iter_mut().zip(db) {
                *vbi = self.momentum * *vbi + dbi;
            }
            axpy_vec(b, vb, -self.lr);
        }
    }
}

fn update_momentum(vel: &mut Mat, grad: &Mat, mu: f64) {
    for (v, g) in vel.data.iter_mut().zip(&grad.data) {
        *v = mu * *v + g;
    }
}

fn axpy_mat(x: &mut Mat, d: &Mat, a: f64) {
    for (xi, di) in x.data.iter_mut().zip(&d.data) {
        *xi += a * di;
    }
}

fn axpy_vec(x: &mut [f64], d: &[f64], a: f64) {
    for (xi, di) in x.iter_mut().zip(d) {
        *xi += a * di;
    }
}

/// Adam (no weight decay — the controlled experiments match the paper's
/// plain matrix-recovery objectives).
pub struct Adam {
    pub lr: f64,
    pub b1: f64,
    pub b2: f64,
    pub eps: f64,
    t: u64,
    m: Option<Vec<(Mat, Option<Mat>, Vec<f64>)>>,
    v: Option<Vec<(Mat, Option<Mat>, Vec<f64>)>>,
}

impl Adam {
    pub fn new(lr: f64) -> Self {
        Adam { lr, b1: 0.9, b2: 0.999, eps: 1e-8, t: 0, m: None, v: None }
    }

    pub fn step(&mut self, net: &mut Net, grads: &NetGrads) {
        self.t += 1;
        let zeros = || {
            grads
                .layers
                .iter()
                .map(|(du, dv, db)| {
                    (
                        Mat::zeros(du.rows, du.cols),
                        dv.as_ref().map(|d| Mat::zeros(d.rows, d.cols)),
                        vec![0.0; db.len()],
                    )
                })
                .collect::<Vec<_>>()
        };
        if self.m.is_none() {
            self.m = Some(zeros());
            self.v = Some(zeros());
        }
        let bc1 = 1.0 - self.b1.powi(self.t as i32);
        let bc2 = 1.0 - self.b2.powi(self.t as i32);
        let (b1, b2, eps, lr) = (self.b1, self.b2, self.eps, self.lr);
        let upd_mat = |p: &mut Mat, g: &Mat, m: &mut Mat, v: &mut Mat| {
            for i in 0..p.data.len() {
                m.data[i] = b1 * m.data[i] + (1.0 - b1) * g.data[i];
                v.data[i] = b2 * v.data[i] + (1.0 - b2) * g.data[i] * g.data[i];
                let mh = m.data[i] / bc1;
                let vh = v.data[i] / bc2;
                p.data[i] -= lr * mh / (vh.sqrt() + eps);
            }
        };
        let upd_vec = |p: &mut [f64], g: &[f64], m: &mut [f64], v: &mut [f64]| {
            for i in 0..p.len() {
                m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
                p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
            }
        };
        let ms = self.m.as_mut().unwrap();
        let vs = self.v.as_mut().unwrap();
        for ((((u, v, b), (du, dv, db)), (mu, mv, mb)), (vu, vv, vb)) in net
            .params_mut()
            .into_iter()
            .zip(&grads.layers)
            .zip(ms.iter_mut())
            .zip(vs.iter_mut())
        {
            upd_mat(u, du, mu, vu);
            if let (Some(v), Some(dv), Some(mv), Some(vv)) = (v, dv, mv.as_mut(), vv.as_mut()) {
                upd_mat(v, dv, mv, vv);
            }
            upd_vec(b, db, mb, vb);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::{mse_loss, Activation, Layer, Net};
    use crate::rng::Rng;

    /// Both optimizers should fit a small regression problem.
    fn fit(opt: &mut dyn FnMut(&mut Net, &NetGrads), steps: usize) -> f64 {
        let mut rng = Rng::new(40);
        let w_true = Mat::randn(4, 3, &mut rng);
        let x = Mat::randn(64, 4, &mut rng);
        let y = &x * &w_true;
        let mut net = Net::new(vec![Layer::fact(4, 3, 3, 0.3, Activation::None, &mut rng)]);
        let profile = [3];
        let mut last = f64::INFINITY;
        for _ in 0..steps {
            let (pred, cache) = net.forward_cached(&x, &profile);
            let (l, g) = mse_loss(&pred, &y);
            let grads = net.backward(&cache, &profile, &g);
            opt(&mut net, &grads);
            last = l;
        }
        last
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn sgd_converges() {
        let mut sgd = Sgd::new(0.05, 0.9);
        let l = fit(&mut |n, g| sgd.step(n, g), 400);
        assert!(l < 1e-3, "sgd final loss {l}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn adam_converges() {
        let mut adam = Adam::new(0.02);
        let l = fit(&mut |n, g| adam.step(n, g), 400);
        assert!(l < 1e-3, "adam final loss {l}");
    }
}
