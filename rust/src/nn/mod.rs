//! Pure-rust trainable networks (manual backprop, f64).
//!
//! This substrate powers the paper's *controlled* experiments, which need
//! thousands of tiny independent training runs (Fig. 2 PTS/ASL/NSL fronts,
//! Fig. 3 Pareto recovery, Fig. 8 single-budget training, Fig. 9 exhaustive
//! DP validation over 10^4 submodels) — far too many to route through PJRT
//! executables with baked shapes.  The transformer-scale path runs through
//! `runtime`/`training` instead.
//!
//! Layers: dense or factorized (`W = V diag(mask) Uᵀ`, paper convention) with
//! per-layer rank masks; losses: MSE + softmax cross-entropy; optimizers:
//! SGD(+momentum) and Adam.

mod layers;
mod loss;
mod net;
mod optim;

pub use layers::{Activation, FactLinear, Layer, LayerKind};
pub use loss::{accuracy, mse_loss, softmax_xent};
pub use net::{Net, NetGrads};
pub use optim::{Adam, Sgd};
