//! Layers: dense / factorized linear with rank masks, activations.

use crate::linalg::{kernels, Mat};
use crate::rng::Rng;

/// Elementwise nonlinearity between layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Relu,
    None,
}

impl Activation {
    pub fn apply(&self, x: &mut Mat) {
        if let Activation::Relu = self {
            for v in x.data.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }

    /// Multiply grad by activation derivative evaluated at pre-activation z.
    pub fn backprop(&self, z: &Mat, g: &mut Mat) {
        if let Activation::Relu = self {
            for (gv, zv) in g.data.iter_mut().zip(&z.data) {
                if *zv <= 0.0 {
                    *gv = 0.0;
                }
            }
        }
    }
}

/// Factorized linear layer: `y = ((x V) ⊙ mask) Uᵀ + b`.
///
/// `u: (m, r)`, `v: (n, r)` exactly as in the paper (`W_paper = U Vᵀ`,
/// row-convention `W = V Uᵀ`).  The mask is a 0/1 vector over components;
/// nested submodels use prefix masks, theory experiments use arbitrary sets.
#[derive(Debug, Clone)]
pub struct FactLinear {
    pub u: Mat,
    pub v: Mat,
    pub b: Vec<f64>,
}

impl FactLinear {
    pub fn new_random(n: usize, m: usize, r: usize, std: f64, rng: &mut Rng) -> Self {
        FactLinear {
            u: Mat::randn(m, r, rng).scale(std),
            v: Mat::randn(n, r, rng).scale(std),
            b: vec![0.0; m],
        }
    }

    /// Build from paper-form factors.
    pub fn from_factors(u: Mat, v: Mat, b: Vec<f64>) -> Self {
        assert_eq!(u.cols, v.cols);
        assert_eq!(u.rows, b.len());
        FactLinear { u, v, b }
    }

    pub fn rank(&self) -> usize {
        self.u.cols
    }

    pub fn in_dim(&self) -> usize {
        self.v.rows
    }

    pub fn out_dim(&self) -> usize {
        self.u.rows
    }

    /// Effective dense weight at a mask: `W = V diag(mask) Uᵀ` (n×m).
    pub fn effective_weight(&self, mask: &[f64]) -> Mat {
        kernels::matmul_nt(&self.v.mul_diag(mask), &self.u)
    }

    /// Forward: returns (y, t) where t = x V (cached for backprop).
    pub fn forward(&self, x: &Mat, mask: &[f64]) -> (Mat, Mat) {
        let t = x * &self.v; // (B, r)
        let tm = t.mul_diag(mask);
        let mut y = kernels::matmul_nt(&tm, &self.u); // (B, m), Uᵀ never materialized
        for i in 0..y.rows {
            for (yj, bj) in y.row_mut(i).iter_mut().zip(&self.b) {
                *yj += bj;
            }
        }
        (y, t)
    }

    /// Backward: given upstream grad g (B×m), cached t = xV, input x.
    /// Returns (dx, du, dv, db).  All transposed products run through the
    /// NT/TN kernels, so no operand transpose is ever materialized.
    pub fn backward(&self, x: &Mat, t: &Mat, mask: &[f64], g: &Mat) -> (Mat, Mat, Mat, Vec<f64>) {
        let gu = g * &self.u; // (B, r)
        let dt = gu.mul_diag(mask); // (B, r)
        let dx = kernels::matmul_nt(&dt, &self.v); // (B, n) = dt·Vᵀ
        let du = kernels::matmul_tn(g, &t.mul_diag(mask)); // (m, r) = gᵀ·(t⊙mask)
        let dv = kernels::matmul_tn(x, &dt); // (n, r) = xᵀ·dt
        let mut db = vec![0.0; self.b.len()];
        for i in 0..g.rows {
            for (dbj, gj) in db.iter_mut().zip(g.row(i)) {
                *dbj += gj;
            }
        }
        (dx, du, dv, db)
    }
}

/// Dense or factorized layer body.
#[derive(Debug, Clone)]
pub enum LayerKind {
    /// Dense: `y = x W + b`, `w: (n, m)`.
    Dense { w: Mat, b: Vec<f64> },
    Fact(FactLinear),
}

/// A layer: linear body + activation.
#[derive(Debug, Clone)]
pub struct Layer {
    pub kind: LayerKind,
    pub act: Activation,
}

impl Layer {
    pub fn dense(n: usize, m: usize, std: f64, act: Activation, rng: &mut Rng) -> Self {
        Layer {
            kind: LayerKind::Dense { w: Mat::randn(n, m, rng).scale(std), b: vec![0.0; m] },
            act,
        }
    }

    pub fn fact(n: usize, m: usize, r: usize, std: f64, act: Activation, rng: &mut Rng) -> Self {
        Layer { kind: LayerKind::Fact(FactLinear::new_random(n, m, r, std, rng)), act }
    }

    pub fn in_dim(&self) -> usize {
        match &self.kind {
            LayerKind::Dense { w, .. } => w.rows,
            LayerKind::Fact(f) => f.in_dim(),
        }
    }

    pub fn out_dim(&self) -> usize {
        match &self.kind {
            LayerKind::Dense { w, .. } => w.cols,
            LayerKind::Fact(f) => f.out_dim(),
        }
    }

    /// Full rank if factorized, else 0 (dense layers are never truncated).
    pub fn rank(&self) -> usize {
        match &self.kind {
            LayerKind::Dense { .. } => 0,
            LayerKind::Fact(f) => f.rank(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn fact_forward_matches_effective_weight() {
        let mut rng = Rng::new(20);
        let f = FactLinear::new_random(5, 4, 3, 0.5, &mut rng);
        let mask = vec![1.0, 0.0, 1.0];
        let x = Mat::randn(6, 5, &mut rng);
        let (y, _t) = f.forward(&x, &mask);
        let w = f.effective_weight(&mask);
        let want = &x * &w;
        assert!(y.close_to(&want, 1e-10));
    }

    #[test]
    fn fact_backward_matches_finite_difference() {
        let mut rng = Rng::new(21);
        let f = FactLinear::new_random(4, 3, 3, 0.5, &mut rng);
        let mask = vec![1.0, 1.0, 0.0];
        let x = Mat::randn(2, 4, &mut rng);

        // Loss = sum(y²)/2 so dL/dy = y.
        let (y, t) = f.forward(&x, &mask);
        let (dx, du, dv, db) = f.backward(&x, &t, &mask, &y);

        let eps = 1e-6;
        let loss = |f: &FactLinear, x: &Mat| -> f64 {
            let (y, _) = f.forward(x, &mask);
            0.5 * y.data.iter().map(|v| v * v).sum::<f64>()
        };
        // dU check (a few entries).
        for &(i, j) in &[(0usize, 0usize), (2, 1), (1, 2)] {
            let mut fp = f.clone();
            fp.u[(i, j)] += eps;
            let num = (loss(&fp, &x) - loss(&f, &x)) / eps;
            assert!((num - du[(i, j)]).abs() < 1e-4, "dU[{i},{j}]: {num} vs {}", du[(i, j)]);
        }
        // dV check.
        for &(i, j) in &[(0usize, 0usize), (3, 2)] {
            let mut fp = f.clone();
            fp.v[(i, j)] += eps;
            let num = (loss(&fp, &x) - loss(&f, &x)) / eps;
            assert!((num - dv[(i, j)]).abs() < 1e-4, "dV[{i},{j}]: {num} vs {}", dv[(i, j)]);
        }
        // db check.
        {
            let mut fp = f.clone();
            fp.b[1] += eps;
            let num = (loss(&fp, &x) - loss(&f, &x)) / eps;
            assert!((num - db[1]).abs() < 1e-4);
        }
        // dx check.
        {
            let mut xp = x.clone();
            xp[(0, 1)] += eps;
            let num = (loss(&f, &xp) - loss(&f, &x)) / eps;
            assert!((num - dx[(0, 1)]).abs() < 1e-4);
        }
    }

    #[test]
    fn relu_backprop_zeroes_negative() {
        let z = Mat::from_rows(&[&[-1.0, 2.0]]);
        let mut g = Mat::from_rows(&[&[3.0, 4.0]]);
        Activation::Relu.backprop(&z, &mut g);
        assert_eq!(g.data, vec![0.0, 4.0]);
    }

    #[test]
    fn property_masked_rank_prefix_monotone_capacity() {
        // Effective weight of prefix-r mask equals sum of first r rank-1 terms.
        prop::forall(
            51,
            15,
            |rng| {
                let n = prop::gen::dim(rng, 2, 8);
                let m = prop::gen::dim(rng, 2, 8);
                let r = n.min(m);
                (FactLinear::new_random(n, m, r, 0.7, rng), r)
            },
            |(f, r)| {
                let mut acc = Mat::zeros(f.in_dim(), f.out_dim());
                for k in 1..=*r {
                    let mut mask = vec![0.0; *r];
                    for m in mask.iter_mut().take(k) {
                        *m = 1.0;
                    }
                    let w = f.effective_weight(&mask);
                    // Rank-1 increment: w_k - w_{k-1} = v_k u_kᵀ.
                    let inc = &w - &acc;
                    let mut want = Mat::zeros(f.in_dim(), f.out_dim());
                    for i in 0..f.in_dim() {
                        for j in 0..f.out_dim() {
                            want[(i, j)] = f.v[(i, k - 1)] * f.u[(j, k - 1)];
                        }
                    }
                    if !inc.close_to(&want, 1e-9) {
                        return Err(format!("increment mismatch at rank {k}"));
                    }
                    acc = w;
                }
                Ok(())
            },
        );
    }
}
