//! Sequential network with per-layer rank masks + manual backprop.

use crate::linalg::{kernels, Mat};

use super::layers::{Layer, LayerKind};

/// Sequential net.  Factorized layers take a mask from the rank profile;
/// dense layers ignore it.
#[derive(Debug, Clone)]
pub struct Net {
    pub layers: Vec<Layer>,
}

/// Per-layer gradients, same structure as the net.
#[derive(Debug, Clone)]
pub struct NetGrads {
    /// (du_or_dw, dv_opt, db) per layer.
    pub layers: Vec<(Mat, Option<Mat>, Vec<f64>)>,
}

/// Forward cache (inputs + pre-activations + factorized t = xV per layer).
pub struct Cache {
    xs: Vec<Mat>,
    zs: Vec<Mat>,
    ts: Vec<Option<Mat>>,
}

impl Net {
    pub fn new(layers: Vec<Layer>) -> Self {
        for w in layers.windows(2) {
            assert_eq!(w[0].out_dim(), w[1].in_dim(), "layer dims must chain");
        }
        Net { layers }
    }

    /// Ranks of the factorized layers, in order (dense layers excluded).
    pub fn fact_ranks(&self) -> Vec<usize> {
        self.layers.iter().filter(|l| l.rank() > 0).map(|l| l.rank()).collect()
    }

    /// Total parameter count at a given prefix-rank profile (inference form:
    /// (m + n) * r per factorized layer + biases; dense layers full size).
    pub fn param_count(&self, profile: &[usize]) -> usize {
        let mut pi = 0;
        let mut total = 0;
        for l in &self.layers {
            match &l.kind {
                LayerKind::Dense { w, b } => total += w.rows * w.cols + b.len(),
                LayerKind::Fact(f) => {
                    let r = profile[pi].min(f.rank());
                    pi += 1;
                    total += (f.in_dim() + f.out_dim()) * r + f.b.len();
                }
            }
        }
        total
    }

    /// Build per-layer masks from a prefix-rank profile.
    fn masks(&self, profile: &[usize]) -> Vec<Option<Vec<f64>>> {
        let mut pi = 0;
        self.layers
            .iter()
            .map(|l| match &l.kind {
                LayerKind::Dense { .. } => None,
                LayerKind::Fact(f) => {
                    let r = profile[pi].min(f.rank());
                    pi += 1;
                    let mut m = vec![0.0; f.rank()];
                    for v in m.iter_mut().take(r) {
                        *v = 1.0;
                    }
                    Some(m)
                }
            })
            .collect()
    }

    /// Forward at a prefix-rank profile; returns output.
    pub fn forward(&self, x: &Mat, profile: &[usize]) -> Mat {
        self.forward_cached(x, profile).0
    }

    /// Forward keeping the cache needed for [`Net::backward`].
    pub fn forward_cached(&self, x: &Mat, profile: &[usize]) -> (Mat, Cache) {
        let masks = self.masks(profile);
        let mut xs = vec![x.clone()];
        let mut zs = Vec::with_capacity(self.layers.len());
        let mut ts = Vec::with_capacity(self.layers.len());
        let mut cur = x.clone();
        for (l, mask) in self.layers.iter().zip(&masks) {
            let (z, t) = match (&l.kind, mask) {
                (LayerKind::Dense { w, b }, _) => {
                    let mut z = &cur * w;
                    for i in 0..z.rows {
                        for (zj, bj) in z.row_mut(i).iter_mut().zip(b) {
                            *zj += bj;
                        }
                    }
                    (z, None)
                }
                (LayerKind::Fact(f), Some(m)) => {
                    let (z, t) = f.forward(&cur, m);
                    (z, Some(t))
                }
                _ => unreachable!(),
            };
            zs.push(z.clone());
            ts.push(t);
            let mut a = z;
            l.act.apply(&mut a);
            xs.push(a.clone());
            cur = a;
        }
        (cur, Cache { xs, zs, ts })
    }

    /// Backward pass from dL/dout; returns parameter grads.
    pub fn backward(&self, cache: &Cache, profile: &[usize], gout: &Mat) -> NetGrads {
        let masks = self.masks(profile);
        let mut g = gout.clone();
        let mut grads: Vec<(Mat, Option<Mat>, Vec<f64>)> = Vec::with_capacity(self.layers.len());
        for (idx, l) in self.layers.iter().enumerate().rev() {
            // Through the activation.
            l.act.backprop(&cache.zs[idx], &mut g);
            let x = &cache.xs[idx];
            match (&l.kind, &masks[idx]) {
                (LayerKind::Dense { w, b }, _) => {
                    let dw = kernels::matmul_tn(x, &g); // xᵀ·g, no transpose temp
                    let mut db = vec![0.0; b.len()];
                    for i in 0..g.rows {
                        for (dbj, gj) in db.iter_mut().zip(g.row(i)) {
                            *dbj += gj;
                        }
                    }
                    let dx = kernels::matmul_nt(&g, w); // g·wᵀ
                    grads.push((dw, None, db));
                    g = dx;
                }
                (LayerKind::Fact(f), Some(m)) => {
                    let t = cache.ts[idx].as_ref().unwrap();
                    let (dx, du, dv, db) = f.backward(x, t, m, &g);
                    grads.push((du, Some(dv), db));
                    g = dx;
                }
                _ => unreachable!(),
            }
        }
        grads.reverse();
        NetGrads { layers: grads }
    }

    /// Flat list of mutable parameter matrices + biases (for optimizers).
    pub fn params_mut(&mut self) -> Vec<(&mut Mat, Option<&mut Mat>, &mut Vec<f64>)> {
        self.layers
            .iter_mut()
            .map(|l| match &mut l.kind {
                LayerKind::Dense { w, b } => (w, None, b),
                LayerKind::Fact(f) => (&mut f.u, Some(&mut f.v), &mut f.b),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{mse_loss, Activation, Layer};
    use crate::rng::Rng;

    fn tiny_net(rng: &mut Rng) -> Net {
        Net::new(vec![
            Layer::fact(3, 5, 3, 0.5, Activation::Relu, rng),
            Layer::fact(5, 2, 2, 0.5, Activation::None, rng),
        ])
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(30);
        let net = tiny_net(&mut rng);
        let x = Mat::randn(7, 3, &mut rng);
        let y = net.forward(&x, &[3, 2]);
        assert_eq!((y.rows, y.cols), (7, 2));
    }

    #[test]
    fn truncation_changes_output() {
        let mut rng = Rng::new(31);
        let net = tiny_net(&mut rng);
        let x = Mat::randn(4, 3, &mut rng);
        let full = net.forward(&x, &[3, 2]);
        let cut = net.forward(&x, &[1, 1]);
        assert!(!full.close_to(&cut, 1e-6));
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut rng = Rng::new(32);
        let mut net = tiny_net(&mut rng);
        let x = Mat::randn(4, 3, &mut rng);
        let target = Mat::randn(4, 2, &mut rng);
        let profile = [3, 2];

        let (y, cache) = net.forward_cached(&x, &profile);
        let (l0, gout) = mse_loss(&y, &target);
        let grads = net.backward(&cache, &profile, &gout);

        let eps = 1e-6;
        // Check dU of layer 0, a few entries; and dV of layer 1.
        let check = |net: &mut Net, li: usize, which: usize, i: usize, j: usize, want: f64| {
            {
                let mut ps = net.params_mut();
                let (u, v, _) = &mut ps[li];
                match which {
                    0 => u[(i, j)] += eps,
                    _ => v.as_mut().unwrap()[(i, j)] += eps,
                }
            }
            let y2 = net.forward(&x, &profile);
            let (l1, _) = mse_loss(&y2, &target);
            {
                let mut ps = net.params_mut();
                let (u, v, _) = &mut ps[li];
                match which {
                    0 => u[(i, j)] -= eps,
                    _ => v.as_mut().unwrap()[(i, j)] -= eps,
                }
            }
            let num = (l1 - l0) / eps;
            assert!((num - want).abs() < 1e-4, "num {num} vs analytic {want}");
        };

        let du0 = grads.layers[0].0.clone();
        check(&mut net, 0, 0, 1, 1, du0[(1, 1)]);
        let dv1 = grads.layers[1].1.clone().unwrap();
        check(&mut net, 1, 1, 2, 0, dv1[(2, 0)]);
    }

    #[test]
    fn param_count_monotone_in_profile() {
        let mut rng = Rng::new(33);
        let net = tiny_net(&mut rng);
        let p1 = net.param_count(&[1, 1]);
        let p2 = net.param_count(&[2, 2]);
        let p3 = net.param_count(&[3, 2]);
        assert!(p1 < p2 && p2 < p3);
    }
}
