//! Losses with gradients: MSE (regression / matrix-recovery) and softmax
//! cross-entropy (classification), plus accuracy.

use crate::linalg::Mat;

/// Mean-squared error over all entries; returns (loss, dL/dpred).
pub fn mse_loss(pred: &Mat, target: &Mat) -> (f64, Mat) {
    assert_eq!((pred.rows, pred.cols), (target.rows, target.cols));
    let n = (pred.rows * pred.cols) as f64;
    let mut grad = Mat::zeros(pred.rows, pred.cols);
    let mut loss = 0.0;
    for i in 0..pred.data.len() {
        let d = pred.data[i] - target.data[i];
        loss += d * d;
        grad.data[i] = 2.0 * d / n;
    }
    (loss / n, grad)
}

/// Softmax cross-entropy with integer labels; returns (mean loss, dL/dlogits).
pub fn softmax_xent(logits: &Mat, labels: &[usize]) -> (f64, Mat) {
    assert_eq!(logits.rows, labels.len());
    let b = logits.rows as f64;
    let mut grad = Mat::zeros(logits.rows, logits.cols);
    let mut loss = 0.0;
    for i in 0..logits.rows {
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0.0;
        for v in row {
            z += (v - mx).exp();
        }
        let logz = z.ln() + mx;
        loss += logz - row[labels[i]];
        for j in 0..logits.cols {
            let p = (row[j] - logz).exp();
            grad[(i, j)] = (p - if j == labels[i] { 1.0 } else { 0.0 }) / b;
        }
    }
    (loss / b, grad)
}

/// Top-1 accuracy of logits vs labels.
pub fn accuracy(logits: &Mat, labels: &[usize]) -> f64 {
    let mut correct = 0usize;
    for i in 0..logits.rows {
        let row = logits.row(i);
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if arg == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / logits.rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_zero_at_target() {
        let a = Mat::from_rows(&[&[1.0, 2.0]]);
        let (l, g) = mse_loss(&a, &a);
        assert_eq!(l, 0.0);
        assert!(g.data.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn mse_gradient_finite_difference() {
        let p = Mat::from_rows(&[&[1.0, -2.0], &[0.5, 3.0]]);
        let t = Mat::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]);
        let (l0, g) = mse_loss(&p, &t);
        let eps = 1e-6;
        let mut p2 = p.clone();
        p2[(1, 0)] += eps;
        let (l1, _) = mse_loss(&p2, &t);
        assert!(((l1 - l0) / eps - g[(1, 0)]).abs() < 1e-5);
    }

    #[test]
    fn xent_uniform_logits() {
        let logits = Mat::zeros(2, 4);
        let (l, _) = softmax_xent(&logits, &[0, 3]);
        assert!((l - (4f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn xent_gradient_sums_to_zero_per_row() {
        let logits = Mat::from_rows(&[&[2.0, -1.0, 0.5]]);
        let (_, g) = softmax_xent(&logits, &[1]);
        let s: f64 = g.row(0).iter().sum();
        assert!(s.abs() < 1e-12);
    }

    #[test]
    fn accuracy_counts() {
        let logits = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 1.0], &[1.0, 0.0]]);
        assert!((accuracy(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-12);
    }
}
