//! Knowledge consolidation for pure-rust nets (Alg. 1 lines 14–17 at
//! controlled-experiment scale; the transformer path is `training::`).
//!
//! Each step samples a budget profile `m_k ∝ α_k` from the nested chain and
//! takes one distillation (or supervised) gradient step on the masked
//! factorized student.

use crate::linalg::Mat;
use crate::nn::{mse_loss, softmax_xent, Adam, Net};
use crate::rng::Rng;

use super::masks::RankProfile;

/// Supervision signal for consolidation.
pub enum Target<'a> {
    /// Distill against a frozen teacher net's logits (MSE on logits — the
    /// linear-probe analogue of Eq. 5 at this scale).
    Teacher(&'a Net),
    /// Supervised regression targets.
    Regress(&'a Mat),
    /// Supervised classification labels.
    Labels(&'a [usize]),
}

/// Configuration for a consolidation run.
pub struct ConsolidateCfg {
    pub steps: usize,
    pub lr: f64,
    pub batch: usize,
    pub log_every: usize,
}

impl Default for ConsolidateCfg {
    fn default() -> Self {
        ConsolidateCfg { steps: 1000, lr: 1e-2, batch: 64, log_every: 0 }
    }
}

/// Run nested consolidation: sample profiles ∝ alphas, step Adam on the
/// masked student.  Returns per-profile final training losses.
pub fn consolidate(
    student: &mut Net,
    profiles: &[RankProfile],
    alphas: &[f64],
    x: &Mat,
    target: Target,
    cfg: &ConsolidateCfg,
    rng: &mut Rng,
) -> Vec<f64> {
    assert_eq!(profiles.len(), alphas.len());
    assert!(!profiles.is_empty());
    let mut opt = Adam::new(cfg.lr);
    let mut last_loss = vec![f64::NAN; profiles.len()];

    // Precompute teacher logits once (frozen teacher).
    let teacher_out = match &target {
        Target::Teacher(t) => {
            let full = t.fact_ranks();
            Some(t.forward(x, &full))
        }
        _ => None,
    };

    for step in 0..cfg.steps {
        let pi = rng.weighted(alphas);
        let profile = &profiles[pi];

        // Minibatch rows.
        let rows: Vec<usize> = (0..cfg.batch.min(x.rows)).map(|_| rng.below(x.rows)).collect();
        let xb = gather_rows(x, &rows);

        let (out, cache) = student.forward_cached(&xb, profile);
        let (loss, gout) = match &target {
            Target::Teacher(_) => {
                let t = gather_rows(teacher_out.as_ref().unwrap(), &rows);
                mse_loss(&out, &t)
            }
            Target::Regress(y) => {
                let t = gather_rows(y, &rows);
                mse_loss(&out, &t)
            }
            Target::Labels(l) => {
                let lb: Vec<usize> = rows.iter().map(|&i| l[i]).collect();
                softmax_xent(&out, &lb)
            }
        };
        let grads = student.backward(&cache, profile, &gout);
        opt.step(student, &grads);
        last_loss[pi] = loss;

        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            eprintln!("consolidate step {step}: profile {pi} loss {loss:.5}");
        }
    }
    last_loss
}

fn gather_rows(m: &Mat, rows: &[usize]) -> Mat {
    let mut out = Mat::zeros(rows.len(), m.cols);
    for (dst, &src) in rows.iter().enumerate() {
        out.row_mut(dst).copy_from_slice(m.row(src));
    }
    out
}

/// Evaluate a net's loss at each profile (MSE against targets).
pub fn eval_profiles(net: &Net, profiles: &[RankProfile], x: &Mat, y: &Mat) -> Vec<f64> {
    profiles
        .iter()
        .map(|p| {
            let out = net.forward(x, p);
            mse_loss(&out, y).0
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Activation, Layer, Net};

    /// Two consolidation runs from the same seeds must be bit-identical
    /// (losses included), and every profile in the sampled set must end
    /// with a lower eval loss than it started with — the reproducibility +
    /// progress contract the figure harnesses rely on.
    #[test]
    fn seeded_runs_identical_and_every_profile_improves() {
        let (n, m, k) = (5, 4, 4);
        let profiles: Vec<RankProfile> = (1..=k).map(|r| vec![r]).collect();
        let alphas = vec![1.0 / k as f64; k];
        let cfg = ConsolidateCfg { steps: 400, lr: 0.02, batch: 32, log_every: 0 };

        let run = |net_seed: u64, train_seed: u64| -> (Vec<f64>, Vec<f64>, Vec<f64>) {
            let mut net_rng = Rng::new(net_seed);
            let w_true = Mat::randn(n, m, &mut net_rng);
            let x = Mat::randn(96, n, &mut net_rng);
            let y = &x * &w_true;
            let mut net =
                Net::new(vec![Layer::fact(n, m, k, 0.4, Activation::None, &mut net_rng)]);
            let before = eval_profiles(&net, &profiles, &x, &y);
            let mut train_rng = Rng::new(train_seed);
            let losses =
                consolidate(&mut net, &profiles, &alphas, &x, Target::Regress(&y), &cfg, &mut train_rng);
            let after = eval_profiles(&net, &profiles, &x, &y);
            (losses, before, after)
        };

        let (l1, before, after) = run(210, 211);
        let (l2, _, after2) = run(210, 211);
        assert_eq!(l1, l2, "same seeds must reproduce losses bit-exactly");
        assert_eq!(after, after2, "same seeds must reproduce the trained net");
        assert_eq!(l1.len(), k, "one last-loss slot per profile");
        assert!(l1.iter().all(|l| l.is_finite()), "all profiles sampled in 400 steps");
        for (r, (b, a)) in before.iter().zip(&after).enumerate() {
            assert!(a < b, "profile rank {}: loss {b} -> {a} did not improve", r + 1);
        }

        // A different training seed samples profiles in a different order —
        // the determinism above is seed-driven, not accidental.
        let (l3, _, _) = run(210, 212);
        assert_ne!(l1, l3, "different seed should change the trajectory");
    }

    /// Nested consolidation on a low-rank regression target must produce a
    /// monotone loss-vs-rank staircase (bigger submodels at least as good).
    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn consolidated_losses_monotone_in_rank() {
        let mut rng = Rng::new(120);
        let (n, m, k) = (6, 6, 6);
        // Target with power-law spectrum.
        let sv: Vec<f64> = (0..k).map(|i| 1.0 / ((i + 1) as f64).powf(1.2)).collect();
        let w_true = Mat::with_singular_values(n, m, &sv, &mut rng);
        let x = Mat::randn(256, n, &mut rng);
        let y = &x * &w_true;

        let mut student = Net::new(vec![Layer::fact(n, m, k, 0.3, Activation::None, &mut rng)]);
        let profiles: Vec<RankProfile> = (1..=k).map(|r| vec![r]).collect();
        let alphas = vec![1.0 / k as f64; k];
        consolidate(
            &mut student,
            &profiles,
            &alphas,
            &x,
            Target::Regress(&y),
            &ConsolidateCfg { steps: 3000, lr: 0.01, batch: 64, log_every: 0 },
            &mut rng,
        );

        let losses = eval_profiles(&student, &profiles, &x, &y);
        // Allow tiny non-monotonicity from stochastic training.
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] * 1.10 + 1e-4, "losses not ~monotone: {losses:?}");
        }
        // Full rank must essentially fit.
        assert!(losses[k - 1] < 5e-2, "full-rank loss {}", losses[k - 1]);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn teacher_distillation_runs() {
        let mut rng = Rng::new(121);
        let teacher = Net::new(vec![
            Layer::fact(4, 8, 4, 0.5, Activation::Relu, &mut rng),
            Layer::fact(8, 3, 3, 0.5, Activation::None, &mut rng),
        ]);
        let mut student = teacher.clone();
        let x = Mat::randn(128, 4, &mut rng);
        let profiles = vec![vec![2, 2], vec![4, 3]];
        let losses = consolidate(
            &mut student,
            &profiles,
            &[0.5, 0.5],
            &x,
            Target::Teacher(&teacher),
            &ConsolidateCfg { steps: 200, lr: 0.005, batch: 32, log_every: 0 },
            &mut rng,
        );
        assert!(losses.iter().all(|l| l.is_finite()));
        // Full profile distills a clone of the teacher: loss must be small.
        let full_out = student.forward(&x, &[4, 3]);
        let t_out = teacher.forward(&x, &[4, 3]);
        let (l, _) = mse_loss(&full_out, &t_out);
        assert!(l < 0.1, "full-profile distillation loss {l}");
    }
}
