//! The paper's contribution (Secs. 3–4), as a library:
//!
//! * [`decompose`] — DataSVD: online covariance accumulation + whitened SVD
//!   (Sec. 3.1, App. C.1).
//! * [`masks`] — rank profiles, budgets, nested chains (Sec. 2.1, 3.2).
//! * [`sensitivity`] — per-layer rank-reduction probing (App. C.2 step 1).
//! * [`dp`] — the MCKP dynamic program with nestedness (Alg. 2 + 3).
//! * [`pareto`] — Pareto-front utilities over (cost, error) points.
//! * [`gar`] — Gauge-Aligned Reparametrization (Sec. 3.5).
//! * [`theory`] — Sec. 4 objects: optimality gap ℰ(U,V,r), water-filling
//!   ASL minimizer (Lemma B.6), PTS/ASL/NSL trainers for linear models.
//! * [`consolidate`] — nested knowledge distillation for pure-rust nets
//!   (Alg. 1 lines 14–17 at controlled-experiment scale; the transformer
//!   path lives in `training::`).

pub mod consolidate;
pub mod decompose;
pub mod dp;
pub mod gar;
pub mod masks;
pub mod pareto;
pub mod sensitivity;
pub mod theory;

pub use decompose::{CovAccum, DataSvd};
pub use dp::{dp_rank_selection, Candidate, DpResult};
pub use gar::Gar;
pub use masks::{profile_cost, NestedChain, RankProfile};
pub use pareto::{pareto_front, ParetoPoint};
