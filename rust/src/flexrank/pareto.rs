//! Pareto-front utilities over (cost, error) points.

use anyhow::{ensure, Result};

/// A point in the (cost, error) objective space, tagged with its index into
/// the originating collection.
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    pub cost: f64,
    pub error: f64,
    pub idx: usize,
}

/// Non-dominated subset (minimize both cost and error), sorted by cost
/// ascending / error descending.  Ties in cost keep the lower error.
///
/// NaN coordinates are rejected up front (same policy as
/// `dp_rank_selection`): comparisons use `total_cmp`, so a NaN no longer
/// panics the sort — but a NaN point is meaningless and must not silently
/// win or lose a frontier scan.
pub fn pareto_front(points: &[(f64, f64)]) -> Result<Vec<ParetoPoint>> {
    for (i, &(c, e)) in points.iter().enumerate() {
        ensure!(
            !c.is_nan() && !e.is_nan(),
            "pareto_front: point {i} has a NaN coordinate (cost {c}, error {e}) — \
             rejecting before the frontier sort"
        );
    }
    let mut idxs: Vec<usize> = (0..points.len()).collect();
    idxs.sort_by(|&a, &b| {
        points[a]
            .0
            .total_cmp(&points[b].0)
            .then(points[a].1.total_cmp(&points[b].1))
    });
    let mut out: Vec<ParetoPoint> = Vec::new();
    let mut best_err = f64::INFINITY;
    for i in idxs {
        let (c, e) = points[i];
        if e < best_err {
            best_err = e;
            out.push(ParetoPoint { cost: c, error: e, idx: i });
        }
    }
    Ok(out)
}

/// Is point (cost, error) dominated by any point in `points`?
pub fn is_dominated(cost: f64, error: f64, points: &[(f64, f64)]) -> bool {
    points
        .iter()
        .any(|&(c, e)| c <= cost && e <= error && (c < cost || e < error))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn front_of_staircase() {
        let pts = vec![(1.0, 3.0), (2.0, 2.0), (3.0, 1.0), (2.5, 2.5), (1.0, 4.0)];
        let f = pareto_front(&pts).unwrap();
        let got: Vec<usize> = f.iter().map(|p| p.idx).collect();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn front_drops_duplicate_costs() {
        let pts = vec![(1.0, 3.0), (1.0, 2.0)];
        let f = pareto_front(&pts).unwrap();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].idx, 1);
    }

    #[test]
    fn nan_point_rejected_not_panicking() {
        // A NaN error point (e.g. a 0/0 probe on a degenerate eval batch)
        // used to panic inside partial_cmp().unwrap(); now it must come back
        // as a pointed error naming the offender.
        let pts = vec![(1.0, 3.0), (2.0, f64::NAN), (3.0, 1.0)];
        let err = pareto_front(&pts).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("NaN"), "{msg}");
        assert!(msg.contains("point 1"), "must name the point: {msg}");

        let pts = vec![(f64::NAN, 0.5)];
        assert!(pareto_front(&pts).is_err(), "NaN cost must be rejected too");
    }

    #[test]
    fn property_front_is_nondominated_and_complete() {
        prop::forall(
            61,
            30,
            |rng| {
                let n = 1 + rng.below(40);
                (0..n)
                    .map(|_| (rng.f64() * 10.0, rng.f64() * 10.0))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let front = pareto_front(pts).map_err(|e| e.to_string())?;
                // every front point is non-dominated
                for p in &front {
                    if is_dominated(p.cost, p.error, pts) {
                        return Err(format!("front point {p:?} dominated"));
                    }
                }
                // every non-front point is dominated or duplicates a front point
                let fr: Vec<(f64, f64)> = front.iter().map(|p| (p.cost, p.error)).collect();
                for (i, &(c, e)) in pts.iter().enumerate() {
                    let on_front = front.iter().any(|p| p.idx == i);
                    if !on_front && !is_dominated(c, e, &fr) && !fr.contains(&(c, e)) {
                        return Err(format!("point {i} ({c},{e}) should be on front"));
                    }
                }
                // sorted ascending cost, descending error
                for w in front.windows(2) {
                    if w[0].cost >= w[1].cost || w[0].error <= w[1].error {
                        return Err("front not strictly staircase".into());
                    }
                }
                Ok(())
            },
        );
    }
}
