//! Layer probing (App. C.2 step 1): measure the model's loss when a single
//! layer is truncated to each candidate rank, all other layers at full
//! capacity — producing the per-layer (saving, Δerror) candidate lists the
//! DP consumes.

use super::dp::Candidate;
use super::masks::{gar_layer_params, RankProfile};

/// Anything that can be evaluated at a rank profile (pure-rust nets, the
/// PJRT student executable, test stubs).
pub trait ProbeModel {
    /// Full rank of each factorized layer.
    fn full_ranks(&self) -> Vec<usize>;
    /// (n_in, m_out) of each factorized layer.
    fn layer_dims(&self) -> Vec<(usize, usize)>;
    /// Loss at a profile (lower = better).
    fn eval(&mut self, profile: &RankProfile) -> f64;
}

/// Probe result: candidate lists per layer + full-model reference loss.
pub struct Sensitivity {
    pub candidates: Vec<Vec<Candidate>>,
    pub full_loss: f64,
    pub full_cost: u64,
}

/// Evaluate the sensitivity matrix S (L × K): truncate layer `l` to each
/// rank in `rank_grid(l)` while all other layers stay full.
///
/// `grid_per_layer` gives candidate ranks per layer (ascending); the no-drop
/// option is added automatically.  Errors are clamped at ≥ 0 (a truncation
/// can measure spuriously better than full on a small probe set; the DP
/// needs monotone non-negative penalties).
pub fn probe<M: ProbeModel>(
    model: &mut M,
    grid_per_layer: &[Vec<usize>],
) -> Sensitivity {
    let full_ranks = model.full_ranks();
    let dims = model.layer_dims();
    assert_eq!(grid_per_layer.len(), full_ranks.len());

    let full_profile: RankProfile = full_ranks.clone();
    let full_loss = model.eval(&full_profile);
    let full_cost: u64 = dims
        .iter()
        .zip(&full_ranks)
        .map(|(&(n, m), &r)| gar_layer_params(n, m, r) as u64)
        .sum();

    let mut candidates = Vec::with_capacity(full_ranks.len());
    for (l, grid) in grid_per_layer.iter().enumerate() {
        let (n, m) = dims[l];
        let rf = full_ranks[l];
        let full_params = gar_layer_params(n, m, rf) as u64;
        let mut cands = vec![Candidate { saving: 0, err: 0.0, rank: rf }];
        for &r in grid {
            if r >= rf {
                continue;
            }
            let mut profile = full_profile.clone();
            profile[l] = r;
            let loss = model.eval(&profile);
            cands.push(Candidate {
                saving: full_params - gar_layer_params(n, m, r) as u64,
                err: (loss - full_loss).max(0.0),
                rank: r,
            });
        }
        // Ascending saving (descending rank).
        cands.sort_by_key(|c| c.saving);
        candidates.push(cands);
    }
    Sensitivity { candidates, full_loss, full_cost }
}

/// Uniform rank grid: K levels spread over [1, full_rank].
pub fn uniform_grid(full_rank: usize, k: usize) -> Vec<usize> {
    (1..=k)
        .map(|i| ((i * full_rank) as f64 / k as f64).round().max(1.0) as usize)
        .collect::<Vec<_>>()
        .into_iter()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Additive stub: loss = Σ_l w_l · (full_l − r_l).
    struct Stub {
        fulls: Vec<usize>,
        weights: Vec<f64>,
        evals: usize,
    }

    impl ProbeModel for Stub {
        fn full_ranks(&self) -> Vec<usize> {
            self.fulls.clone()
        }
        fn layer_dims(&self) -> Vec<(usize, usize)> {
            self.fulls.iter().map(|&r| (r * 2, r * 3)).collect()
        }
        fn eval(&mut self, profile: &RankProfile) -> f64 {
            self.evals += 1;
            profile
                .iter()
                .zip(&self.fulls)
                .zip(&self.weights)
                .map(|((&r, &f), &w)| w * (f - r) as f64)
                .sum()
        }
    }

    #[test]
    fn probe_recovers_additive_weights() {
        let mut stub = Stub { fulls: vec![4, 4], weights: vec![1.0, 3.0], evals: 0 };
        let grids = vec![vec![1, 2, 3], vec![1, 2, 3]];
        let s = probe(&mut stub, &grids);
        assert_eq!(s.full_loss, 0.0);
        // Layer 1 candidates must have 3x the error of layer 0 at same drop.
        let e0: Vec<f64> = s.candidates[0].iter().map(|c| c.err).collect();
        let e1: Vec<f64> = s.candidates[1].iter().map(|c| c.err).collect();
        for (a, b) in e0.iter().zip(&e1) {
            assert!((b - 3.0 * a).abs() < 1e-12);
        }
        // Evaluation count: 1 (full) + 3 + 3 = O(L*K), not K^L.
        assert_eq!(stub.evals, 7);
    }

    #[test]
    fn probe_clamps_negative_errors() {
        struct Noisy;
        impl ProbeModel for Noisy {
            fn full_ranks(&self) -> Vec<usize> {
                vec![3]
            }
            fn layer_dims(&self) -> Vec<(usize, usize)> {
                vec![(4, 4)]
            }
            fn eval(&mut self, profile: &RankProfile) -> f64 {
                if profile[0] == 2 {
                    -1.0 // "better than full" noise
                } else {
                    0.0
                }
            }
        }
        let s = probe(&mut Noisy, &[vec![1, 2]]);
        assert!(s.candidates[0].iter().all(|c| c.err >= 0.0));
    }

    #[test]
    fn uniform_grid_spans_range() {
        let g = uniform_grid(128, 8);
        assert_eq!(g.len(), 8);
        assert_eq!(*g.last().unwrap(), 128);
        assert!(g[0] >= 1);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }
}
