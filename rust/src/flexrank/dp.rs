//! Dynamic-programming rank selection — Algorithm 2 + subroutines of
//! Algorithm 3 (ExpandLayer, KeepMinErrorPerSaving, ParetoPrune, Backtrack,
//! ParetoFilter, NestedChain).
//!
//! Frames nested submodel search as a Multi-Choice Knapsack over per-layer
//! (saving, error) candidates under the additive-error probe (App. C.2/C.3):
//! states are (total saving, total error) pairs, pruned to the Pareto
//! frontier after every layer, with backpointers for profile reconstruction.
//!
//! Savings can be grouped into buckets (`quant > 1`) to bound the state
//! count on large models; `quant = 1` is exact.

use anyhow::{ensure, Result};

use super::masks::{is_nested, NestedChain, RankProfile};

/// One rank-drop option for a layer: truncating to `rank` saves `saving`
/// parameters at probe-error increase `err`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub saving: u64,
    pub err: f64,
    pub rank: usize,
}

/// DP output: the componentwise-nested chain plus the full Pareto set.
#[derive(Debug, Clone)]
pub struct DpResult {
    /// Nested chain, ascending in cost (descending total saving).
    pub chain: NestedChain,
    /// All Pareto-optimal (saving, err, profile) triples, saving ascending.
    pub pareto: Vec<(u64, f64, RankProfile)>,
}

#[derive(Debug, Clone, Copy)]
struct State {
    saving: u64,
    err: f64,
}

/// Run the DP over per-layer candidate lists.
///
/// * `candidates[l]` — options for layer l (must include the no-drop option
///   `saving = 0`, `err = 0`, `rank = full`).
/// * `full_cost` — parameter cost of the full model (profile costs are
///   `full_cost − saving`).
/// * `quant` — saving bucket width for state grouping (1 = exact).
///
/// Rejects NaN probe errors up front: a NaN candidate would otherwise
/// poison every comparison in the frontier sorts and the Pareto scans
/// (comparisons use `total_cmp`, so they no longer panic — but a NaN
/// state is meaningless and must not silently win or lose a sort).
pub fn dp_rank_selection(
    candidates: &[Vec<Candidate>],
    full_cost: u64,
    quant: u64,
) -> Result<DpResult> {
    for (l, cands) in candidates.iter().enumerate() {
        for c in cands {
            ensure!(
                !c.err.is_nan(),
                "layer {l}: candidate at rank {} has a NaN probe error — \
                 rejecting before rank selection",
                c.rank
            );
        }
    }
    let quant = quant.max(1);
    let l_total = candidates.len();

    // Frontier after each layer + backpointers (state -> (prev_state, rank)).
    let mut frontier: Vec<State> = vec![State { saving: 0, err: 0.0 }];
    let mut backptrs: Vec<Vec<(usize, usize)>> = Vec::with_capacity(l_total);

    for cands in candidates {
        // ExpandLayer: cross product of frontier with this layer's options.
        let mut expanded: Vec<(State, usize, usize)> = Vec::with_capacity(frontier.len() * cands.len());
        for (i, st) in frontier.iter().enumerate() {
            for c in cands {
                expanded.push((
                    State { saving: st.saving + c.saving, err: st.err + c.err },
                    i,
                    c.rank,
                ));
            }
        }

        // KeepMinErrorPerSaving (bucketed by `quant`).
        expanded.sort_by(|a, b| {
            (a.0.saving / quant)
                .cmp(&(b.0.saving / quant))
                .then(a.0.err.total_cmp(&b.0.err))
        });
        let mut grouped: Vec<(State, usize, usize)> = Vec::new();
        let mut last_bucket = u64::MAX;
        for e in expanded {
            let bucket = e.0.saving / quant;
            if bucket != last_bucket {
                grouped.push(e);
                last_bucket = bucket;
            }
        }

        // ParetoPrune: scan from largest saving down, keep strictly-improving
        // errors (non-dominated set for maximize-saving / minimize-error).
        let mut new_frontier: Vec<State> = Vec::new();
        let mut new_bp: Vec<(usize, usize)> = Vec::new();
        let mut e_best = f64::INFINITY;
        for (st, prev, rank) in grouped.into_iter().rev() {
            if st.err < e_best {
                e_best = st.err;
                new_frontier.push(st);
                new_bp.push((prev, rank));
            }
        }
        new_frontier.reverse();
        new_bp.reverse();

        frontier = new_frontier;
        backptrs.push(new_bp);
    }

    // Backtrack every final state into a profile.
    let mut pareto: Vec<(u64, f64, RankProfile)> = Vec::with_capacity(frontier.len());
    for (fi, st) in frontier.iter().enumerate() {
        let mut ranks = vec![0usize; l_total];
        let mut h = fi;
        for l in (0..l_total).rev() {
            let (prev, rank) = backptrs[l][h];
            ranks[l] = rank;
            h = prev;
        }
        pareto.push((st.saving, st.err, ranks));
    }
    pareto.sort_by_key(|p| p.0);

    // ParetoFilter (already non-dominated by construction, but re-assert) —
    // scan ascending saving keeping strictly-decreasing error from the right.
    let mut filtered: Vec<(u64, f64, RankProfile)> = Vec::new();
    let mut e_best = f64::INFINITY;
    for p in pareto.iter().rev() {
        if p.1 < e_best {
            e_best = p.1;
            filtered.push(p.clone());
        }
    }
    filtered.reverse();

    // NestedChain: ascending saving (= descending rank), keep profiles whose
    // ranks are componentwise ≤ the previously kept one.
    let mut chain_profiles: Vec<RankProfile> = Vec::new();
    let mut chain_savings: Vec<u64> = Vec::new();
    let mut chain_errors: Vec<f64> = Vec::new();
    for (s, e, prof) in filtered.iter() {
        match chain_profiles.last() {
            None => {
                chain_profiles.push(prof.clone());
                chain_savings.push(*s);
                chain_errors.push(*e);
            }
            Some(last) => {
                if is_nested(prof, last) {
                    chain_profiles.push(prof.clone());
                    chain_savings.push(*s);
                    chain_errors.push(*e);
                }
            }
        }
    }
    // Ascending cost = descending saving.
    chain_profiles.reverse();
    chain_savings.reverse();
    chain_errors.reverse();
    let costs: Vec<usize> = chain_savings
        .iter()
        .map(|&s| (full_cost - s) as usize)
        .collect();

    Ok(DpResult {
        chain: NestedChain { profiles: chain_profiles, costs, errors: chain_errors },
        pareto: filtered,
    })
}

/// Brute-force reference (exponential): enumerate all combinations, return
/// the Pareto set of (saving, error).  Test/validation only.
pub fn brute_force_pareto(candidates: &[Vec<Candidate>]) -> Vec<(u64, f64, RankProfile)> {
    let mut all: Vec<(u64, f64, RankProfile)> = vec![(0, 0.0, vec![])];
    for cands in candidates {
        let mut next = Vec::with_capacity(all.len() * cands.len());
        for (s, e, prof) in &all {
            for c in cands {
                let mut p = prof.clone();
                p.push(c.rank);
                next.push((s + c.saving, e + c.err, p));
            }
        }
        all = next;
    }
    all.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut out: Vec<(u64, f64, RankProfile)> = Vec::new();
    let mut e_best = f64::INFINITY;
    for p in all.iter().rev() {
        if p.1 < e_best {
            e_best = p.1;
            out.push(p.clone());
        }
    }
    out.reverse();
    // Dedup equal savings (keep min error, already ensured by scan order).
    out.dedup_by_key(|p| p.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    fn layer_cands(rng: &mut crate::rng::Rng, full_rank: usize, dim_sum: u64) -> Vec<Candidate> {
        // Monotone: smaller rank -> bigger saving, bigger error.
        let mut out = vec![Candidate { saving: 0, err: 0.0, rank: full_rank }];
        let mut err = 0.0;
        for r in (1..full_rank).rev() {
            err += rng.f64() * 0.3;
            out.push(Candidate {
                saving: dim_sum * (full_rank - r) as u64,
                err,
                rank: r,
            });
        }
        out
    }

    #[test]
    fn dp_matches_brute_force_exact() {
        prop::forall(
            71,
            20,
            |rng| {
                let l = 2 + rng.below(3);
                (0..l)
                    .map(|_| {
                        let fr = 2 + rng.below(3);
                        let ds = 3 + rng.below(5) as u64;
                        layer_cands(rng, fr, ds)
                    })
                    .collect::<Vec<Vec<Candidate>>>()
            },
            |cands| {
                let full: u64 = 10_000;
                let dp = dp_rank_selection(cands, full, 1).unwrap();
                let bf = brute_force_pareto(cands);
                if dp.pareto.len() != bf.len() {
                    return Err(format!("front sizes {} vs {}", dp.pareto.len(), bf.len()));
                }
                for (d, b) in dp.pareto.iter().zip(&bf) {
                    if d.0 != b.0 || (d.1 - b.1).abs() > 1e-12 {
                        return Err(format!("state mismatch {:?} vs {:?}", (d.0, d.1), (b.0, b.1)));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn chain_is_nested_and_costs_ascend() {
        let mut rng = crate::rng::Rng::new(72);
        let cands: Vec<Vec<Candidate>> =
            (0..4).map(|_| layer_cands(&mut rng, 5, 7)).collect();
        let dp = dp_rank_selection(&cands, 1_000, 1).unwrap();
        assert!(dp.chain.validate(), "chain must be nested + cost-ascending");
        assert!(!dp.chain.profiles.is_empty());
        // Chain endpoints: full model present (saving 0 => cost == full).
        assert_eq!(*dp.chain.costs.last().unwrap(), 1_000);
        assert_eq!(dp.chain.errors.last().copied().unwrap(), 0.0);
    }

    #[test]
    fn quantization_stays_near_exact() {
        let mut rng = crate::rng::Rng::new(73);
        let cands: Vec<Vec<Candidate>> =
            (0..5).map(|_| layer_cands(&mut rng, 6, 11)).collect();
        let exact = dp_rank_selection(&cands, 10_000, 1).unwrap();
        let quant = dp_rank_selection(&cands, 10_000, 8).unwrap();
        // For every exact front point there is a quantized point within one
        // bucket of saving whose error is no worse than the bucket-mate's.
        for (s, e, _) in &exact.pareto {
            let ok = quant
                .pareto
                .iter()
                .any(|(qs, qe, _)| qs + 8 >= *s && *qe <= *e + 1e-9);
            assert!(ok, "exact point (s={s}, e={e}) lost under quantization");
        }
    }

    #[test]
    fn errors_decrease_with_cost_along_chain() {
        let mut rng = crate::rng::Rng::new(74);
        let cands: Vec<Vec<Candidate>> =
            (0..3).map(|_| layer_cands(&mut rng, 4, 9)).collect();
        let dp = dp_rank_selection(&cands, 500, 1).unwrap();
        for w in dp.chain.errors.windows(2) {
            assert!(w[0] >= w[1] - 1e-12, "errors must fall as cost rises");
        }
    }

    /// Randomized candidate sets: every DP chain (exact *and* quantized)
    /// must be componentwise nested, and bucketing the savings (`quant = 8`)
    /// must never produce a front point the exact DP can't match or beat —
    /// quantization trades state count for resolution, never correctness.
    #[test]
    fn property_chains_nested_and_quantized_never_beats_exact() {
        prop::forall(
            75,
            25,
            |rng| {
                let l = 2 + rng.below(3);
                (0..l)
                    .map(|_| {
                        let fr = 2 + rng.below(4);
                        let ds = 2 + rng.below(9) as u64;
                        layer_cands(rng, fr, ds)
                    })
                    .collect::<Vec<Vec<Candidate>>>()
            },
            |cands| {
                let full: u64 = 100_000;
                let exact = dp_rank_selection(cands, full, 1).map_err(|e| e.to_string())?;
                let quant = dp_rank_selection(cands, full, 8).map_err(|e| e.to_string())?;
                for dp in [&exact, &quant] {
                    if !dp.chain.validate() {
                        return Err(format!("chain invariant broken: {:?}", dp.chain.profiles));
                    }
                    for w in dp.chain.profiles.windows(2) {
                        if !is_nested(&w[0], &w[1]) {
                            return Err(format!(
                                "chain not componentwise nested: {:?} vs {:?}",
                                w[0], w[1]
                            ));
                        }
                    }
                }
                // Every quantized front point is a real achievable profile,
                // so the exact (true) front must dominate it: same-or-more
                // saving at same-or-less total error.
                for (qs, qe, _) in &quant.pareto {
                    let matched = exact
                        .pareto
                        .iter()
                        .any(|(es, ee, _)| es >= qs && *ee <= qe + 1e-12);
                    if !matched {
                        return Err(format!(
                            "quantized point (saving {qs}, err {qe}) beats the exact front"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nan_probe_error_rejected_at_boundary() {
        // A NaN probe error (degenerate calibration batch, 0/0 in the
        // probe) used to panic inside the frontier sort; now the DP must
        // reject the candidate set up front with a pointed error.
        let cands = vec![
            vec![Candidate { saving: 0, err: 0.0, rank: 3 }],
            vec![
                Candidate { saving: 0, err: 0.0, rank: 3 },
                Candidate { saving: 5, err: f64::NAN, rank: 1 },
            ],
        ];
        let err = dp_rank_selection(&cands, 100, 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("NaN"), "{msg}");
        assert!(msg.contains("layer 1"), "must name the layer: {msg}");
        assert!(msg.contains("rank 1"), "must name the rank: {msg}");
    }
}
