//! Sec. 4 theory objects on linear models `M = U diag(mask) Vᵀ`:
//!
//! * best-submodel optimality gap ℰ(U, V, r) (Eq. 9),
//! * PTS / ASL / NSL trainers (Eqs. 10–12),
//! * the ASL water-filling minimizer `w_i = max(0, 2σ_i − λ)` (Lemma B.6)
//!   and the Thm. 4.2 lower bound.
//!
//! These regenerate Fig. 2 and provide executable checks of Thms. 4.1–4.3.

use crate::linalg::{svd, Mat};
use crate::rng::Rng;

/// Training strategy over submodel masks (Sec. 4.2–4.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Post-Training Selection: optimize only the full model (Eq. 10).
    Pts,
    /// All-Subspaces Learning: random subsets each step (Eq. 11).
    Asl,
    /// Nested Subspace Learning: random prefix [r] each step (Eq. 12).
    Nsl,
}

/// A trained linear factor pair.
#[derive(Debug, Clone)]
pub struct LinearFactors {
    pub u: Mat, // (m, k)
    pub v: Mat, // (n, k)
}

impl LinearFactors {
    pub fn random(m: usize, n: usize, k: usize, std: f64, rng: &mut Rng) -> Self {
        LinearFactors { u: Mat::randn(m, k, rng).scale(std), v: Mat::randn(n, k, rng).scale(std) }
    }

    /// `U diag(mask) Vᵀ`.
    pub fn realize(&self, mask: &[f64]) -> Mat {
        &self.u.mul_diag(mask) * &self.v.mul_diag(mask).t()
    }

    pub fn k(&self) -> usize {
        self.u.cols
    }
}

/// One GD step of `‖U diag(mask) Vᵀ − M*‖²_F` at learning rate lr.
fn gd_step(f: &mut LinearFactors, mstar: &Mat, mask: &[f64], lr: f64) -> f64 {
    let um = f.u.mul_diag(mask);
    let vm = f.v.mul_diag(mask);
    let e = &(&um * &vm.t()) - mstar; // (m, n)
    let loss = e.frob_norm().powi(2);
    // dU = 2 E V diag(mask); dV = 2 Eᵀ U diag(mask)
    let du = (&e * &vm).mul_diag(mask).scale(2.0);
    let dv = (&e.t() * &um).mul_diag(mask).scale(2.0);
    for (p, g) in f.u.data.iter_mut().zip(&du.data) {
        *p -= lr * g;
    }
    for (p, g) in f.v.data.iter_mut().zip(&dv.data) {
        *p -= lr * g;
    }
    loss
}

/// Train factors against `mstar` under a strategy (plain GD, matching the
/// paper's simulations).  Returns the final full-model loss.
pub fn train(
    f: &mut LinearFactors,
    mstar: &Mat,
    strategy: Strategy,
    steps: usize,
    lr: f64,
    rng: &mut Rng,
) -> f64 {
    let k = f.k();
    let mut full = vec![1.0; k];
    let mut last = f64::INFINITY;
    for _ in 0..steps {
        let mask: Vec<f64> = match strategy {
            Strategy::Pts => full.clone(),
            Strategy::Asl => {
                // Uniform non-empty subset.
                loop {
                    let m: Vec<f64> =
                        (0..k).map(|_| if rng.f64() < 0.5 { 1.0 } else { 0.0 }).collect();
                    if m.iter().any(|&x| x > 0.0) {
                        break m;
                    }
                }
            }
            Strategy::Nsl => {
                let r = 1 + rng.below(k);
                (0..k).map(|i| if i < r { 1.0 } else { 0.0 }).collect()
            }
        };
        last = gd_step(f, mstar, &mask, lr);
        if strategy == Strategy::Pts {
            // keep `full` borrowless clone cheap
        }
        full.truncate(k);
    }
    last
}

/// All r-subsets of [k] (test scale: k ≤ ~12).
fn subsets_of_size(k: usize, r: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(r);
    fn rec(start: usize, k: usize, r: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == r {
            out.push(cur.clone());
            return;
        }
        for i in start..k {
            cur.push(i);
            rec(i + 1, k, r, cur, out);
            cur.pop();
        }
    }
    rec(0, k, r, &mut cur, &mut out);
    out
}

/// Best-submodel optimality gap ℰ(U, V, r) (Eq. 9): exhaustive search over
/// index subsets against the Eckart–Young truncation `A_r` of `mstar`.
pub fn optimality_gap(f: &LinearFactors, mstar: &Mat, r: usize) -> f64 {
    let k = f.k();
    let a_r = svd(mstar).truncate(r);
    let mut best = f64::INFINITY;
    for s in subsets_of_size(k, r) {
        let mut mask = vec![0.0; k];
        for i in s {
            mask[i] = 1.0;
        }
        let d = f.realize(&mask).frob_dist(&a_r).powi(2);
        if d < best {
            best = d;
        }
    }
    best
}

/// Reconstruction error of the *best* rank-r submodel against `mstar`
/// (the Fig. 2 y-axis): `min_S ‖U Π_S Vᵀ − M*‖²_F`.
pub fn best_submodel_error(f: &LinearFactors, mstar: &Mat, r: usize) -> f64 {
    let k = f.k();
    let mut best = f64::INFINITY;
    for s in subsets_of_size(k, r) {
        let mut mask = vec![0.0; k];
        for i in s {
            mask[i] = 1.0;
        }
        let d = f.realize(&mask).frob_dist(mstar).powi(2);
        if d < best {
            best = d;
        }
    }
    best
}

/// Water-filling singular values of the ASL minimizer (Lemma B.6):
/// `w_i = max(0, 2σ_i − λ)`, `λ = (1/k) Σ w_j`.  Solved exactly by scanning
/// the active-set size.
pub fn asl_water_filling(sigma: &[f64]) -> (Vec<f64>, f64) {
    let k = sigma.len();
    // Assume sigma sorted descending; active set is a prefix {1..t}.
    for t in (1..=k).rev() {
        // λ = (2/(k+t)) Σ_{i≤t} σ_i  (from λ·k = Σ_{i≤t} (2σ_i − λ))
        let s: f64 = sigma[..t].iter().sum();
        let lambda = 2.0 * s / (k + t) as f64;
        let w: Vec<f64> = sigma.iter().map(|&x| (2.0 * x - lambda).max(0.0)).collect();
        let active = w.iter().filter(|&&x| x > 0.0).count();
        if active == t {
            return (w, lambda);
        }
    }
    (vec![0.0; k], 0.0)
}

/// Thm. 4.2 lower bound on ℰ(U, V, r) at an ASL minimizer:
/// `(1/k) (r λ − Σ_{i≤r} σ_i)²` with `λ = ‖W*‖_* / k`.
pub fn asl_gap_lower_bound(sigma: &[f64], r: usize) -> f64 {
    let k = sigma.len();
    let (w, _) = asl_water_filling(sigma);
    let lambda = w.iter().sum::<f64>() / k as f64;
    let s_r: f64 = sigma[..r].iter().sum();
    (r as f64 * lambda - s_r).powi(2) / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn powerlaw_mstar(k: usize, decay: f64, rng: &mut Rng) -> (Mat, Vec<f64>) {
        let sv: Vec<f64> = (0..k).map(|i| 1.0 / ((i + 1) as f64).powf(decay)).collect();
        (Mat::with_singular_values(k, k, &sv, rng), sv)
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn nsl_recovers_pareto_front_thm43() {
        let mut rng = Rng::new(100);
        let k = 4;
        let (mstar, sv) = powerlaw_mstar(k, 1.2, &mut rng);
        let mut f = LinearFactors::random(k, k, k, 0.3, &mut rng);
        train(&mut f, &mstar, Strategy::Nsl, 6000, 0.05, &mut rng);
        // Gap ~0 at every rank (Thm 4.3).
        for r in 1..=k {
            let gap = optimality_gap(&f, &mstar, r);
            assert!(gap < 5e-3, "NSL gap at r={r}: {gap}");
        }
        let _ = sv;
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn pts_fails_at_reduced_ranks_thm41() {
        let mut rng = Rng::new(101);
        let k = 4;
        let (mstar, _) = powerlaw_mstar(k, 1.2, &mut rng);
        let mut f = LinearFactors::random(k, k, k, 0.3, &mut rng);
        let full_loss = train(&mut f, &mstar, Strategy::Pts, 6000, 0.05, &mut rng);
        assert!(full_loss < 1e-6, "PTS must fit the full model, got {full_loss}");
        // ...but some reduced rank has a strictly positive gap (a.s.).
        let worst = (1..k)
            .map(|r| optimality_gap(&f, &mstar, r))
            .fold(0.0f64, f64::max);
        assert!(worst > 1e-4, "PTS gap unexpectedly zero: {worst}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn asl_gap_positive_and_above_bound_thm42() {
        let mut rng = Rng::new(102);
        let k = 4;
        let (mstar, sv) = powerlaw_mstar(k, 1.2, &mut rng);
        let mut f = LinearFactors::random(k, k, k, 0.3, &mut rng);
        train(&mut f, &mstar, Strategy::Asl, 12000, 0.03, &mut rng);
        // At least one rank's best-submodel gap must be significantly > 0 and
        // the theoretical bound itself must be positive for distinct sigmas.
        let bound_max = (1..=k)
            .map(|r| asl_gap_lower_bound(&sv, r))
            .fold(0.0f64, f64::max);
        assert!(bound_max > 1e-5, "thm bound trivial: {bound_max}");
        let gap_max = (1..=k)
            .map(|r| optimality_gap(&f, &mstar, r))
            .fold(0.0f64, f64::max);
        assert!(gap_max > 1e-4, "ASL gap unexpectedly ~0: {gap_max}");
    }

    #[test]
    fn water_filling_consistency() {
        let sigma = [4.0, 2.0, 1.0, 0.25];
        let (w, lambda) = asl_water_filling(&sigma);
        // λ must equal mean of w.
        let mean = w.iter().sum::<f64>() / sigma.len() as f64;
        assert!((lambda - mean).abs() < 1e-12);
        // w_i = max(0, 2σ_i − λ).
        for (wi, si) in w.iter().zip(&sigma) {
            assert!((wi - (2.0 * si - lambda).max(0.0)).abs() < 1e-12);
        }
        // Equal sigmas ⇒ W* = M* (Thm B.7 iff condition).
        let (w_eq, lam_eq) = asl_water_filling(&[3.0, 3.0, 3.0]);
        for wi in &w_eq {
            assert!((wi - 3.0).abs() < 1e-12, "{w_eq:?} {lam_eq}");
        }
    }

    #[test]
    fn subsets_count_binomial() {
        assert_eq!(subsets_of_size(5, 2).len(), 10);
        assert_eq!(subsets_of_size(6, 3).len(), 20);
        assert_eq!(subsets_of_size(4, 4).len(), 1);
    }
}
