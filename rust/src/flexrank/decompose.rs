//! DataSVD layer decomposition (Sec. 3.1, App. C.1).
//!
//! Two stages:
//!  1. **Online covariance estimation** — accumulate `Σ_l = Σ_j x_j x_jᵀ`
//!     batch by batch; memory is O(n_l²), independent of sample count.
//!  2. **Whitened SVD** — `Σ^{1/2}` via symmetric eigendecomposition, SVD of
//!     `W_paper Σ^{1/2} = P Λ Qᵀ`, factors recovered as
//!     `U = P Λ^{1/2}`, `V = Σ^{-1/2} Q Λ^{1/2}` (Eq. 61).
//!
//! Convention note: model weights arrive row-convention (`y = x W`,
//! `W : n×m`); the paper's matrix is `W_paper = Wᵀ`.

use crate::linalg::{kernels, psd_sqrt, svd, Mat};

/// Online accumulator for one layer's activation second moment.
#[derive(Debug, Clone)]
pub struct CovAccum {
    pub sigma: Mat,
    pub count: usize,
}

impl CovAccum {
    pub fn new(n: usize) -> Self {
        CovAccum { sigma: Mat::zeros(n, n), count: 0 }
    }

    /// Add a batch of activations X (rows = samples): `Σ += XᵀX` in one
    /// panel-packed kernel call (no per-row temporaries).
    pub fn add_batch(&mut self, x: &Mat) {
        assert_eq!(x.cols, self.sigma.rows);
        kernels::matmul_tn_acc(x, x, &mut self.sigma);
        self.count += x.rows;
    }

    /// Add a precomputed increment `XᵀX` (as produced by the `teacher_acts`
    /// artifact) for `rows` samples.
    pub fn add_gram(&mut self, gram: &Mat, rows: usize) {
        assert_eq!((gram.rows, gram.cols), (self.sigma.rows, self.sigma.cols));
        for (s, g) in self.sigma.data.iter_mut().zip(&gram.data) {
            *s += g;
        }
        self.count += rows;
    }
}

/// DataSVD result for one layer: importance-ordered factors + spectrum.
#[derive(Debug, Clone)]
pub struct DataSvd {
    /// (m, k) left factor, paper convention (`W_paper = U Vᵀ`).
    pub u: Mat,
    /// (n, k) right factor.
    pub v: Mat,
    /// Whitened singular values (importance of each component).
    pub lambda: Vec<f64>,
}

impl DataSvd {
    /// Decompose `w` (row-convention n×m) under activation covariance `sigma`.
    ///
    /// `eps_rel` regularizes the whitening: eigenvalues below
    /// `eps_rel * λ_max` are clamped (rank-deficient covariances from small
    /// calibration sets stay invertible).
    pub fn compute(w_row: &Mat, cov: &CovAccum, eps_rel: f64) -> DataSvd {
        let w_paper = w_row.t(); // (m, n)
        let n = w_row.rows;
        assert_eq!(cov.sigma.rows, n, "covariance dim != layer input dim");

        // Scale-invariant floor for the whitener.
        let max_diag = (0..n).map(|i| cov.sigma[(i, i)]).fold(0.0f64, f64::max);
        let floor = (eps_rel * max_diag).max(1e-12);
        let (sig_half, sig_inv_half) = psd_sqrt(&cov.sigma, floor);

        // SVD of the whitened weight.
        let wh = &w_paper * &sig_half; // (m, n)
        let d = svd(&wh);
        let k = d.s.len();

        // U = P Λ^{1/2}, V = Σ^{-1/2} Q Λ^{1/2}.
        let mut u = d.u.clone(); // (m, k)
        let mut q = d.vt.t(); // (n, k)
        for i in 0..k {
            let sh = d.s[i].max(0.0).sqrt();
            u.scale_col(i, sh);
            q.scale_col(i, sh);
        }
        let v = &sig_inv_half * &q; // (n, k)
        DataSvd { u, v, lambda: d.s.clone() }
    }

    /// Plain weight-SVD (the "SVD" baseline): identity covariance.
    pub fn compute_plain(w_row: &Mat) -> DataSvd {
        let d = svd(&w_row.t());
        let (u, v) = d.balanced_factors();
        DataSvd { u, v, lambda: d.s }
    }

    /// Effective row-convention weight at rank r: `(U_r V_rᵀ)ᵀ = V_r U_rᵀ`.
    pub fn truncated_weight(&self, r: usize) -> Mat {
        let r = r.min(self.lambda.len());
        &self.v.slice_cols(0, r) * &self.u.slice_cols(0, r).t()
    }

    /// Data-weighted reconstruction error `‖(W − W_r) Σ^{1/2}‖_F²` per
    /// sample — the objective of Eq. 3 evaluated at rank r.
    pub fn recon_error(&self, w_row: &Mat, cov: &CovAccum, r: usize) -> f64 {
        let diff = &w_row.t() - &self.truncated_weight(r).t(); // (m, n) paper conv
        // ‖D Σ^{1/2}‖² = tr(D Σ Dᵀ)
        let ds = &diff * &cov.sigma;
        let mut tr = 0.0;
        for i in 0..diff.rows {
            for j in 0..diff.cols {
                tr += ds[(i, j)] * diff[(i, j)];
            }
        }
        tr / cov.count.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_setup(rng: &mut Rng, n: usize, m: usize, samples: usize) -> (Mat, CovAccum, Mat) {
        let w = Mat::randn(n, m, rng);
        let x = Mat::randn(samples, n, rng);
        let mut cov = CovAccum::new(n);
        cov.add_batch(&x);
        (w, cov, x)
    }

    #[test]
    fn full_rank_reconstructs_weight() {
        let mut rng = Rng::new(80);
        let (w, cov, _x) = random_setup(&mut rng, 6, 5, 64);
        let d = DataSvd::compute(&w, &cov, 1e-10);
        let w_full = d.truncated_weight(5);
        assert!(w_full.close_to(&w, 1e-6), "dist {}", w_full.frob_dist(&w));
    }

    #[test]
    fn datasvd_beats_plain_svd_on_anisotropic_data() {
        // When inputs concentrate along few directions, DataSVD's truncation
        // error in *output* space (Eq. 3) must not exceed plain SVD's.
        let mut rng = Rng::new(81);
        let n = 8;
        let m = 6;
        let w = Mat::randn(n, m, &mut rng);
        // Anisotropic activations: strong first 2 directions.
        let basis = Mat::randn(n, n, &mut rng).orthonormal_cols(n);
        let mut x = Mat::zeros(256, n);
        for i in 0..x.rows {
            for k in 0..n {
                let scale = if k < 2 { 4.0 } else { 0.25 };
                let c = rng.normal() * scale;
                for j in 0..n {
                    x[(i, j)] += c * basis[(j, k)];
                }
            }
        }
        let mut cov = CovAccum::new(n);
        cov.add_batch(&x);

        let data = DataSvd::compute(&w, &cov, 1e-10);
        let plain = DataSvd::compute_plain(&w);

        for r in 1..5 {
            let err_data = output_err(&x, &w, &data.truncated_weight(r));
            let err_plain = output_err(&x, &w, &plain.truncated_weight(r));
            assert!(
                err_data <= err_plain * 1.02 + 1e-9,
                "r={r}: data {err_data} > plain {err_plain}"
            );
        }
    }

    fn output_err(x: &Mat, w: &Mat, w_approx: &Mat) -> f64 {
        let d = &(x * w) - &(x * w_approx);
        d.frob_norm().powi(2) / x.rows as f64
    }

    #[test]
    fn recon_error_decreases_in_rank() {
        let mut rng = Rng::new(82);
        let (w, cov, _) = random_setup(&mut rng, 7, 7, 128);
        let d = DataSvd::compute(&w, &cov, 1e-10);
        let errs: Vec<f64> = (0..=7).map(|r| d.recon_error(&w, &cov, r)).collect();
        for win in errs.windows(2) {
            assert!(win[0] >= win[1] - 1e-9);
        }
        assert!(errs[7] < 1e-8);
    }

    #[test]
    fn gram_accumulation_matches_batch() {
        let mut rng = Rng::new(83);
        let x = Mat::randn(32, 5, &mut rng);
        let mut a = CovAccum::new(5);
        a.add_batch(&x);
        let mut b = CovAccum::new(5);
        b.add_gram(&(&x.t() * &x), 32);
        assert!(a.sigma.close_to(&b.sigma, 1e-9));
        assert_eq!(a.count, b.count);
    }
}
