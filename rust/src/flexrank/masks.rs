//! Rank profiles (the configuration vectors m_k of Sec. 3.2) and nested
//! chains over them.

/// Per-factorized-layer rank assignment — the paper's configuration vector
/// `m_k = {r_{k,l}}`.
pub type RankProfile = Vec<usize>;

/// Inference-time parameter cost of one factorized layer at rank r under GAR
/// (Sec. 3.5): `(m + n − r) · r` — strictly less than `(m + n) · r` naive and
/// `m·n` dense for any `r < min(m, n)`.
pub fn gar_layer_params(n: usize, m: usize, r: usize) -> usize {
    (m + n - r) * r
}

/// Total inference parameter cost of a profile over layer dims
/// `(n_in, m_out)` per layer.
pub fn profile_cost(dims: &[(usize, usize)], profile: &RankProfile) -> usize {
    assert_eq!(dims.len(), profile.len());
    dims.iter()
        .zip(profile)
        .map(|(&(n, m), &r)| gar_layer_params(n, m, r))
        .sum()
}

/// True iff `a ≤ b` componentwise (the paper's nestedness m_{k-1} ≤ m_k).
pub fn is_nested(a: &RankProfile, b: &RankProfile) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x <= y)
}

/// A componentwise-nested chain of profiles, ascending in cost.
#[derive(Debug, Clone)]
pub struct NestedChain {
    pub profiles: Vec<RankProfile>,
    /// Inference cost of each profile (same order).
    pub costs: Vec<usize>,
    /// Probe error of each profile (same order).
    pub errors: Vec<f64>,
}

impl NestedChain {
    /// Check the chain invariant.
    pub fn validate(&self) -> bool {
        self.profiles.windows(2).all(|w| is_nested(&w[0], &w[1]))
            && self.costs.windows(2).all(|w| w[0] <= w[1])
    }

    /// SELECTPROFILES (Alg. 1 line 13/19): for each budget fraction, the
    /// largest-cost profile with cost ≤ budget·full_cost (or the smallest
    /// profile if none fits).
    pub fn select(&self, budgets: &[f64], full_cost: usize) -> Vec<RankProfile> {
        budgets
            .iter()
            .map(|&beta| {
                let cap = (beta * full_cost as f64).round() as usize;
                let mut best: Option<usize> = None;
                for (i, &c) in self.costs.iter().enumerate() {
                    if c <= cap {
                        best = Some(i);
                    }
                }
                self.profiles[best.unwrap_or(0)].clone()
            })
            .collect()
    }
}

/// Uniform profile: same rank everywhere.
pub fn uniform_profile(n_layers: usize, r: usize) -> RankProfile {
    vec![r; n_layers]
}

/// Profile → per-layer 0/1 prefix masks flattened (for the PJRT student
/// `masks` input, shape (n_blocks, 4, rank_full) row-major).
pub fn profile_to_masks(profile: &RankProfile, rank_full: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(profile.len() * rank_full);
    for &r in profile {
        for i in 0..rank_full {
            out.push(if i < r { 1.0 } else { 0.0 });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gar_cost_below_naive_and_dense() {
        let (n, m) = (128, 512);
        for r in 1..128 {
            let gar = gar_layer_params(n, m, r);
            assert!(gar < (m + n) * r);
            assert!(gar < m * n, "r={r}");
        }
    }

    #[test]
    fn nestedness_check() {
        assert!(is_nested(&vec![1, 2, 3], &vec![1, 2, 3]));
        assert!(is_nested(&vec![1, 2, 2], &vec![1, 2, 3]));
        assert!(!is_nested(&vec![2, 2, 3], &vec![1, 9, 9]));
        assert!(!is_nested(&vec![1, 2], &vec![1, 2, 3]));
    }

    #[test]
    fn select_profiles_respects_budgets() {
        let chain = NestedChain {
            profiles: vec![vec![1, 1], vec![2, 2], vec![4, 4]],
            costs: vec![10, 20, 40],
            errors: vec![3.0, 1.0, 0.0],
        };
        assert!(chain.validate());
        let sel = chain.select(&[0.25, 0.55, 1.0], 40);
        assert_eq!(sel[0], vec![1, 1]);
        assert_eq!(sel[1], vec![2, 2]);
        assert_eq!(sel[2], vec![4, 4]);
    }

    #[test]
    fn masks_are_prefix() {
        let m = profile_to_masks(&vec![2, 0, 3], 3);
        assert_eq!(m, vec![1., 1., 0., 0., 0., 0., 1., 1., 1.]);
    }
}
