//! Gauge-Aligned Reparametrization (Sec. 3.5).
//!
//! Once a rank r is fixed, the factorization `W_paper = U_r V_rᵀ` is
//! non-unique under `U → U G`, `V → V G⁻ᵀ`.  Choosing `G = (U_r)_{1:r,:}⁻¹`
//! makes the top r×r block of `Ũ = U_r G` the identity, which is then never
//! stored nor multiplied: a matvec costs `(m + n − r)·r` MACs instead of
//! `(m + n)·r`, strictly below dense `m·n` for any `r < min(m, n)`.

use anyhow::{ensure, Context, Result};

use crate::linalg::{inverse, kernels, lu_solve_many, AlignedVec, Mat};

/// GAR form of a rank-r layer: `Ũ = [I_r; Û]`, `Ṽ`.
#[derive(Debug, Clone)]
pub struct Gar {
    /// (m − r, r) — the non-identity rows of Ũ.
    pub u_hat: Mat,
    /// (n, r) — re-gauged right factor.
    pub v_tilde: Mat,
    pub rank: usize,
}

impl Gar {
    /// Re-gauge truncated factors `u: (m, k)`, `v: (n, k)` at rank `r ≤ k`.
    ///
    /// The gauge is `G = (U_r)_{1:r,:}⁻¹` — requires the leading r×r block of
    /// the truncated U to be invertible (generic; the caller falls back to
    /// [`Gar::from_factors_pivoted`] if not).
    pub fn from_factors(u: &Mat, v: &Mat, r: usize) -> Result<Gar> {
        ensure!(r >= 1 && r <= u.cols && r <= v.cols, "bad rank {r}");
        ensure!(r <= u.rows, "rank {} exceeds output dim {}", r, u.rows);
        let ur = u.slice_cols(0, r); // (m, r)
        let vr = v.slice_cols(0, r); // (n, r)
        let head = ur.slice_rows(0, r); // (r, r)
        let g = inverse(&head).context("GAR gauge: leading block singular")?;
        let u_tilde = &ur * &g; // (m, r), top block = I
        let u_hat = u_tilde.slice_rows(r, u.rows - r);
        // Ṽ = V_r G⁻ᵀ  ⇔  Ṽᵀ = G⁻¹ V_rᵀ  ⇔  solve headᵀ? — G⁻¹ = head, so
        // Ṽ = V_r headᵀ.
        let v_tilde = &vr * &head.t();
        Ok(Gar { u_hat, v_tilde, rank: r })
    }

    /// GAR cost in MACs for one matvec: `(m + n − r) · r`.
    pub fn macs(n: usize, m: usize, r: usize) -> usize {
        (m + n - r) * r
    }

    /// Output dimension `m = r + (m − r)`.
    pub fn out_dim(&self) -> usize {
        self.rank + self.u_hat.rows
    }

    /// Forward: `y = [t, t Ûᵀ]` with `t = x Ṽ`; x is (B, n) row-major.
    ///
    /// Fused single-kernel path: `t` lands in scratch once, and the output
    /// stage streams `[t, t·Ûᵀ]` directly into `y` — no intermediate `rest`
    /// matrix and no assembly copy loop (the old implementation is preserved
    /// as [`crate::linalg::reference::gar_forward`]).
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut t = Mat::zeros(x.rows, self.rank);
        let mut y = Mat::zeros(x.rows, self.out_dim());
        self.forward_into(x, &mut t, &mut y);
        y
    }

    /// Allocation-free fused forward: `t` is `(B, r)` scratch, `y` the
    /// `(B, m)` output — both fully overwritten, reusable across calls.
    pub fn forward_into(&self, x: &Mat, t: &mut Mat, y: &mut Mat) {
        kernels::matmul_into(x, &self.v_tilde, t);
        kernels::gar_emit(t, &self.u_hat, y);
    }

    /// Fused forward drawing scratch from (and returning it to) `arena` —
    /// zero allocations once the arena is warm, and the returned buffer is
    /// 64-byte aligned.  Row-major `(B, m)`; callers hand it back via
    /// [`kernels::Arena::give`].  Bit-identical to [`Gar::forward_into`]
    /// (same slice kernels, same order).
    pub fn forward_arena(&self, x: &Mat, arena: &mut kernels::Arena) -> AlignedVec<f64> {
        let (rows, r, m) = (x.rows, self.rank, self.out_dim());
        let mut t = arena.take(rows * r);
        let mut y = arena.take(rows * m);
        kernels::matmul_f64(&x.data, &self.v_tilde.data, rows, x.cols, r, &mut t);
        kernels::gar_emit_f64(&t, rows, r, &self.u_hat.data, self.u_hat.rows, &mut y, m, 0);
        arena.give(t);
        y
    }

    /// Effective row-convention weight `(Ũ Ṽᵀ)ᵀ = Ṽ Ũᵀ` (n × m), for checks.
    pub fn effective_weight(&self) -> Mat {
        let m = self.rank + self.u_hat.rows;
        let mut u_tilde = Mat::zeros(m, self.rank);
        for i in 0..self.rank {
            u_tilde[(i, i)] = 1.0;
        }
        for i in 0..self.u_hat.rows {
            for j in 0..self.rank {
                u_tilde[(self.rank + i, j)] = self.u_hat[(i, j)];
            }
        }
        kernels::matmul_nt(&self.v_tilde, &u_tilde)
    }
}

/// Batch-convert truncated factors via LU solve (equivalent to
/// [`Gar::from_factors`] but solving instead of inverting; used by the
/// pipeline for the marginally better conditioning).
pub fn gar_solve(u: &Mat, v: &Mat, r: usize) -> Result<Gar> {
    ensure!(r >= 1 && r <= u.cols && r <= v.cols && r <= u.rows, "bad rank {r}");
    let ur = u.slice_cols(0, r);
    let vr = v.slice_cols(0, r);
    let head = ur.slice_rows(0, r); // (r, r)
    // Û = U_tail · G where G = head⁻¹  ⇔  Ûᵀ = G ᵀ U_tailᵀ = (headᵀ)⁻¹ U_tailᵀ
    // → solve headᵀ X = U_tailᵀ.
    let tail = ur.slice_rows(r, u.rows - r); // (m-r, r)
    let u_hat_t = lu_solve_many(&head.t(), &tail.t()).context("GAR solve")?; // (r, m-r)
    let v_tilde = &vr * &head.t();
    Ok(Gar { u_hat: u_hat_t.t(), v_tilde, rank: r })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::rng::Rng;

    #[test]
    fn gar_preserves_function() {
        let mut rng = Rng::new(90);
        let (n, m, k) = (6, 9, 6);
        let u = Mat::randn(m, k, &mut rng);
        let v = Mat::randn(n, k, &mut rng);
        for r in 1..=5 {
            let gar = Gar::from_factors(&u, &v, r).unwrap();
            // Truncated weight (row conv): V_r U_rᵀ
            let want = &v.slice_cols(0, r) * &u.slice_cols(0, r).t();
            assert!(
                gar.effective_weight().close_to(&want, 1e-8),
                "r={r} weight mismatch"
            );
            // Forward matches x @ W.
            let x = Mat::randn(4, n, &mut rng);
            let y = gar.forward(&x);
            assert!(y.close_to(&(&x * &want), 1e-8), "r={r} forward mismatch");
        }
    }

    #[test]
    fn gar_solve_equals_inverse_path() {
        let mut rng = Rng::new(91);
        let u = Mat::randn(7, 5, &mut rng);
        let v = Mat::randn(4, 5, &mut rng);
        for r in 1..=4 {
            let a = Gar::from_factors(&u, &v, r).unwrap();
            let b = gar_solve(&u, &v, r).unwrap();
            assert!(a.u_hat.close_to(&b.u_hat, 1e-8));
            assert!(a.v_tilde.close_to(&b.v_tilde, 1e-8));
        }
    }

    #[test]
    fn gar_cost_strictly_below_alternatives() {
        for (n, m) in [(128usize, 384usize), (512, 128), (128, 128)] {
            for r in 1..n.min(m) {
                let g = Gar::macs(n, m, r);
                assert!(g < (m + n) * r, "naive");
                assert!(g < m * n, "dense (n={n} m={m} r={r})");
            }
        }
    }

    #[test]
    fn full_rank_square_has_empty_uhat() {
        let mut rng = Rng::new(92);
        let u = Mat::randn(5, 5, &mut rng);
        let v = Mat::randn(8, 5, &mut rng);
        let gar = Gar::from_factors(&u, &v, 5).unwrap();
        assert_eq!(gar.u_hat.rows, 0);
        let x = Mat::randn(3, 8, &mut rng);
        let want = &x * &(&v * &u.t());
        assert!(gar.forward(&x).close_to(&want, 1e-8));
    }

    #[test]
    fn property_fused_forward_matches_reference() {
        use crate::linalg::reference;
        prop::forall(
            102,
            30,
            |rng| {
                // Random GAR factors directly (no invertibility concerns),
                // including the edge shapes: B = 1, n = 1, r = m (empty Û).
                let n = prop::gen::dim(rng, 1, 12);
                let m = prop::gen::dim(rng, 1, 12);
                let r = 1 + rng.below(m);
                let b = prop::gen::dim(rng, 1, 9);
                let gar = Gar {
                    u_hat: Mat::randn(m - r, r, rng),
                    v_tilde: Mat::randn(n, r, rng),
                    rank: r,
                };
                (gar, Mat::randn(b, n, rng))
            },
            |(gar, x)| {
                let fused = gar.forward(x);
                let naive = reference::gar_forward(&gar.u_hat, &gar.v_tilde, gar.rank, x);
                if !fused.close_to(&naive, 1e-10) {
                    return Err(format!(
                        "fused/reference mismatch (B={} n={} m={} r={})",
                        x.rows,
                        gar.v_tilde.rows,
                        gar.out_dim(),
                        gar.rank
                    ));
                }
                // Arena path must agree bit-for-bit with the plain path.
                let mut arena = crate::linalg::kernels::Arena::new();
                let a1 = gar.forward_arena(x, &mut arena);
                if a1[..] != fused.data[..] {
                    return Err("arena path diverged".into());
                }
                arena.give(a1);
                Ok(())
            },
        );
    }

    #[test]
    fn property_gar_function_preservation() {
        prop::forall(
            101,
            25,
            |rng| {
                let n = prop::gen::dim(rng, 2, 12);
                let m = prop::gen::dim(rng, 2, 12);
                let k = n.min(m);
                let r = 1 + rng.below(k.min(m));
                (Mat::randn(m, k, rng), Mat::randn(n, k, rng), r)
            },
            |(u, v, r)| {
                let gar = match Gar::from_factors(u, v, *r) {
                    Err(_) => return Ok(()), // singular head block: acceptable draw
                    Ok(g) => g,
                };
                let want = &v.slice_cols(0, *r) * &u.slice_cols(0, *r).t();
                if !gar.effective_weight().close_to(&want, 1e-6) {
                    return Err("weight not preserved".into());
                }
                Ok(())
            },
        );
    }
}
