//! Synthetic data substrates (DESIGN.md §substitutions).
//!
//! * [`corpus`] — hierarchical-grammar byte corpus (stands in for
//!   FineWebEdu): Zipf word distribution + sentence templates + nesting, so
//!   a small LM has real structure to learn; held-out split for eval loss.
//! * [`digits`] — structured cluster "digits" (stands in for MNIST/ImageNet
//!   in the controlled Fig. 3 experiments).
//! * [`domains`] — math-expression and bracket-code corpora for the Tab. 1
//!   LoRA post-adaptation experiments.
//! * [`trace`] — synthetic serving request traces (Poisson arrivals, mixed
//!   budget SLOs) for the coordinator.

pub mod corpus;
pub mod digits;
pub mod domains;
pub mod trace;

pub use corpus::{Corpus, TokenBatcher};
pub use digits::Digits;
pub use trace::{ArrivalShape, Request, TenantCfg, TraceCfg, TraceGen};
