//! Synthetic serving request traces for the elastic coordinator.
//!
//! Poisson arrivals; each request carries a latency SLO class, a token
//! payload, and (for the incremental decode path) a generation length.
//! Stands in for the production traces the paper's deployment story
//! assumes (DESIGN.md §substitutions).

use crate::rng::Rng;

/// SLO class of a request — maps to a serving tier (budget) by policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Slo {
    /// Interactive: tight latency, accepts the smallest viable submodel.
    Interactive,
    /// Standard: balanced.
    Standard,
    /// Batch/quality: wants the largest submodel, latency-insensitive.
    Quality,
}

impl Slo {
    pub const ALL: [Slo; 3] = [Slo::Interactive, Slo::Standard, Slo::Quality];
}

/// One serving request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset from trace start (seconds).
    pub arrival_s: f64,
    pub slo: Slo,
    /// Prompt tokens, values in [0, vocab).  The legacy one-shot path
    /// expects exactly `seq_len` of them; the incremental decode path
    /// accepts any prompt length with `prompt + gen_len ≤ seq_len`.
    pub tokens: Vec<i32>,
    /// Tokens to generate after the prompt (0 = prefill-only / legacy
    /// one-shot window semantics).
    pub gen_len: usize,
    /// Optional explicit budget override.  Contract: finite and in (0, 1]
    /// — `serve_trace` rejects anything else at ingest rather than letting
    /// the tier arithmetic silently absorb NaN or out-of-range values.
    pub budget: Option<f64>,
}

impl Request {
    /// K/V capacity the request needs end to end (prompt + generation).
    pub fn total_tokens(&self) -> usize {
        self.tokens.len() + self.gen_len
    }
}

/// Trace generation knobs.
#[derive(Debug, Clone)]
pub struct TraceCfg {
    pub n_requests: usize,
    /// Mean arrival rate (req/s).
    pub rate: f64,
    /// Mix over SLO classes (interactive, standard, quality).
    pub slo_mix: [f64; 3],
    pub seq_len: usize,
    pub vocab: usize,
    pub seed: u64,
    /// Prompt-length distribution, uniform in `[prompt_len_min,
    /// prompt_len_max]`.  `prompt_len_max == 0` (the default) keeps the
    /// legacy fixed-`seq_len` prompts.
    pub prompt_len_min: usize,
    pub prompt_len_max: usize,
    /// Generation-length distribution, uniform in `[gen_len_min,
    /// gen_len_max]`, clamped so `prompt + gen ≤ seq_len` (the positional
    /// table bound).  `gen_len_max == 0` (the default) generates nothing —
    /// the legacy one-shot trace.
    pub gen_len_min: usize,
    pub gen_len_max: usize,
}

impl Default for TraceCfg {
    fn default() -> Self {
        TraceCfg {
            n_requests: 200,
            rate: 50.0,
            slo_mix: [0.5, 0.3, 0.2],
            seq_len: 64,
            vocab: 256,
            seed: 77,
            prompt_len_min: 0,
            prompt_len_max: 0,
            gen_len_min: 0,
            gen_len_max: 0,
        }
    }
}

/// Deterministic trace generator.
pub struct TraceGen {
    cfg: TraceCfg,
    rng: Rng,
    t: f64,
    issued: u64,
    source: Vec<u8>,
}

impl TraceGen {
    pub fn new(cfg: TraceCfg, source_text: &[u8]) -> Self {
        let rng = Rng::new(cfg.seed);
        TraceGen { cfg, rng, t: 0.0, issued: 0, source: source_text.to_vec() }
    }

    /// Generate the full trace.
    pub fn generate(mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.cfg.n_requests);
        while out.len() < self.cfg.n_requests {
            out.push(self.next_request());
        }
        out
    }

    fn next_request(&mut self) -> Request {
        // Exponential inter-arrival.
        let u = self.rng.f64().max(1e-12);
        self.t += -u.ln() / self.cfg.rate;
        let slo = Slo::ALL[self.rng.weighted(&self.cfg.slo_mix)];
        let prompt_len = if self.cfg.prompt_len_max == 0 {
            self.cfg.seq_len
        } else {
            let lo = self.cfg.prompt_len_min.clamp(1, self.cfg.seq_len);
            let hi = self.cfg.prompt_len_max.clamp(lo, self.cfg.seq_len);
            lo + self.rng.below(hi - lo + 1)
        };
        let gen_len = if self.cfg.gen_len_max == 0 {
            0
        } else {
            let lo = self.cfg.gen_len_min.min(self.cfg.gen_len_max);
            let drawn = lo + self.rng.below(self.cfg.gen_len_max - lo + 1);
            // A stream never outgrows the positional table.
            drawn.min(self.cfg.seq_len - prompt_len)
        };
        let start = self.rng.below(self.source.len().saturating_sub(prompt_len).max(1));
        let tokens: Vec<i32> = (0..prompt_len)
            .map(|i| {
                let b = self.source.get(start + i).copied().unwrap_or(b' ');
                (b as usize % self.cfg.vocab) as i32
            })
            .collect();
        self.issued += 1;
        Request { id: self.issued, arrival_s: self.t, slo, tokens, gen_len, budget: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(n: usize, seed: u64) -> Vec<Request> {
        let cfg = TraceCfg { n_requests: n, seed, ..Default::default() };
        TraceGen::new(cfg, b"hello world this is source text for requests").generate()
    }

    #[test]
    fn arrivals_monotone_and_deterministic() {
        let a = trace(100, 1);
        let b = trace(100, 1);
        assert_eq!(a.len(), 100);
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.slo, y.slo);
        }
    }

    #[test]
    fn slo_mix_roughly_respected() {
        let a = trace(3000, 2);
        let inter = a.iter().filter(|r| r.slo == Slo::Interactive).count() as f64 / 3000.0;
        assert!((inter - 0.5).abs() < 0.05, "interactive fraction {inter}");
    }

    #[test]
    fn tokens_in_range() {
        let a = trace(50, 3);
        assert!(a.iter().all(|r| r.tokens.iter().all(|&t| (0..256).contains(&t))));
        assert!(a.iter().all(|r| r.tokens.len() == 64 && r.gen_len == 0));
    }

    #[test]
    fn variable_length_distributions_respect_bounds() {
        let cfg = TraceCfg {
            n_requests: 500,
            seq_len: 32,
            prompt_len_min: 4,
            prompt_len_max: 24,
            gen_len_min: 2,
            gen_len_max: 16,
            seed: 9,
            ..Default::default()
        };
        let a = TraceGen::new(cfg, b"variable length source text for decode traces").generate();
        for r in &a {
            assert!((4..=24).contains(&r.tokens.len()), "prompt {}", r.tokens.len());
            assert!(r.gen_len <= 16);
            assert!(r.total_tokens() <= 32, "stream {} outgrows seq_len", r.total_tokens());
        }
        // Both knobs actually vary…
        assert!(a.iter().any(|r| r.tokens.len() != a[0].tokens.len()));
        assert!(a.iter().any(|r| r.gen_len >= 1), "generation lengths all clamped to zero");
    }
}
