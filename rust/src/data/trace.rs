//! Synthetic serving request traces for the elastic coordinator.
//!
//! Poisson arrivals; each request carries a latency SLO class, a token
//! payload, and (for the incremental decode path) a generation length.
//! Stands in for the production traces the paper's deployment story
//! assumes (DESIGN.md §substitutions).

use anyhow::{ensure, Result};

use crate::rng::Rng;

/// SLO class of a request — maps to a serving tier (budget) by policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Slo {
    /// Interactive: tight latency, accepts the smallest viable submodel.
    Interactive,
    /// Standard: balanced.
    Standard,
    /// Batch/quality: wants the largest submodel, latency-insensitive.
    Quality,
}

impl Slo {
    pub const ALL: [Slo; 3] = [Slo::Interactive, Slo::Standard, Slo::Quality];

    /// Stable one-byte wire encoding (see [`wire`]).
    pub fn code(self) -> u8 {
        match self {
            Slo::Interactive => 0,
            Slo::Standard => 1,
            Slo::Quality => 2,
        }
    }

    pub fn from_code(c: u8) -> Option<Slo> {
        Slo::ALL.get(c as usize).copied()
    }
}

/// One serving request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset from trace start (seconds).
    pub arrival_s: f64,
    pub slo: Slo,
    /// Prompt tokens, values in [0, vocab).  The legacy one-shot path
    /// expects exactly `seq_len` of them; the incremental decode path
    /// accepts any prompt length with `prompt + gen_len ≤ seq_len`.
    pub tokens: Vec<i32>,
    /// Tokens to generate after the prompt (0 = prefill-only / legacy
    /// one-shot window semantics).
    pub gen_len: usize,
    /// Optional explicit budget override.  Contract: finite and in (0, 1]
    /// — `serve_trace` rejects anything else at ingest rather than letting
    /// the tier arithmetic silently absorb NaN or out-of-range values.
    pub budget: Option<f64>,
}

impl Request {
    /// K/V capacity the request needs end to end (prompt + generation).
    pub fn total_tokens(&self) -> usize {
        self.tokens.len() + self.gen_len
    }
}

/// Arrival-shape of the trace: how the instantaneous Poisson rate evolves
/// over the trace clock.  Shapes the load the elastic controller must ride
/// out; the serving bench sweeps policies across these scenarios.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalShape {
    /// Constant-rate Poisson arrivals (the legacy trace).
    Steady,
    /// Sinusoidal rate swing: `rate · (1 + swing · sin(2π t / period_s))`.
    /// Models the slow day/night cycle a deployed fleet sees.
    Diurnal { period_s: f64, swing: f64 },
    /// Alternating phases: `burst_s` seconds at `mult ×` the base rate,
    /// then `idle_s` seconds at the base rate.  The overload scenario the
    /// Pareto acceptance criterion measures.
    Bursty { burst_s: f64, idle_s: f64, mult: f64 },
    /// Worst-case clumping: every `clump` consecutive requests arrive at
    /// the same instant, all Quality-class with full-length prompts —
    /// load concentrated on the largest tier.
    Adversarial { clump: usize },
}

impl ArrivalShape {
    /// Parse a CLI scenario name with built-in default parameters
    /// ("steady" | "diurnal" | "bursty" | "adversarial").
    pub fn parse(s: &str) -> Result<ArrivalShape> {
        match s {
            "steady" => Ok(ArrivalShape::Steady),
            "diurnal" => Ok(ArrivalShape::Diurnal { period_s: 2.0, swing: 0.8 }),
            "bursty" => Ok(ArrivalShape::Bursty { burst_s: 0.25, idle_s: 0.75, mult: 8.0 }),
            "adversarial" => Ok(ArrivalShape::Adversarial { clump: 8 }),
            other => anyhow::bail!(
                "unknown scenario {other:?} (steady|diurnal|bursty|adversarial)"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArrivalShape::Steady => "steady",
            ArrivalShape::Diurnal { .. } => "diurnal",
            ArrivalShape::Bursty { .. } => "bursty",
            ArrivalShape::Adversarial { .. } => "adversarial",
        }
    }
}

impl Default for ArrivalShape {
    fn default() -> Self {
        ArrivalShape::Steady
    }
}

/// One tenant in a multi-tenant trace mix: a traffic share, an optional
/// contracted budget override stamped onto every request, and the tenant's
/// own SLO mix.
#[derive(Debug, Clone, Copy)]
pub struct TenantCfg {
    /// Relative traffic weight (positive; normalised across tenants).
    pub weight: f64,
    /// Contracted budget in (0, 1] stamped as the explicit per-request
    /// override, or `None` for SLO-routed traffic.
    pub budget: Option<f64>,
    pub slo_mix: [f64; 3],
}

impl TenantCfg {
    /// A representative 4-tenant mix: two SLO-routed tenants plus two
    /// budget-contracted ones (a cheap bulk tenant and a premium one).
    pub fn default_mix() -> Vec<TenantCfg> {
        vec![
            TenantCfg { weight: 0.4, budget: None, slo_mix: [0.7, 0.2, 0.1] },
            TenantCfg { weight: 0.3, budget: None, slo_mix: [0.1, 0.3, 0.6] },
            TenantCfg { weight: 0.2, budget: Some(0.3), slo_mix: [0.5, 0.5, 0.0] },
            TenantCfg { weight: 0.1, budget: Some(1.0), slo_mix: [0.0, 0.2, 0.8] },
        ]
    }
}

/// Trace generation knobs.
#[derive(Debug, Clone)]
pub struct TraceCfg {
    pub n_requests: usize,
    /// Mean arrival rate (req/s).
    pub rate: f64,
    /// Mix over SLO classes (interactive, standard, quality).
    pub slo_mix: [f64; 3],
    pub seq_len: usize,
    pub vocab: usize,
    pub seed: u64,
    /// Prompt-length distribution, uniform in `[prompt_len_min,
    /// prompt_len_max]`.  `prompt_len_max == 0` (the default) keeps the
    /// legacy fixed-`seq_len` prompts.
    pub prompt_len_min: usize,
    pub prompt_len_max: usize,
    /// Generation-length distribution, uniform in `[gen_len_min,
    /// gen_len_max]`, clamped so `prompt + gen ≤ seq_len` (the positional
    /// table bound).  `gen_len_max == 0` (the default) generates nothing —
    /// the legacy one-shot trace.
    pub gen_len_min: usize,
    pub gen_len_max: usize,
    /// How the instantaneous arrival rate evolves (default: steady).
    pub shape: ArrivalShape,
    /// Multi-tenant mix; empty (the default) keeps the single-tenant
    /// legacy trace driven by `slo_mix` alone.
    pub tenants: Vec<TenantCfg>,
}

impl Default for TraceCfg {
    fn default() -> Self {
        TraceCfg {
            n_requests: 200,
            rate: 50.0,
            slo_mix: [0.5, 0.3, 0.2],
            seq_len: 64,
            vocab: 256,
            seed: 77,
            prompt_len_min: 0,
            prompt_len_max: 0,
            gen_len_min: 0,
            gen_len_max: 0,
            shape: ArrivalShape::Steady,
            tenants: Vec::new(),
        }
    }
}

impl TraceCfg {
    /// Reject contradictory configs loudly instead of silently degrading.
    ///
    /// The headline case (regression-tested): `gen_len_max > 0` with the
    /// legacy fixed-length prompts (`prompt_len_max == 0`) used to clamp
    /// every `gen_len` to 0 — full-`seq_len` prompts leave no positional
    /// room — turning a decode trace into prefill-only without a word.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.rate.is_finite() && self.rate > 0.0,
            "trace rate must be positive and finite, got {}",
            self.rate
        );
        ensure!(self.seq_len >= 1, "seq_len must be >= 1");
        ensure!(self.vocab >= 1, "vocab must be >= 1");
        ensure!(
            self.slo_mix.iter().all(|w| w.is_finite() && *w >= 0.0)
                && self.slo_mix.iter().sum::<f64>() > 0.0,
            "slo_mix must be non-negative with positive mass, got {:?}",
            self.slo_mix
        );
        ensure!(
            self.gen_len_max == 0 || self.prompt_len_max > 0,
            "contradictory trace config: gen_len_max = {} asks for generation, but \
             prompt_len_max == 0 keeps legacy fixed seq_len ({}) prompts that fill \
             the positional table — every gen_len would silently clamp to 0.  Set \
             prompt_len_max < seq_len (variable prompts) or gen_len_max = 0 \
             (one-shot window trace)",
            self.gen_len_max,
            self.seq_len
        );
        if self.gen_len_max > 0 {
            ensure!(
                self.prompt_len_min.max(1) + self.gen_len_min <= self.seq_len,
                "prompt_len_min ({}) + gen_len_min ({}) exceeds seq_len ({})",
                self.prompt_len_min,
                self.gen_len_min,
                self.seq_len
            );
        }
        match self.shape {
            ArrivalShape::Steady => {}
            ArrivalShape::Diurnal { period_s, swing } => {
                ensure!(
                    period_s.is_finite() && period_s > 0.0,
                    "diurnal period_s must be positive, got {period_s}"
                );
                ensure!(
                    (0.0..1.0).contains(&swing),
                    "diurnal swing must be in [0, 1), got {swing}"
                );
            }
            ArrivalShape::Bursty { burst_s, idle_s, mult } => {
                ensure!(
                    burst_s.is_finite() && burst_s > 0.0 && idle_s.is_finite() && idle_s >= 0.0,
                    "bursty phases must be positive, got burst_s={burst_s} idle_s={idle_s}"
                );
                ensure!(
                    mult.is_finite() && mult >= 1.0,
                    "bursty mult must be >= 1, got {mult}"
                );
            }
            ArrivalShape::Adversarial { clump } => {
                ensure!(clump >= 2, "adversarial clump must be >= 2, got {clump}");
            }
        }
        for (i, t) in self.tenants.iter().enumerate() {
            ensure!(
                t.weight.is_finite() && t.weight > 0.0,
                "tenant {i}: weight must be positive, got {}",
                t.weight
            );
            if let Some(b) = t.budget {
                ensure!(
                    b.is_finite() && b > 0.0 && b <= 1.0,
                    "tenant {i}: budget must be in (0, 1], got {b}"
                );
            }
            ensure!(
                t.slo_mix.iter().all(|w| w.is_finite() && *w >= 0.0)
                    && t.slo_mix.iter().sum::<f64>() > 0.0,
                "tenant {i}: slo_mix must be non-negative with positive mass, got {:?}",
                t.slo_mix
            );
        }
        Ok(())
    }
}

/// Deterministic trace generator.
pub struct TraceGen {
    cfg: TraceCfg,
    rng: Rng,
    t: f64,
    issued: u64,
    source: Vec<u8>,
}

impl TraceGen {
    /// Validating constructor — a contradictory [`TraceCfg`] is rejected
    /// here, before a single request is drawn.
    pub fn new(cfg: TraceCfg, source_text: &[u8]) -> Result<Self> {
        cfg.validate()?;
        let rng = Rng::new(cfg.seed);
        Ok(TraceGen { cfg, rng, t: 0.0, issued: 0, source: source_text.to_vec() })
    }

    /// Instantaneous arrival rate at trace time `t` under the configured
    /// shape (adversarial clumping is handled in `next_request` directly).
    fn rate_at(&self, t: f64) -> f64 {
        let base = self.cfg.rate;
        match self.cfg.shape {
            ArrivalShape::Steady | ArrivalShape::Adversarial { .. } => base,
            ArrivalShape::Diurnal { period_s, swing } => {
                base * (1.0 + swing * (std::f64::consts::TAU * t / period_s).sin())
            }
            ArrivalShape::Bursty { burst_s, idle_s, mult } => {
                let phase = t % (burst_s + idle_s);
                if phase < burst_s {
                    base * mult
                } else {
                    base
                }
            }
        }
    }

    /// Generate the full trace.
    pub fn generate(mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.cfg.n_requests);
        while out.len() < self.cfg.n_requests {
            out.push(self.next_request());
        }
        out
    }

    fn next_request(&mut self) -> Request {
        // Adversarial clumping: all but the first request of each clump
        // arrive at the same instant as the clump head.
        let clumped = match self.cfg.shape {
            ArrivalShape::Adversarial { clump } => self.issued % clump as u64 != 0,
            _ => false,
        };
        if !clumped {
            // Exponential inter-arrival at the shape's instantaneous rate.
            let u = self.rng.f64().max(1e-12);
            self.t += -u.ln() / self.rate_at(self.t);
        }
        // Tenant mix overrides the trace-wide SLO mix and may stamp a
        // contracted budget; adversarial clumps force Quality-class load.
        let (mix, budget) = if self.cfg.tenants.is_empty() {
            (self.cfg.slo_mix, None)
        } else {
            let mut weights = [0.0f64; 8];
            let n = self.cfg.tenants.len().min(weights.len());
            for (w, t) in weights.iter_mut().zip(self.cfg.tenants.iter()) {
                *w = t.weight;
            }
            let tenant = &self.cfg.tenants[self.rng.weighted(&weights[..n])];
            (tenant.slo_mix, tenant.budget)
        };
        let slo = if clumped { Slo::Quality } else { Slo::ALL[self.rng.weighted(&mix)] };
        let prompt_len = if self.cfg.prompt_len_max == 0 || clumped {
            self.cfg.seq_len.saturating_sub(if clumped { self.cfg.gen_len_min } else { 0 })
        } else {
            let lo = self.cfg.prompt_len_min.clamp(1, self.cfg.seq_len);
            let hi = self.cfg.prompt_len_max.clamp(lo, self.cfg.seq_len);
            lo + self.rng.below(hi - lo + 1)
        };
        let gen_len = if self.cfg.gen_len_max == 0 {
            0
        } else {
            let lo = self.cfg.gen_len_min.min(self.cfg.gen_len_max);
            let drawn = lo + self.rng.below(self.cfg.gen_len_max - lo + 1);
            // A stream never outgrows the positional table.
            drawn.min(self.cfg.seq_len - prompt_len)
        };
        let start = self.rng.below(self.source.len().saturating_sub(prompt_len).max(1));
        let tokens: Vec<i32> = (0..prompt_len)
            .map(|i| {
                let b = self.source.get(start + i).copied().unwrap_or(b' ');
                (b as usize % self.cfg.vocab) as i32
            })
            .collect();
        self.issued += 1;
        Request { id: self.issued, arrival_s: self.t, slo, tokens, gen_len, budget }
    }
}

pub mod wire {
    //! The serving wire protocol: length-prefixed request/response frames.
    //!
    //! One frame = a 6-byte header (`magic`, `version`, `payload_len` u32
    //! LE) followed by `payload_len` bytes.  Request payload layout (all
    //! integers little-endian):
    //!
    //! ```text
    //! id: u64 | flags: u8 (bit0 = has budget) | budget: f64 | slo: u8
    //! | gen_len: u32 | n_tokens: u32 | tokens: n_tokens × i32
    //! ```
    //!
    //! Response payload: `id: u64 | status: u8 | n_tokens: u32 | tokens`,
    //! where `status` is [`Status`] (`Ok` carries the generated tokens,
    //! `Shed` is the 503-style load-shedding refusal, `Error` a per-request
    //! framing/contract rejection).  Responses are id-tagged and may arrive
    //! out of submission order on a pipelined connection.
    //!
    //! The client side ([`encode_request`], [`decode_response`]) is used by
    //! the serving bench, the `listen_client` example, and the listener
    //! tests; the server side ([`decode_request`] into a reusable
    //! [`RequestSlot`], [`encode_response`]) is what
    //! `coordinator::listener` runs on its zero-allocation ingest path.
    //! Request decoding touches only caller-provided buffers — the
    //! fingerprint test in `tests/fuzz_ingest.rs` pins that decoding `N`
    //! frames through one slot performs zero heap allocations.

    use anyhow::{bail, ensure, Result};

    use super::{Request, Slo};
    use crate::json::pull::{Event, PullParser};

    pub const REQ_MAGIC: u8 = 0xF7;
    pub const RESP_MAGIC: u8 = 0xF8;
    pub const VERSION: u8 = 1;
    /// Frame header bytes: magic, version, payload_len u32.
    pub const HEADER_LEN: usize = 6;
    /// Request payload bytes before the token array.
    pub const REQ_FIXED: usize = 8 + 1 + 8 + 1 + 4 + 4;
    /// Hard ceiling on any accepted payload length; a length prefix past
    /// this is a framing attack (or corruption), not a big request.
    pub const MAX_PAYLOAD: usize = 1 << 20;

    /// Response status byte.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Status {
        Ok,
        /// Load shed: admission queue saturated, retry later (HTTP 503).
        Shed,
        /// Malformed frame or ingest-contract violation (HTTP 400).
        Error,
    }

    impl Status {
        pub fn code(self) -> u8 {
            match self {
                Status::Ok => 0,
                Status::Shed => 1,
                Status::Error => 2,
            }
        }

        pub fn from_code(c: u8) -> Option<Status> {
            [Status::Ok, Status::Shed, Status::Error].get(c as usize).copied()
        }
    }

    /// A parsed request in caller-owned storage.  The token buffer is
    /// reused across frames on a connection: `decode_request` clears and
    /// refills it but never grows it past its construction capacity, so
    /// steady-state ingest performs no allocation (`fingerprint` pins the
    /// buffer identity for tests).
    #[derive(Debug)]
    pub struct RequestSlot {
        pub id: u64,
        pub budget: Option<f64>,
        pub slo: Slo,
        pub gen_len: usize,
        pub tokens: Vec<i32>,
    }

    impl RequestSlot {
        /// A slot able to hold up to `max_tokens` prompt tokens without
        /// ever reallocating.
        pub fn with_capacity(max_tokens: usize) -> Self {
            RequestSlot {
                id: 0,
                budget: None,
                slo: Slo::Standard,
                gen_len: 0,
                tokens: Vec::with_capacity(max_tokens),
            }
        }

        /// Buffer identity (pointer, capacity) — flat across decodes.
        pub fn fingerprint(&self) -> (usize, usize) {
            (self.tokens.as_ptr() as usize, self.tokens.capacity())
        }

        /// Move the parsed request out, installing `replacement` (a
        /// recycled buffer from the connection's pool) as the next parse
        /// target.  No allocation: ownership swaps, nothing is copied.
        pub fn take_request(&mut self, arrival_s: f64, replacement: Vec<i32>) -> Request {
            let tokens = std::mem::replace(&mut self.tokens, replacement);
            Request {
                id: self.id,
                arrival_s,
                slo: self.slo,
                tokens,
                gen_len: self.gen_len,
                budget: self.budget,
            }
        }
    }

    fn put_u32(out: &mut Vec<u8>, x: u32) {
        out.extend_from_slice(&x.to_le_bytes());
    }

    fn get_u32(b: &[u8], at: usize) -> u32 {
        u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
    }

    fn get_u64(b: &[u8], at: usize) -> u64 {
        let mut x = [0u8; 8];
        x.copy_from_slice(&b[at..at + 8]);
        u64::from_le_bytes(x)
    }

    /// Client side: append one framed request to `out`.
    pub fn encode_request(out: &mut Vec<u8>, req: &Request) {
        let payload = REQ_FIXED + 4 * req.tokens.len();
        out.push(REQ_MAGIC);
        out.push(VERSION);
        put_u32(out, payload as u32);
        out.extend_from_slice(&req.id.to_le_bytes());
        out.push(u8::from(req.budget.is_some()));
        out.extend_from_slice(&req.budget.unwrap_or(0.0).to_le_bytes());
        out.push(req.slo.code());
        put_u32(out, req.gen_len as u32);
        put_u32(out, req.tokens.len() as u32);
        for t in &req.tokens {
            out.extend_from_slice(&t.to_le_bytes());
        }
    }

    /// Server side: decode a request payload (header already stripped)
    /// into `slot`, rejecting token counts past `max_tokens` (the slot's
    /// capacity floor) so the reused buffer never grows.
    pub fn decode_request(payload: &[u8], max_tokens: usize, slot: &mut RequestSlot) -> Result<()> {
        ensure!(
            payload.len() >= REQ_FIXED,
            "request frame payload {} bytes, need at least {REQ_FIXED}",
            payload.len()
        );
        slot.id = get_u64(payload, 0);
        let has_budget = payload[8];
        ensure!(has_budget <= 1, "bad budget flag {has_budget}");
        let budget = f64::from_le_bytes({
            let mut x = [0u8; 8];
            x.copy_from_slice(&payload[9..17]);
            x
        });
        slot.budget = (has_budget == 1).then_some(budget);
        slot.slo = match Slo::from_code(payload[17]) {
            Some(s) => s,
            None => bail!("bad slo code {}", payload[17]),
        };
        slot.gen_len = get_u32(payload, 18) as usize;
        let n_tokens = get_u32(payload, 22) as usize;
        ensure!(
            n_tokens <= max_tokens,
            "request {} carries {n_tokens} tokens, limit {max_tokens}",
            slot.id
        );
        ensure!(
            payload.len() == REQ_FIXED + 4 * n_tokens,
            "request {} frame declares {n_tokens} tokens but payload is {} bytes \
             (want {})",
            slot.id,
            payload.len(),
            REQ_FIXED + 4 * n_tokens
        );
        slot.tokens.clear();
        for i in 0..n_tokens {
            slot.tokens.push(i32::from_le_bytes({
                let mut x = [0u8; 4];
                x.copy_from_slice(&payload[REQ_FIXED + 4 * i..REQ_FIXED + 4 * i + 4]);
                x
            }));
        }
        Ok(())
    }

    /// Parse an HTTP-fallback JSON body into `slot` through the pull
    /// parser — same zero-allocation contract as [`decode_request`].
    /// Schema: `{"id": u64, "tokens": [i32…], "gen_len": u32,
    /// "budget": f64?, "slo": "interactive"|"standard"|"quality"?}`;
    /// unknown keys are skipped.
    pub fn decode_request_json(
        body: &[u8],
        max_tokens: usize,
        slot: &mut RequestSlot,
    ) -> Result<()> {
        slot.id = 0;
        slot.budget = None;
        slot.slo = Slo::Standard;
        slot.gen_len = 0;
        slot.tokens.clear();
        let mut p = PullParser::new(body);
        ensure!(p.next()? == Event::ObjBegin, "request body must be a JSON object");
        let mut saw_tokens = false;
        loop {
            match p.next()? {
                Event::ObjEnd => break,
                Event::Key { raw, escaped } => {
                    ensure!(!escaped, "request keys must be plain ASCII");
                    match raw {
                        b"id" => match p.next()? {
                            Event::Num(x) if x >= 0.0 => slot.id = x as u64,
                            e => bail!("bad 'id' value {e:?}"),
                        },
                        b"budget" => match p.next()? {
                            Event::Num(x) => slot.budget = Some(x),
                            Event::Null => slot.budget = None,
                            e => bail!("bad 'budget' value {e:?}"),
                        },
                        b"gen_len" => match p.next()? {
                            Event::Num(x) if x >= 0.0 && x <= u32::MAX as f64 => {
                                slot.gen_len = x as usize
                            }
                            e => bail!("bad 'gen_len' value {e:?}"),
                        },
                        b"slo" => match p.next()? {
                            Event::Str { raw: b"interactive", .. } => slot.slo = Slo::Interactive,
                            Event::Str { raw: b"standard", .. } => slot.slo = Slo::Standard,
                            Event::Str { raw: b"quality", .. } => slot.slo = Slo::Quality,
                            e => bail!("bad 'slo' value {e:?}"),
                        },
                        b"tokens" => {
                            ensure!(p.next()? == Event::ArrBegin, "'tokens' must be an array");
                            saw_tokens = true;
                            loop {
                                match p.next()? {
                                    Event::ArrEnd => break,
                                    Event::Num(x)
                                        if x.fract() == 0.0
                                            && (i32::MIN as f64..=i32::MAX as f64)
                                                .contains(&x) =>
                                    {
                                        ensure!(
                                            slot.tokens.len() < max_tokens,
                                            "request carries more than {max_tokens} tokens"
                                        );
                                        slot.tokens.push(x as i32);
                                    }
                                    e => bail!("bad token {e:?}"),
                                }
                            }
                        }
                        _ => {
                            let first = p.next()?;
                            p.skip_value(&first)?;
                        }
                    }
                }
                e => bail!("unexpected {e:?} in request object"),
            }
        }
        ensure!(p.next()? == Event::End, "trailing bytes after request object");
        ensure!(saw_tokens, "request body missing 'tokens'");
        Ok(())
    }

    /// Server side: append one framed response to `out`.
    pub fn encode_response(out: &mut Vec<u8>, id: u64, status: Status, tokens: &[i32]) {
        let payload = 8 + 1 + 4 + 4 * tokens.len();
        out.push(RESP_MAGIC);
        out.push(VERSION);
        put_u32(out, payload as u32);
        out.extend_from_slice(&id.to_le_bytes());
        out.push(status.code());
        put_u32(out, tokens.len() as u32);
        for t in tokens {
            out.extend_from_slice(&t.to_le_bytes());
        }
    }

    /// Client side: decode a response payload (header already stripped).
    pub fn decode_response(payload: &[u8]) -> Result<(u64, Status, Vec<i32>)> {
        ensure!(payload.len() >= 13, "response payload too short: {}", payload.len());
        let id = get_u64(payload, 0);
        let status = match Status::from_code(payload[8]) {
            Some(s) => s,
            None => bail!("bad response status {}", payload[8]),
        };
        let n = get_u32(payload, 9) as usize;
        ensure!(
            payload.len() == 13 + 4 * n,
            "response declares {n} tokens but payload is {} bytes",
            payload.len()
        );
        let tokens = (0..n)
            .map(|i| {
                i32::from_le_bytes({
                    let mut x = [0u8; 4];
                    x.copy_from_slice(&payload[13 + 4 * i..13 + 4 * i + 4]);
                    x
                })
            })
            .collect();
        Ok((id, status, tokens))
    }

    /// Read one frame header + payload from `r` into `buf` (reused; must
    /// have been reserved to `max_payload` so the read never reallocates).
    /// Returns the magic byte, with the payload left in `buf`, or `None`
    /// on a clean EOF *before* any header byte.  EOF mid-frame, a bad
    /// magic/version, and an oversized length prefix are all hard errors.
    pub fn read_frame(
        r: &mut impl std::io::Read,
        buf: &mut Vec<u8>,
        max_payload: usize,
    ) -> Result<Option<u8>> {
        let mut header = [0u8; HEADER_LEN];
        let mut got = 0usize;
        while got < HEADER_LEN {
            match r.read(&mut header[got..]) {
                Ok(0) => {
                    if got == 0 {
                        return Ok(None);
                    }
                    bail!("truncated frame: EOF after {got} header bytes");
                }
                Ok(n) => got += n,
                Err(e) => return Err(e.into()),
            }
        }
        let magic = header[0];
        ensure!(
            magic == REQ_MAGIC || magic == RESP_MAGIC,
            "bad frame magic 0x{magic:02x} (not a framed-protocol stream)"
        );
        ensure!(header[1] == VERSION, "unsupported frame version {}", header[1]);
        let len = get_u32(&header, 2) as usize;
        ensure!(
            len <= max_payload && len <= MAX_PAYLOAD,
            "frame length prefix {len} exceeds the {max_payload}-byte limit"
        );
        buf.clear();
        buf.resize(len, 0);
        let mut at = 0usize;
        while at < len {
            match r.read(&mut buf[at..]) {
                Ok(0) => bail!("truncated frame: EOF {at}/{len} payload bytes in"),
                Ok(n) => at += n,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(Some(magic))
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn req(id: u64, tokens: Vec<i32>, gen: usize, budget: Option<f64>) -> Request {
            Request { id, arrival_s: 0.0, slo: Slo::Quality, tokens, gen_len: gen, budget }
        }

        #[test]
        fn request_frame_roundtrip() {
            let r = req(42, vec![1, -7, 300], 5, Some(0.75));
            let mut out = Vec::new();
            encode_request(&mut out, &r);
            let mut slot = RequestSlot::with_capacity(16);
            decode_request(&out[HEADER_LEN..], 16, &mut slot).unwrap();
            assert_eq!(slot.id, 42);
            assert_eq!(slot.budget, Some(0.75));
            assert_eq!(slot.slo, Slo::Quality);
            assert_eq!(slot.gen_len, 5);
            assert_eq!(slot.tokens, vec![1, -7, 300]);
        }

        #[test]
        fn response_frame_roundtrip() {
            let mut out = Vec::new();
            encode_response(&mut out, 9, Status::Ok, &[4, 5, 6]);
            let (id, status, toks) = decode_response(&out[HEADER_LEN..]).unwrap();
            assert_eq!((id, status), (9, Status::Ok));
            assert_eq!(toks, vec![4, 5, 6]);
            let mut out = Vec::new();
            encode_response(&mut out, 10, Status::Shed, &[]);
            let (id, status, toks) = decode_response(&out[HEADER_LEN..]).unwrap();
            assert_eq!((id, status), (10, Status::Shed));
            assert!(toks.is_empty());
        }

        #[test]
        fn json_body_roundtrip_and_unknown_keys() {
            let body = br#"{"extra": {"deep": [1, 2]}, "id": 3, "tokens": [1, 2, 3],
                            "gen_len": 4, "budget": 0.5, "slo": "interactive"}"#;
            let mut slot = RequestSlot::with_capacity(8);
            decode_request_json(body, 8, &mut slot).unwrap();
            assert_eq!(slot.id, 3);
            assert_eq!(slot.tokens, vec![1, 2, 3]);
            assert_eq!(slot.gen_len, 4);
            assert_eq!(slot.budget, Some(0.5));
            assert_eq!(slot.slo, Slo::Interactive);
            assert!(decode_request_json(br#"{"id": 1}"#, 8, &mut slot).is_err());
            assert!(decode_request_json(br#"{"tokens": [1.5]}"#, 8, &mut slot).is_err());
        }

        #[test]
        fn slot_reuse_never_reallocates() {
            let mut slot = RequestSlot::with_capacity(32);
            let fp = slot.fingerprint();
            for i in 0..200u64 {
                let r = req(i, vec![1; (i % 32) as usize], 2, None);
                let mut out = Vec::new();
                encode_request(&mut out, &r);
                decode_request(&out[HEADER_LEN..], 32, &mut slot).unwrap();
                assert_eq!(slot.fingerprint(), fp, "slot buffer moved at frame {i}");
            }
        }

        #[test]
        fn frame_reader_rejects_adversarial_streams() {
            // Oversized length prefix.
            let mut bad = vec![REQ_MAGIC, VERSION];
            bad.extend_from_slice(&(u32::MAX).to_le_bytes());
            let mut buf = Vec::with_capacity(64);
            let err = read_frame(&mut bad.as_slice(), &mut buf, 1024).unwrap_err();
            assert!(err.to_string().contains("length prefix"), "{err}");
            // Garbage magic.
            let garbage = [0xAAu8; 32];
            let err = read_frame(&mut garbage.as_slice(), &mut buf, 1024).unwrap_err();
            assert!(err.to_string().contains("magic"), "{err}");
            // Truncated payload.
            let r = req(1, vec![1, 2, 3, 4], 0, None);
            let mut out = Vec::new();
            encode_request(&mut out, &r);
            out.truncate(out.len() - 3);
            let err = read_frame(&mut out.as_slice(), &mut buf, 1024).unwrap_err();
            assert!(err.to_string().contains("truncated"), "{err}");
            // Clean EOF before any byte: None, not an error.
            let mut empty: &[u8] = &[];
            assert!(read_frame(&mut empty, &mut buf, 1024).unwrap().is_none());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(n: usize, seed: u64) -> Vec<Request> {
        let cfg = TraceCfg { n_requests: n, seed, ..Default::default() };
        TraceGen::new(cfg, b"hello world this is source text for requests").unwrap().generate()
    }

    #[test]
    fn arrivals_monotone_and_deterministic() {
        let a = trace(100, 1);
        let b = trace(100, 1);
        assert_eq!(a.len(), 100);
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.slo, y.slo);
        }
    }

    #[test]
    fn slo_mix_roughly_respected() {
        let a = trace(3000, 2);
        let inter = a.iter().filter(|r| r.slo == Slo::Interactive).count() as f64 / 3000.0;
        assert!((inter - 0.5).abs() < 0.05, "interactive fraction {inter}");
    }

    #[test]
    fn tokens_in_range() {
        let a = trace(50, 3);
        assert!(a.iter().all(|r| r.tokens.iter().all(|&t| (0..256).contains(&t))));
        assert!(a.iter().all(|r| r.tokens.len() == 64 && r.gen_len == 0));
    }

    #[test]
    fn variable_length_distributions_respect_bounds() {
        let cfg = TraceCfg {
            n_requests: 500,
            seq_len: 32,
            prompt_len_min: 4,
            prompt_len_max: 24,
            gen_len_min: 2,
            gen_len_max: 16,
            seed: 9,
            ..Default::default()
        };
        let a = TraceGen::new(cfg, b"variable length source text for decode traces")
            .unwrap()
            .generate();
        for r in &a {
            assert!((4..=24).contains(&r.tokens.len()), "prompt {}", r.tokens.len());
            assert!(r.gen_len <= 16);
            assert!(r.total_tokens() <= 32, "stream {} outgrows seq_len", r.total_tokens());
        }
        // Both knobs actually vary…
        assert!(a.iter().any(|r| r.tokens.len() != a[0].tokens.len()));
        assert!(a.iter().any(|r| r.gen_len >= 1), "generation lengths all clamped to zero");
    }

    #[test]
    fn decode_trace_with_legacy_prompts_rejected_loudly() {
        // Regression: gen_len_max > 0 with prompt_len_max == 0 used to
        // silently clamp every gen_len to 0 (full-seq_len prompts leave no
        // positional room) — a decode trace degrading to prefill-only.
        let cfg = TraceCfg { gen_len_max: 8, ..Default::default() };
        let err = TraceGen::new(cfg, b"source").unwrap_err();
        assert!(err.to_string().contains("prompt_len_max"), "{err}");
        // The validation names both halves of the contradiction.
        assert!(err.to_string().contains("gen_len_max"), "{err}");
    }

    #[test]
    fn degenerate_rate_and_mix_rejected() {
        let bad_rate = TraceCfg { rate: 0.0, ..Default::default() };
        assert!(TraceGen::new(bad_rate, b"x").is_err());
        let bad_mix = TraceCfg { slo_mix: [0.0, 0.0, 0.0], ..Default::default() };
        assert!(TraceGen::new(bad_mix, b"x").is_err());
    }

    #[test]
    fn scenario_parse_and_validation() {
        for name in ["steady", "diurnal", "bursty", "adversarial"] {
            let shape = ArrivalShape::parse(name).unwrap();
            assert_eq!(shape.label(), name);
            let cfg = TraceCfg { shape, ..Default::default() };
            assert!(cfg.validate().is_ok(), "{name} defaults must validate");
        }
        assert!(ArrivalShape::parse("sawtooth").is_err());
        let bad = TraceCfg {
            shape: ArrivalShape::Diurnal { period_s: 2.0, swing: 1.5 },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = TraceCfg { shape: ArrivalShape::Adversarial { clump: 1 }, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn bursty_shape_compresses_arrivals() {
        // Same request count and mean rate: the bursty trace must finish in
        // less wall time than steady (bursts at mult× the base rate), and
        // stay deterministic and monotone.
        let steady = trace(400, 5);
        let cfg = TraceCfg {
            n_requests: 400,
            seed: 5,
            shape: ArrivalShape::parse("bursty").unwrap(),
            ..Default::default()
        };
        let bursty = TraceGen::new(cfg, b"hello world this is source text for requests")
            .unwrap()
            .generate();
        for w in bursty.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        let steady_span = steady.last().unwrap().arrival_s;
        let bursty_span = bursty.last().unwrap().arrival_s;
        assert!(
            bursty_span < steady_span,
            "bursty span {bursty_span} not compressed vs steady {steady_span}"
        );
    }

    #[test]
    fn adversarial_shape_clumps_quality_requests() {
        let cfg = TraceCfg {
            n_requests: 64,
            seed: 11,
            shape: ArrivalShape::Adversarial { clump: 8 },
            ..Default::default()
        };
        let a = TraceGen::new(cfg, b"adversarial source text").unwrap().generate();
        // Within each clump of 8, requests 1..8 share the head's arrival
        // instant and are all Quality-class with full prompts.
        for (i, r) in a.iter().enumerate() {
            if i % 8 != 0 {
                assert_eq!(r.arrival_s, a[i - i % 8].arrival_s, "request {i} not clumped");
                assert_eq!(r.slo, Slo::Quality, "request {i} not quality");
                assert_eq!(r.tokens.len(), 64, "request {i} prompt not full");
            }
        }
        // Clump heads advance the clock.
        assert!(a[8].arrival_s > a[0].arrival_s);
    }

    #[test]
    fn tenant_mix_stamps_budgets_and_respects_weights() {
        let cfg = TraceCfg {
            n_requests: 2000,
            seed: 13,
            tenants: TenantCfg::default_mix(),
            ..Default::default()
        };
        let a = TraceGen::new(cfg, b"tenant mix source text").unwrap().generate();
        let budgeted = a.iter().filter(|r| r.budget.is_some()).count() as f64 / 2000.0;
        // Tenants 3+4 carry 30% of the traffic weight.
        assert!((budgeted - 0.3).abs() < 0.05, "budgeted fraction {budgeted}");
        for r in &a {
            if let Some(b) = r.budget {
                assert!(b > 0.0 && b <= 1.0);
            }
        }
        // A bad tenant budget is a config error.
        let bad = TraceCfg {
            tenants: vec![TenantCfg { weight: 1.0, budget: Some(1.5), slo_mix: [1.0, 0.0, 0.0] }],
            ..Default::default()
        };
        assert!(TraceGen::new(bad, b"x").is_err());
    }
}
