//! Synthetic hierarchical-grammar byte corpus.
//!
//! Generates text with multi-scale structure a byte LM can actually learn:
//! * a fixed word vocabulary (Zipf-distributed) of pronounceable words,
//! * sentence templates (SVO with optional modifiers),
//! * occasional parenthetical nesting (long-range dependency),
//! * deterministic from a seed, split into train / held-out.

use crate::rng::Rng;

const CONSONANTS: &[u8] = b"bcdfghjklmnprstvwz";
const VOWELS: &[u8] = b"aeiou";

/// A generated corpus: train + held-out byte streams.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub train: Vec<u8>,
    pub heldout: Vec<u8>,
    pub vocab_words: usize,
}

impl Corpus {
    /// Generate ~`total_bytes` of text, 90/10 train/held-out.
    pub fn generate(total_bytes: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let vocab_words = 64;
        // Pronounceable CVCV(C) words, 3-6 bytes.
        let words: Vec<Vec<u8>> = (0..vocab_words)
            .map(|_| {
                let syllables = 1 + rng.below(2);
                let mut w = Vec::new();
                for _ in 0..=syllables {
                    w.push(CONSONANTS[rng.below(CONSONANTS.len())]);
                    w.push(VOWELS[rng.below(VOWELS.len())]);
                }
                if rng.f64() < 0.3 {
                    w.push(CONSONANTS[rng.below(CONSONANTS.len())]);
                }
                w
            })
            .collect();
        // Zipf weights over words.
        let weights: Vec<f64> = (0..vocab_words).map(|i| 1.0 / (i + 1) as f64).collect();

        let mut text = Vec::with_capacity(total_bytes + 128);
        while text.len() < total_bytes {
            Self::sentence(&mut text, &words, &weights, &mut rng, 0);
        }
        let split = total_bytes * 9 / 10;
        let heldout = text.split_off(split.min(text.len()));
        Corpus { train: text, heldout, vocab_words }
    }

    fn sentence(out: &mut Vec<u8>, words: &[Vec<u8>], w: &[f64], rng: &mut Rng, depth: usize) {
        let len = 3 + rng.below(5);
        for i in 0..len {
            if i > 0 {
                out.push(b' ');
            }
            out.extend_from_slice(&words[rng.weighted(w)]);
            // Parenthetical nesting: long-range matched delimiters.
            if depth < 2 && rng.f64() < 0.08 {
                out.extend_from_slice(b" (");
                Self::sentence(out, words, w, rng, depth + 1);
                out.push(b')');
            }
        }
        out.extend_from_slice(if rng.f64() < 0.5 { b". " } else { b", " });
    }
}

/// Samples (B, T+1) int32 token windows from a byte stream.
#[derive(Debug, Clone)]
pub struct TokenBatcher {
    bytes: Vec<u8>,
    pub batch: usize,
    pub window: usize,
    rng: Rng,
    vocab: usize,
}

impl TokenBatcher {
    /// `window` = T+1 (inputs + shifted targets).  Bytes are clamped into
    /// [0, vocab) so tiny-vocab configs stay valid.
    pub fn new(bytes: &[u8], batch: usize, window: usize, vocab: usize, seed: u64) -> Self {
        assert!(bytes.len() > window, "corpus shorter than one window");
        TokenBatcher { bytes: bytes.to_vec(), batch, window, rng: Rng::new(seed), vocab }
    }

    /// Next random batch, flattened row-major (batch × window).
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.window);
        for _ in 0..self.batch {
            let start = self.rng.below(self.bytes.len() - self.window);
            out.extend(
                self.bytes[start..start + self.window]
                    .iter()
                    .map(|&b| (b as usize % self.vocab) as i32),
            );
        }
        out
    }

    /// Deterministic sequential batches (for evaluation), `count` of them.
    pub fn eval_batches(&self, count: usize) -> Vec<Vec<i32>> {
        let stride = (self.bytes.len() - self.window) / (count * self.batch + 1).max(1);
        let mut out = Vec::with_capacity(count);
        let mut pos = 0usize;
        for _ in 0..count {
            let mut b = Vec::with_capacity(self.batch * self.window);
            for _ in 0..self.batch {
                let start = pos.min(self.bytes.len() - self.window - 1);
                b.extend(
                    self.bytes[start..start + self.window]
                        .iter()
                        .map(|&x| (x as usize % self.vocab) as i32),
                );
                pos += stride.max(1);
            }
            out.push(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_deterministic_and_split() {
        let a = Corpus::generate(10_000, 7);
        let b = Corpus::generate(10_000, 7);
        assert_eq!(a.train, b.train);
        assert_eq!(a.heldout, b.heldout);
        assert!(a.train.len() >= 8_000);
        assert!(!a.heldout.is_empty());
    }

    #[test]
    fn corpus_has_structure() {
        let c = Corpus::generate(50_000, 1);
        // Parentheses are balanced-ish (every open has a close).
        let opens = c.train.iter().filter(|&&b| b == b'(').count();
        let closes = c.train.iter().filter(|&&b| b == b')').count();
        assert!(opens > 0, "no nesting generated");
        assert!((opens as i64 - closes as i64).unsigned_abs() < 8);
        // Only expected byte classes.
        assert!(c
            .train
            .iter()
            .all(|&b| b.is_ascii_lowercase() || matches!(b, b' ' | b'.' | b',' | b'(' | b')')));
    }

    #[test]
    fn batcher_shapes_and_range() {
        let c = Corpus::generate(20_000, 3);
        let mut tb = TokenBatcher::new(&c.train, 4, 17, 256, 9);
        let b = tb.next_batch();
        assert_eq!(b.len(), 4 * 17);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
        // Eval batches deterministic.
        let e1 = tb.eval_batches(3);
        let e2 = tb.eval_batches(3);
        assert_eq!(e1, e2);
        assert_eq!(e1.len(), 3);
    }

    #[test]
    fn batcher_tiny_vocab_clamps() {
        let c = Corpus::generate(5_000, 4);
        let mut tb = TokenBatcher::new(&c.train, 2, 9, 64, 1);
        assert!(tb.next_batch().iter().all(|&t| (0..64).contains(&t)));
    }
}
