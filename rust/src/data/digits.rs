//! Synthetic "digits": structured Gaussian clusters on an 8×8 grid.
//!
//! Stands in for MNIST in the paper's controlled setting (App. D.1 / Fig. 3):
//! 10 class prototypes (smooth random blobs), samples are prototypes with
//! additive noise and small translations, so classes are separable but not
//! trivially so — there is real low-rank structure to discover.

use crate::linalg::Mat;
use crate::rng::Rng;

/// A generated dataset.
#[derive(Debug, Clone)]
pub struct Digits {
    pub x: Mat,
    pub y: Vec<usize>,
    pub x_test: Mat,
    pub y_test: Vec<usize>,
    pub side: usize,
    pub classes: usize,
}

impl Digits {
    pub fn generate(train_n: usize, test_n: usize, seed: u64) -> Digits {
        let mut rng = Rng::new(seed);
        let side = 8usize;
        let classes = 10usize;
        let dim = side * side;

        // Smooth prototypes: a few random Gaussian bumps per class.
        let protos: Vec<Vec<f64>> = (0..classes)
            .map(|_| {
                let mut p = vec![0.0f64; dim];
                for _ in 0..3 {
                    let cx = rng.range_f64(1.0, side as f64 - 1.0);
                    let cy = rng.range_f64(1.0, side as f64 - 1.0);
                    let amp = rng.range_f64(0.6, 1.2);
                    let s2 = rng.range_f64(1.0, 2.5);
                    for i in 0..side {
                        for j in 0..side {
                            let d2 = (i as f64 - cy).powi(2) + (j as f64 - cx).powi(2);
                            p[i * side + j] += amp * (-d2 / (2.0 * s2)).exp();
                        }
                    }
                }
                p
            })
            .collect();

        let sample = |rng: &mut Rng| -> (Vec<f64>, usize) {
            let c = rng.below(classes);
            let (dx, dy) = (rng.below(3) as i64 - 1, rng.below(3) as i64 - 1);
            let mut v = vec![0.0f64; dim];
            for i in 0..side as i64 {
                for j in 0..side as i64 {
                    let si = i - dy;
                    let sj = j - dx;
                    if (0..side as i64).contains(&si) && (0..side as i64).contains(&sj) {
                        v[(i * side as i64 + j) as usize] =
                            protos[c][(si * side as i64 + sj) as usize];
                    }
                }
            }
            for x in v.iter_mut() {
                *x += rng.normal() * 0.15;
            }
            (v, c)
        };

        let fill = |n: usize, rng: &mut Rng| -> (Mat, Vec<usize>) {
            let mut x = Mat::zeros(n, dim);
            let mut y = Vec::with_capacity(n);
            for i in 0..n {
                let (v, c) = sample(rng);
                x.row_mut(i).copy_from_slice(&v);
                y.push(c);
            }
            (x, y)
        };
        let (x, y) = fill(train_n, &mut rng);
        let (x_test, y_test) = fill(test_n, &mut rng);
        Digits { x, y, x_test, y_test, side, classes }
    }

    pub fn dim(&self) -> usize {
        self.side * self.side
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{accuracy, softmax_xent, Activation, Adam, Layer, Net};

    #[test]
    fn shapes_and_determinism() {
        let a = Digits::generate(100, 50, 11);
        let b = Digits::generate(100, 50, 11);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.x.rows, 100);
        assert_eq!(a.x_test.rows, 50);
        assert_eq!(a.dim(), 64);
        assert!(a.y.iter().all(|&c| c < 10));
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow under debug; run via `cargo test --release` (make test)")]
    fn classes_are_learnable() {
        // A small dense MLP must beat chance comfortably.
        let d = Digits::generate(600, 200, 12);
        let mut rng = Rng::new(13);
        let mut net = Net::new(vec![
            Layer::dense(64, 32, 0.15, Activation::Relu, &mut rng),
            Layer::dense(32, 10, 0.15, Activation::None, &mut rng),
        ]);
        let mut opt = Adam::new(5e-3);
        for _ in 0..300 {
            let (out, cache) = net.forward_cached(&d.x, &[]);
            let (_l, g) = softmax_xent(&out, &d.y);
            let grads = net.backward(&cache, &[], &g);
            opt.step(&mut net, &grads);
        }
        let acc = accuracy(&net.forward(&d.x_test, &[]), &d.y_test);
        assert!(acc > 0.7, "test accuracy {acc}");
    }
}
