//! Domain corpora for Tab. 1 post-adaptation: a "math" domain (arithmetic
//! with answers) and a "code" domain (assignment statements over a bracket
//! language).  Both come with an answer-region evaluator so we can report a
//! task accuracy, not just loss.

use crate::rng::Rng;

/// Which synthetic downstream domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Math,
    Code,
}

/// A domain dataset: byte text + (start, len) answer spans in that text.
#[derive(Debug, Clone)]
pub struct DomainData {
    pub text: Vec<u8>,
    /// Byte spans whose prediction constitutes "solving" an example.
    pub answer_spans: Vec<(usize, usize)>,
}

/// Generate ~`n_examples` examples of a domain.
pub fn generate(domain: Domain, n_examples: usize, seed: u64) -> DomainData {
    let mut rng = Rng::new(seed);
    let mut text = Vec::new();
    let mut spans = Vec::new();
    for _ in 0..n_examples {
        match domain {
            Domain::Math => {
                // "a+b=c;" or "a*b=c;" with small operands.
                let mul = rng.f64() < 0.4;
                let (a, b) = if mul {
                    (rng.below(12) as i64, rng.below(12) as i64)
                } else {
                    (rng.below(50) as i64, rng.below(50) as i64)
                };
                let c = if mul { a * b } else { a + b };
                let prefix = format!("{a}{}{b}=", if mul { '*' } else { '+' });
                let ans = format!("{c};");
                text.extend_from_slice(prefix.as_bytes());
                let start = text.len();
                text.extend_from_slice(ans.as_bytes());
                spans.push((start, ans.len() - 1)); // answer digits, not ';'
            }
            Domain::Code => {
                // "x=(y+(z*w));" — the answer is the closing-bracket suffix,
                // which requires tracking nesting depth.
                let vars = b"abcdefgh";
                let depth = 1 + rng.below(3);
                let mut expr = String::new();
                for _ in 0..depth {
                    expr.push('(');
                    expr.push(vars[rng.below(vars.len())] as char);
                    expr.push(if rng.f64() < 0.5 { '+' } else { '*' });
                }
                expr.push(vars[rng.below(vars.len())] as char);
                let prefix = format!("{}={}", vars[rng.below(vars.len())] as char, expr);
                let ans: String = std::iter::repeat(')').take(depth).chain(";".chars()).collect();
                text.extend_from_slice(prefix.as_bytes());
                let start = text.len();
                text.extend_from_slice(ans.as_bytes());
                spans.push((start, depth)); // the closing brackets
            }
        }
    }
    DomainData { text, answer_spans: spans }
}

impl DomainData {
    /// Fraction of answer bytes predicted correctly by `predict(context) ->
    /// next byte` — greedy next-token accuracy restricted to answer spans.
    /// `window` is the model context length.
    pub fn answer_accuracy(
        &self,
        window: usize,
        mut predict: impl FnMut(&[u8]) -> u8,
    ) -> f64 {
        let mut total = 0usize;
        let mut correct = 0usize;
        for &(start, len) in &self.answer_spans {
            for k in 0..len {
                let pos = start + k;
                if pos == 0 || pos >= self.text.len() {
                    continue;
                }
                let ctx_lo = pos.saturating_sub(window);
                let got = predict(&self.text[ctx_lo..pos]);
                total += 1;
                if got == self.text[pos] {
                    correct += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn math_examples_are_correct() {
        let d = generate(Domain::Math, 50, 21);
        let text = String::from_utf8(d.text.clone()).unwrap();
        for ex in text.split(';').filter(|s| !s.is_empty()) {
            let (lhs, rhs) = ex.split_once('=').unwrap();
            let val: i64 = rhs.parse().unwrap();
            let computed = if let Some((a, b)) = lhs.split_once('+') {
                a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap()
            } else {
                let (a, b) = lhs.split_once('*').unwrap();
                a.parse::<i64>().unwrap() * b.parse::<i64>().unwrap()
            };
            assert_eq!(val, computed, "bad example {ex}");
        }
    }

    #[test]
    fn code_brackets_balanced() {
        let d = generate(Domain::Code, 50, 22);
        let text = String::from_utf8(d.text.clone()).unwrap();
        for stmt in text.split(';').filter(|s| !s.is_empty()) {
            let opens = stmt.matches('(').count();
            let closes = stmt.matches(')').count();
            assert_eq!(opens, closes, "unbalanced: {stmt}");
        }
    }

    #[test]
    fn spans_point_at_answers() {
        let d = generate(Domain::Math, 20, 23);
        for &(s, l) in &d.answer_spans {
            assert!(d.text[s..s + l].iter().all(|b| b.is_ascii_digit()));
        }
    }

    #[test]
    fn oracle_predictor_gets_full_accuracy() {
        let d = generate(Domain::Code, 30, 24);
        let text = d.text.clone();
        // Predictor that just looks up the true next byte (upper bound).
        let mut pos_of = std::collections::HashMap::new();
        for i in 0..text.len() {
            pos_of.insert(text[..i].to_vec().len().min(i), ());
        }
        let acc = d.answer_accuracy(16, |ctx| {
            // find ctx in text (contexts are unique enough at this size);
            // emulate oracle by scanning.
            for i in ctx.len()..text.len() {
                if text[i - ctx.len()..i] == *ctx {
                    return text[i];
                }
            }
            b'?'
        });
        assert!(acc > 0.95, "oracle accuracy {acc}");
    }
}
