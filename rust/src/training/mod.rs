//! Training drivers: teacher pretraining, calibration, knowledge
//! consolidation, checkpointing, and the end-to-end pipeline orchestration.
//!
//! Two backends share the same stage semantics: [`native`] (default — pure
//! rust over `linalg::kernels`, fully offline) and [`driver`] (PJRT over the
//! AOT artifacts, behind the `pjrt` feature).

pub mod ckpt;
#[cfg(feature = "pjrt")]
pub mod driver;
#[cfg(feature = "pjrt")]
pub mod lora;
pub mod native;
pub mod params;
pub mod pipeline;

/// Result of a training run: final params + loss curve (shared by the
/// native and PJRT drivers).
pub struct TrainRun {
    pub params: params::ParamSet,
    pub losses: Vec<f32>,
}

/// Stage-output directory shared by the pipeline and the serving CLI
/// (checkpoints land here so `repro serve` can reuse a consolidated student
/// regardless of which backend produced it).
pub fn stage_dir() -> std::path::PathBuf {
    crate::results_dir().join("pipeline")
}

/// Corpus size used by the pipeline + figures (bytes).
pub const CORPUS_BYTES: usize = 400_000;
