//! Training drivers over the PJRT artifacts: teacher pretraining,
//! calibration, knowledge consolidation, checkpointing, LoRA adaptation,
//! and the end-to-end pipeline orchestration.

pub mod ckpt;
pub mod driver;
pub mod lora;
pub mod params;
pub mod pipeline;

/// Corpus size used by the pipeline + figures (bytes).
pub const CORPUS_BYTES: usize = 400_000;
