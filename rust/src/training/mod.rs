//! Training drivers over the PJRT artifacts: teacher pretraining,
//! calibration, knowledge consolidation, checkpointing, LoRA adaptation,
//! and the end-to-end pipeline orchestration.

pub mod ckpt;
#[cfg(feature = "pjrt")]
pub mod driver;
#[cfg(feature = "pjrt")]
pub mod lora;
pub mod params;
#[cfg(feature = "pjrt")]
pub mod pipeline;

/// Stage-output directory shared by the pipeline and the serving CLI
/// (checkpoints land here so `repro serve` can reuse a consolidated student
/// regardless of which backend produced it).
pub fn stage_dir() -> std::path::PathBuf {
    crate::results_dir().join("pipeline")
}

/// Corpus size used by the pipeline + figures (bytes).
pub const CORPUS_BYTES: usize = 400_000;
