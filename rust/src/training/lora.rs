//! LoRA post-adaptation on frozen GAR submodels (Tab. 1).
//!
//! For each serving tier: freeze the GAR-form submodel extracted from the
//! consolidated student, train LoRA adapters (A: N(0, .02), B: 0) on a
//! domain corpus via the `lora_train_step_t{i}` artifact, then report the
//! answer-span accuracy via `lora_logits_t{i}`.

use anyhow::{ensure, Result};

use crate::data::domains::{self, Domain, DomainData};
use crate::data::TokenBatcher;
use crate::rng::Rng;
use crate::runtime::{Engine, Tensor};

use super::params::{gar_params_for, ParamSet};

/// Initialize LoRA tensors per the artifact's arg-1 spec.
fn init_lora(spec: &crate::runtime::ArtifactSpec, lora_rank: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    spec.inputs
        .iter()
        .filter(|i| i.name.starts_with("1."))
        .map(|i| {
            if i.shape[0] == lora_rank {
                Tensor::zeros(&i.shape) // B side
            } else {
                Tensor::f32(i.shape.clone(), rng.normal_vec(i.numel(), 0.02))
            }
        })
        .collect()
}

/// Train LoRA adapters for tier `tier_idx` on `domain`; returns
/// (final loss, answer accuracy).
pub fn adapt_tier(
    engine: &Engine,
    student: &ParamSet,
    tier_idx: usize,
    domain: Domain,
    steps: usize,
    seed: u64,
) -> Result<(f32, f64)> {
    let data = domains::generate(domain, 800, seed);
    let (gar, lora, loss) = adapt_on_text(engine, student, tier_idx, &data.text, steps, seed)?;
    let acc = eval_answer_accuracy(engine, tier_idx, &gar, &lora, &data)?;
    Ok((loss, acc))
}

/// Train LoRA adapters for a tier on arbitrary text (also used by the
/// ACIP-like baseline's "LoRA repair" stage on the main corpus); returns
/// (gar params, adapted lora params, final CE loss).
pub fn adapt_on_text(
    engine: &Engine,
    student: &ParamSet,
    tier_idx: usize,
    text: &[u8],
    steps: usize,
    seed: u64,
) -> Result<(Vec<Tensor>, Vec<Tensor>, f32)> {
    let cfg = engine.manifest.config.clone();
    let step_exe = engine.load(&format!("lora_train_step_t{tier_idx}"))?;
    let spec = step_exe.spec.clone();

    // Frozen GAR params for this tier (device-resident for the whole run).
    let serve_spec = engine.manifest.artifact(&format!("serve_gar_t{tier_idx}"))?.clone();
    let gar = gar_params_for(&cfg, student, &serve_spec)?;
    let gar_bufs = engine.to_device_all(&gar)?;

    ensure!(text.len() > cfg.seq_len + 1, "lora corpus too small");
    let mut batcher =
        TokenBatcher::new(text, cfg.batch_train, cfg.seq_len + 1, cfg.vocab, seed ^ 0x9);

    let mut lora = init_lora(&spec, cfg.lora_rank, seed ^ 0x1);
    let mut m: Vec<Tensor> = lora.iter().map(|t| Tensor::zeros(t.shape())).collect();
    let mut v = m.clone();
    let n_lora = lora.len();
    let mut last_loss = f32::NAN;

    for step in 0..steps {
        let tokens = Tensor::i32(vec![cfg.batch_train, cfg.seq_len + 1], batcher.next_batch());
        let mut bufs = Vec::new();
        for t in lora.iter().chain(m.iter()).chain(v.iter()) {
            bufs.push(engine.to_device(t)?);
        }
        bufs.push(engine.to_device(&Tensor::scalar_f32((step + 1) as f32))?);
        bufs.push(engine.to_device(&tokens)?);
        let mut refs: Vec<&xla::PjRtBuffer> = gar_bufs.iter().map(|d| d.buffer()).collect();
        refs.extend(bufs.iter().map(|d| d.buffer()));
        let out_l = step_exe.run_b(&refs)?;
        let out: Vec<Tensor> = out_l.iter().map(Tensor::from_literal).collect::<Result<_>>()?;
        lora = out[..n_lora].to_vec();
        m = out[n_lora..2 * n_lora].to_vec();
        v = out[2 * n_lora..3 * n_lora].to_vec();
        last_loss = out[3 * n_lora].item_f32()?;
    }
    Ok((gar, lora, last_loss))
}

/// CE loss of an adapted (gar, lora) tier on deterministic windows of `text`.
pub fn ce_on_text(
    engine: &Engine,
    tier_idx: usize,
    gar: &[Tensor],
    lora: &[Tensor],
    text: &[u8],
    n_batches: usize,
) -> Result<f64> {
    let cfg = engine.manifest.config.clone();
    let exe = engine.load(&format!("lora_logits_t{tier_idx}"))?;
    let (b, t, v) = (cfg.batch_eval, cfg.seq_len, cfg.vocab);
    let batcher = TokenBatcher::new(text, b, t + 1, cfg.vocab, 0);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for batch in batcher.eval_batches(n_batches) {
        let mut x = Vec::with_capacity(b * t);
        for row in batch.chunks(t + 1) {
            x.extend_from_slice(&row[..t]);
        }
        let mut inputs: Vec<Tensor> = gar.to_vec();
        inputs.extend(lora.iter().cloned());
        inputs.push(Tensor::i32(vec![b, t], x));
        let out = exe.run(&inputs)?;
        let lf = out[0].as_f32()?;
        for (ri, row) in batch.chunks(t + 1).enumerate() {
            for pos in 0..t {
                let logits = &lf[(ri * t + pos) * v..(ri * t + pos + 1) * v];
                let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse = logits.iter().map(|x| (x - mx).exp()).sum::<f32>().ln() + mx;
                total += (lse - logits[row[pos + 1] as usize]) as f64;
                count += 1;
            }
        }
    }
    Ok(total / count.max(1) as f64)
}

/// Greedy answer-span accuracy via `lora_logits_t{i}`.
pub fn eval_answer_accuracy(
    engine: &Engine,
    tier_idx: usize,
    gar: &[Tensor],
    lora: &[Tensor],
    data: &DomainData,
) -> Result<f64> {
    let cfg = engine.manifest.config.clone();
    let exe = engine.load(&format!("lora_logits_t{tier_idx}"))?;
    let b = cfg.batch_eval;
    let t_len = cfg.seq_len;

    // Collect (context, want) pairs over answer spans (cap for runtime).
    let mut cases: Vec<(Vec<i32>, u8, usize)> = Vec::new(); // (window, want, pos_in_window)
    for &(start, len) in data.answer_spans.iter().take(120) {
        for k in 0..len {
            let pos = start + k;
            if pos == 0 || pos >= data.text.len() {
                continue;
            }
            let lo = pos.saturating_sub(t_len);
            let ctx = &data.text[lo..pos];
            let mut window = vec![b' ' as i32; t_len];
            let off = t_len - ctx.len();
            for (i, &byte) in ctx.iter().enumerate() {
                window[off + i] = (byte as usize % cfg.vocab) as i32;
            }
            cases.push((window, data.text[pos], t_len - 1));
        }
    }
    ensure!(!cases.is_empty(), "no answer cases");

    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in cases.chunks(b) {
        let mut tokens = Vec::with_capacity(b * t_len);
        for (w, _, _) in chunk {
            tokens.extend_from_slice(w);
        }
        // pad the batch
        for _ in chunk.len()..b {
            tokens.extend(std::iter::repeat(b' ' as i32).take(t_len));
        }
        let mut inputs: Vec<Tensor> = gar.to_vec();
        inputs.extend(lora.iter().cloned());
        inputs.push(Tensor::i32(vec![b, t_len], tokens));
        let out = exe.run(&inputs)?;
        let logits = &out[0]; // (b, t, vocab)
        let lf = logits.as_f32()?;
        for (ri, (_, want, pos)) in chunk.iter().enumerate() {
            let row = &lf[(ri * t_len + pos) * cfg.vocab..(ri * t_len + pos + 1) * cfg.vocab];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            total += 1;
            if arg == (*want as usize % cfg.vocab) {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}
