//! Checkpoint I/O for [`ParamSet`]s: a JSON sidecar (names/shapes/dtypes) +
//! a raw little-endian blob.  Keeps pipeline stages (pretrain → decompose →
//! consolidate → figures) resumable and independently runnable.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::json::{self, Value};
use crate::runtime::Tensor;

use super::params::ParamSet;

/// Write `<stem>.json` + `<stem>.bin`.
pub fn save(ps: &ParamSet, stem: impl AsRef<Path>) -> Result<()> {
    let stem = stem.as_ref();
    let mut entries = Vec::new();
    let mut blob: Vec<u8> = Vec::new();
    for (name, t) in &ps.map {
        let (dtype, bytes): (&str, Vec<u8>) = match t {
            Tensor::F32 { data, .. } => {
                ("float32", data.iter().flat_map(|x| x.to_le_bytes()).collect())
            }
            Tensor::I32 { data, .. } => {
                ("int32", data.iter().flat_map(|x| x.to_le_bytes()).collect())
            }
        };
        entries.push(json::obj(vec![
            ("name", Value::Str(name.clone())),
            ("shape", json::arr_usize(t.shape())),
            ("dtype", Value::Str(dtype.into())),
            ("offset", Value::Num(blob.len() as f64)),
        ]));
        blob.extend(bytes);
    }
    let meta = json::obj(vec![("params", Value::Arr(entries))]);
    std::fs::write(stem.with_extension("json"), json::to_string(&meta))?;
    std::fs::write(stem.with_extension("bin"), blob)?;
    Ok(())
}

/// Load a checkpoint written by [`save`].
pub fn load(stem: impl AsRef<Path>) -> Result<ParamSet> {
    let stem = stem.as_ref();
    let meta = json::parse_file(stem.with_extension("json"))
        .with_context(|| format!("loading {}", stem.display()))?;
    let blob = std::fs::read(stem.with_extension("bin"))?;
    let mut ps = ParamSet::default();
    for e in meta.req("params")?.as_arr()? {
        let name = e.req("name")?.as_str()?;
        let shape = e.req("shape")?.as_usize_vec()?;
        let off = e.req("offset")?.as_usize()?;
        let n: usize = shape.iter().product();
        ensure!(off + 4 * n <= blob.len(), "checkpoint blob too short for {name}");
        let raw = &blob[off..off + 4 * n];
        let t = match e.req("dtype")?.as_str()? {
            "float32" => Tensor::f32(
                shape,
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            "int32" => Tensor::i32(
                shape,
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            other => bail!("bad dtype {other}"),
        };
        ps.map.insert(name.to_string(), t);
    }
    Ok(ps)
}

/// Does a checkpoint exist at this stem?
pub fn exists(stem: impl AsRef<Path>) -> bool {
    stem.as_ref().with_extension("json").exists() && stem.as_ref().with_extension("bin").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ps = ParamSet::default();
        ps.insert("a.w", Tensor::f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 9.0, -7.25]));
        ps.insert("b", Tensor::i32(vec![2], vec![5, -6]));
        let dir = std::env::temp_dir().join("flexrank_ckpt_test");
        let _ = std::fs::create_dir_all(&dir);
        let stem = dir.join("ck");
        save(&ps, &stem).unwrap();
        assert!(exists(&stem));
        let back = load(&stem).unwrap();
        assert_eq!(back.map.len(), 2);
        assert_eq!(back.get("a.w").unwrap().as_f32().unwrap(), ps.get("a.w").unwrap().as_f32().unwrap());
        assert_eq!(back.get("b").unwrap().as_i32().unwrap(), &[5, -6]);
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/nonexistent/path/ck").is_err());
    }

    fn mixed_set() -> ParamSet {
        let mut ps = ParamSet::default();
        ps.insert("w.f", Tensor::f32(vec![3, 2], vec![0.5, -1.25, f32::MIN_POSITIVE, 3e8, -0.0, 7.75]));
        ps.insert("idx", Tensor::i32(vec![4], vec![i32::MIN, -1, 0, i32::MAX]));
        ps.insert("b", Tensor::f32(vec![2], vec![1.0, 2.0]));
        ps
    }

    fn temp_stem(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("flexrank_ckpt_{}_{tag}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        dir.join("ck")
    }

    /// save → load → save must be *byte*-exact on both the blob and the
    /// sidecar — the checkpoint format is the contract between pipeline
    /// stages and the serving CLI, so any drift is corruption.
    #[test]
    fn save_load_save_is_byte_exact() {
        let ps = mixed_set();
        let stem = temp_stem("exact");
        save(&ps, &stem).unwrap();
        let blob1 = std::fs::read(stem.with_extension("bin")).unwrap();
        let meta1 = std::fs::read(stem.with_extension("json")).unwrap();
        let back = load(&stem).unwrap();
        let stem2 = temp_stem("exact2");
        save(&back, &stem2).unwrap();
        assert_eq!(blob1, std::fs::read(stem2.with_extension("bin")).unwrap());
        assert_eq!(meta1, std::fs::read(stem2.with_extension("json")).unwrap());
        // And the i32 payload survived without being f32-mangled.
        assert_eq!(back.get("idx").unwrap().as_i32().unwrap(), &[i32::MIN, -1, 0, i32::MAX]);
    }

    #[test]
    fn truncated_blob_fails_loudly() {
        let ps = mixed_set();
        let stem = temp_stem("trunc");
        save(&ps, &stem).unwrap();
        let bin = stem.with_extension("bin");
        let mut blob = std::fs::read(&bin).unwrap();
        blob.truncate(blob.len() - 3);
        std::fs::write(&bin, blob).unwrap();
        let err = load(&stem).unwrap_err();
        assert!(err.to_string().contains("too short"), "{err}");
    }

    #[test]
    fn garbled_dtype_fails_loudly() {
        let ps = mixed_set();
        let stem = temp_stem("dtype");
        save(&ps, &stem).unwrap();
        let meta_path = stem.with_extension("json");
        let meta = std::fs::read_to_string(&meta_path).unwrap();
        std::fs::write(&meta_path, meta.replace("float32", "float99")).unwrap();
        let err = load(&stem).unwrap_err();
        assert!(err.to_string().contains("bad dtype"), "{err}");
    }

    #[test]
    fn missing_dtype_key_fails_loudly() {
        let ps = mixed_set();
        let stem = temp_stem("nodtype");
        save(&ps, &stem).unwrap();
        let meta_path = stem.with_extension("json");
        let meta = std::fs::read_to_string(&meta_path).unwrap();
        std::fs::write(&meta_path, meta.replace("\"dtype\"", "\"dtypo\"")).unwrap();
        assert!(load(&stem).is_err(), "a checkpoint without dtypes must not deserialize");
    }
}
