//! Native training backend: the full Algorithm-1 stage set — teacher
//! pretraining, calibration, masked-student evaluation, sensitivity probing,
//! and nested KD consolidation — implemented directly over
//! [`crate::linalg::kernels`] f32 paths with manual backprop.  No PJRT, no
//! artifacts: this is what makes `repro pipeline` run on an offline machine.
//!
//! Semantics mirror `python/compile/model.py` exactly:
//!
//! * **teacher** — dense byte-GPT (`teacher_fwd`): token + position
//!   embeddings, pre-LN blocks with causal multi-head attention
//!   (scale `1/√hd`), tanh-GELU MLP, final LN, tied logits head.
//! * **student** — every linear factorized as `y = (x·V ⊙ mask)·Uᵀ + b`
//!   with per-layer prefix rank masks (`student_fwd`), so one parameter set
//!   serves every budget profile.
//! * **losses** — mean next-token CE (`ce_loss`) and the temperature-scaled
//!   KD loss of Eq. 5: `τ²·mean_rows KL(p_t‖p_s)` with
//!   `∂L/∂s = τ·(p_s − p_t)/rows` (`kd_loss_grad`, matching the custom VJP
//!   in `kernels/kd_loss.py`).
//! * **AdamW** — `p ← p − lr·(m̂/(√v̂+ε) + wd·p)` over every parameter
//!   (python `adamw_update` applies weight decay to the whole tree).
//!
//! The backward pass is hand-derived per layer (LN, factorized/dense linear,
//! causal softmax attention, GELU, tied embeddings); finite-difference tests
//! below pin every gradient path.

use anyhow::{anyhow, bail, ensure, Result};

use crate::data::TokenBatcher;
use crate::flexrank::decompose::CovAccum;
use crate::flexrank::masks::RankProfile;
use crate::flexrank::sensitivity::ProbeModel;
use crate::linalg::{kernels, pool, Mat};
use crate::rng::Rng;
use crate::runtime::attention::{
    causal_attention, causal_attention_backward, causal_attention_backward_streaming, AttnPath,
    AttnGradWorkspace, AttnWorkspace,
};
use crate::runtime::{ModelConfig, Tensor};

use super::params::{fact_layers, ParamSet};
use super::TrainRun;

// ---------------------------------------------------------------------------
// Small helpers
// ---------------------------------------------------------------------------

fn add_bias(y: &mut [f32], rows: usize, m: usize, b: &[f32]) {
    for row in y.chunks_exact_mut(m).take(rows) {
        for (o, &bv) in row.iter_mut().zip(b) {
            *o += bv;
        }
    }
}

fn add_assign(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Mutable f32 view of a grad tensor by name.
fn gmut<'a>(grads: &'a mut ParamSet, name: &str) -> Result<&'a mut [f32]> {
    grads
        .map
        .get_mut(name)
        .ok_or_else(|| anyhow!("grad '{name}' missing"))?
        .as_f32_mut()
        .map(|v| v.as_mut_slice())
}

// ---------------------------------------------------------------------------
// Layer norm
// ---------------------------------------------------------------------------

struct LnCache {
    /// Normalized activations `(x − μ)·inv`, (rows, d).
    xhat: Vec<f32>,
    /// Per-row `1/√(var + ε)`.
    inv: Vec<f32>,
}

fn ln_forward(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32]) -> (Vec<f32>, LnCache) {
    let mut y = vec![0f32; rows * d];
    let mut xhat = vec![0f32; rows * d];
    let mut inv = vec![0f32; rows];
    for i in 0..rows {
        let xr = &x[i * d..(i + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let iv = 1.0 / (var + 1e-5).sqrt();
        inv[i] = iv;
        let xh = &mut xhat[i * d..(i + 1) * d];
        let yr = &mut y[i * d..(i + 1) * d];
        for j in 0..d {
            let h = (xr[j] - mu) * iv;
            xh[j] = h;
            yr[j] = h * g[j] + b[j];
        }
    }
    (y, LnCache { xhat, inv })
}

/// Backward through LN; accumulates `dg`/`db`, returns `dx`.
fn ln_backward(
    cache: &LnCache,
    rows: usize,
    d: usize,
    g: &[f32],
    dy: &[f32],
    dg: &mut [f32],
    db: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0f32; rows * d];
    for i in 0..rows {
        let xh = &cache.xhat[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        let mut s_dxh = 0f32;
        let mut s_dxh_xh = 0f32;
        for j in 0..d {
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
            let dxh = dyr[j] * g[j];
            s_dxh += dxh;
            s_dxh_xh += dxh * xh[j];
        }
        let m1 = s_dxh / d as f32;
        let m2 = s_dxh_xh / d as f32;
        let iv = cache.inv[i];
        let dxr = &mut dx[i * d..(i + 1) * d];
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dxr[j] = iv * (dxh - m1 - xh[j] * m2);
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation, matching python `_gelu`)
// ---------------------------------------------------------------------------

const GELU_C: f32 = 0.797_884_56;
const GELU_A: f32 = 0.044_715;

fn gelu_forward(h: &[f32]) -> Vec<f32> {
    h.iter()
        .map(|&z| 0.5 * z * (1.0 + (GELU_C * (z + GELU_A * z * z * z)).tanh()))
        .collect()
}

fn gelu_backward(h: &[f32], df: &[f32]) -> Vec<f32> {
    h.iter()
        .zip(df)
        .map(|(&z, &g)| {
            let t = (GELU_C * (z + GELU_A * z * z * z)).tanh();
            let dz = 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * z * z);
            g * dz
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Linear layers (dense teacher / masked factorized student)
// ---------------------------------------------------------------------------

/// Forward one linear.  Teacher (`fact = None`): `y = x·W + b`.
/// Student (`fact = Some(r)`): `t = x·V`, prefix mask to `r`, `y = t·Uᵀ + b`.
/// Returns `(y, t_cache)`; the cached `t` is already masked.
#[allow(clippy::too_many_arguments)]
fn lin_forward(
    params: &ParamSet,
    prefix: &str,
    fact: Option<usize>,
    r_full: usize,
    x: &[f32],
    rows: usize,
    n: usize,
    m: usize,
) -> Result<(Vec<f32>, Option<Vec<f32>>)> {
    let mut y = vec![0f32; rows * m];
    let t_cache = match fact {
        None => {
            let w = params.get(&format!("{prefix}_w"))?.as_f32()?;
            kernels::matmul_f32(&x[..rows * n], w, rows, n, m, &mut y);
            None
        }
        Some(r) => {
            let u = params.get(&format!("{prefix}_u"))?.as_f32()?;
            let v = params.get(&format!("{prefix}_v"))?.as_f32()?;
            let mut t = vec![0f32; rows * r_full];
            kernels::matmul_f32(&x[..rows * n], v, rows, n, r_full, &mut t);
            if r < r_full {
                for row in t.chunks_exact_mut(r_full) {
                    for tv in &mut row[r..] {
                        *tv = 0.0;
                    }
                }
            }
            kernels::matmul_nt_f32(&t, u, rows, r_full, m, &mut y);
            Some(t)
        }
    };
    let b = params.get(&format!("{prefix}_b"))?.as_f32()?;
    add_bias(&mut y, rows, m, b);
    Ok((y, t_cache))
}

/// Backward one linear; accumulates param grads into `grads`, returns `dx`.
#[allow(clippy::too_many_arguments)]
fn lin_backward(
    params: &ParamSet,
    grads: &mut ParamSet,
    prefix: &str,
    fact: Option<usize>,
    r_full: usize,
    x: &[f32],
    t: Option<&Vec<f32>>,
    dy: &[f32],
    rows: usize,
    n: usize,
    m: usize,
) -> Result<Vec<f32>> {
    {
        let db = gmut(grads, &format!("{prefix}_b"))?;
        for row in dy.chunks_exact(m).take(rows) {
            for (dbj, &dyj) in db.iter_mut().zip(row) {
                *dbj += dyj;
            }
        }
    }
    let mut dx = vec![0f32; rows * n];
    match fact {
        None => {
            let w = params.get(&format!("{prefix}_w"))?.as_f32()?;
            {
                let dw = gmut(grads, &format!("{prefix}_w"))?;
                kernels::matmul_tn_acc_f32(&x[..rows * n], dy, rows, n, m, dw);
            }
            kernels::matmul_nt_f32(dy, w, rows, m, n, &mut dx);
        }
        Some(r) => {
            let t = t.ok_or_else(|| anyhow!("{prefix}: factorized cache missing"))?;
            let u = params.get(&format!("{prefix}_u"))?.as_f32()?;
            let v = params.get(&format!("{prefix}_v"))?.as_f32()?;
            {
                // dU += dyᵀ·t — masked columns of t are zero, so masked
                // components get zero gradient automatically.
                let du = gmut(grads, &format!("{prefix}_u"))?;
                kernels::matmul_tn_acc_f32(dy, t, rows, m, r_full, du);
            }
            let mut dt = vec![0f32; rows * r_full];
            kernels::matmul_f32(dy, u, rows, m, r_full, &mut dt);
            if r < r_full {
                for row in dt.chunks_exact_mut(r_full) {
                    for dv in &mut row[r..] {
                        *dv = 0.0;
                    }
                }
            }
            {
                let dv = gmut(grads, &format!("{prefix}_v"))?;
                kernels::matmul_tn_acc_f32(&x[..rows * n], &dt, rows, n, r_full, dv);
            }
            kernels::matmul_nt_f32(&dt, v, rows, r_full, n, &mut dx);
        }
    }
    Ok(dx)
}

// ---------------------------------------------------------------------------
// Persistent training workspace + attention (shared blocked implementation)
// ---------------------------------------------------------------------------

/// Persistent per-trainer workspace: the shared attention panel sets for
/// forward and backward ([`crate::runtime::attention`]), sized once from
/// the config and reused across layers and steps — the previous
/// `attention_forward` heap-allocated its panel buffers per layer per
/// step, which throttled the native KD loop.
///
/// The layout follows the config's attention crossover: at/above
/// `attn_streaming_min_seq` the forward runs the streaming tile (no
/// retained probs, nothing quadratic in `seq`) and the backward is the
/// recompute-based [`causal_attention_backward_streaming`]; below it the
/// blocked forward retains probs for [`causal_attention_backward`].
#[derive(Debug)]
pub struct Workspace {
    seq: usize,
    hd: usize,
    slots: usize,
    /// Forward panels; its layout (`AttnWorkspace::tile`) is the single
    /// source of truth for which path this workspace runs.
    attn: AttnWorkspace,
    /// Backward panels, sized lazily on the first backward pass — the
    /// forward-only users (probe, eval, calibration) never pay for them.
    grad: Option<AttnGradWorkspace>,
}

impl Workspace {
    /// Workspace following the config's `attn_streaming_min_seq` crossover.
    pub fn new(cfg: &ModelConfig) -> Workspace {
        Workspace::with_path(cfg, cfg.attn_path())
    }

    /// Blocked (probs-retaining) workspace regardless of the crossover.
    pub fn new_blocked(cfg: &ModelConfig) -> Workspace {
        Workspace::with_path(cfg, AttnPath::Blocked)
    }

    /// Streaming workspace at the config's tile regardless of the crossover.
    pub fn new_streaming(cfg: &ModelConfig) -> Workspace {
        Workspace::with_path(cfg, AttnPath::Streaming { tile: cfg.attn_tile })
    }

    fn with_path(cfg: &ModelConfig, path: AttnPath) -> Workspace {
        let hd = cfg.d_model / cfg.n_heads.max(1);
        // Enough slots to saturate the pool at any batch size ≥ 1.
        let slots = pool::size();
        let attn = AttnWorkspace::with_path(cfg.seq_len, hd, slots, path);
        Workspace { seq: cfg.seq_len, hd, slots, attn, grad: None }
    }

    /// Whether forwards/backwards through this workspace run the streaming
    /// (flash-style) attention.
    pub fn is_streaming(&self) -> bool {
        self.attn.is_streaming()
    }

    fn grad_ws(&mut self) -> &mut AttnGradWorkspace {
        if self.grad.is_none() {
            // Mirror the forward workspace's resolved (clamped) layout so
            // forward and backward can never disagree on the path.
            self.grad = Some(match self.attn.tile() {
                Some(tc) => AttnGradWorkspace::new_streaming(self.seq, self.hd, self.slots, tc),
                None => AttnGradWorkspace::new(self.seq, self.hd, self.slots),
            });
        }
        self.grad.as_mut().unwrap()
    }

    /// Buffer base pointers — tests pin that repeated training steps never
    /// reallocate the workspace (call after a warm-up step so the lazy
    /// backward panels exist).
    pub fn fingerprint(&self) -> Vec<usize> {
        let mut fp = self.attn.fingerprint();
        if let Some(g) = &self.grad {
            fp.extend(g.fingerprint());
        }
        fp
    }
}

/// Returns `(att, probs)`: merged heads (rows, d) and, on the blocked
/// path, the retained causal softmax weights — one (t_len, t_len) matrix
/// per (batch, head) pair — for [`attention_backward`].  On the streaming
/// path `probs` is **empty**: the backward recomputes them tile by tile,
/// so the training cache never holds a `(t, t)` buffer either.
fn attention_forward(
    qkv: &[f32],
    batch: usize,
    t_len: usize,
    d: usize,
    heads: usize,
    ws: &mut Workspace,
) -> (Vec<f32>, Vec<f32>) {
    let mut att = vec![0f32; batch * t_len * d];
    if ws.is_streaming() {
        causal_attention(qkv, batch, t_len, d, heads, &mut ws.attn, &mut att, None);
        (att, Vec::new())
    } else {
        let mut probs = vec![0f32; batch * heads * t_len * t_len];
        causal_attention(qkv, batch, t_len, d, heads, &mut ws.attn, &mut att, Some(&mut probs));
        (att, probs)
    }
}

/// Backward through the attention: `datt` (rows, d) → `dqkv` (rows, 3d).
/// Dispatches on the workspace layout: retained-probs backward (blocked)
/// or recompute-based streaming backward (probs empty).
#[allow(clippy::too_many_arguments)]
fn attention_backward(
    qkv: &[f32],
    probs: &[f32],
    datt: &[f32],
    batch: usize,
    t_len: usize,
    d: usize,
    heads: usize,
    ws: &mut Workspace,
) -> Vec<f32> {
    let mut dqkv = vec![0f32; batch * t_len * 3 * d];
    if ws.is_streaming() {
        debug_assert!(probs.is_empty(), "streaming forward retains no probs");
        causal_attention_backward_streaming(
            qkv, datt, batch, t_len, d, heads, ws.grad_ws(), &mut dqkv,
        );
    } else {
        causal_attention_backward(
            qkv, probs, datt, batch, t_len, d, heads, ws.grad_ws(), &mut dqkv,
        );
    }
    dqkv
}

// ---------------------------------------------------------------------------
// Full model forward/backward
// ---------------------------------------------------------------------------

struct BlockCache {
    ln1: LnCache,
    a1: Vec<f32>,
    t_qkv: Option<Vec<f32>>,
    qkv: Vec<f32>,
    probs: Vec<f32>,
    att: Vec<f32>,
    t_proj: Option<Vec<f32>>,
    ln2: LnCache,
    a2: Vec<f32>,
    t_fc: Option<Vec<f32>>,
    h_fc: Vec<f32>,
    f: Vec<f32>,
    t_fcp: Option<Vec<f32>>,
}

/// Forward cache: all intermediates needed by [`backward`], plus logits.
pub struct Cache {
    batch: usize,
    t_len: usize,
    tokens: Vec<i32>,
    blocks: Vec<BlockCache>,
    lnf: LnCache,
    xf: Vec<f32>,
    /// (batch·t_len, vocab) row-major.
    pub logits: Vec<f32>,
}

/// Run the model forward.  `profile = None` → dense teacher (`{kind}_w`),
/// `profile = Some(ranks)` → masked factorized student (`{kind}_u/_v`).
/// `tokens` is `batch` sequences of `tokens.len()/batch` ids (≤ seq_len).
///
/// Convenience wrapper that sizes a one-shot [`Workspace`]; step loops
/// (pretrain/consolidate/probe) use [`forward_ws`] with a persistent one.
pub fn forward(
    cfg: &ModelConfig,
    params: &ParamSet,
    profile: Option<&RankProfile>,
    tokens: &[i32],
    batch: usize,
) -> Result<Cache> {
    forward_ws(cfg, params, profile, tokens, batch, &mut Workspace::new(cfg))
}

/// [`forward`] over a caller-supplied persistent workspace.
pub fn forward_ws(
    cfg: &ModelConfig,
    params: &ParamSet,
    profile: Option<&RankProfile>,
    tokens: &[i32],
    batch: usize,
    ws: &mut Workspace,
) -> Result<Cache> {
    ensure!(batch > 0 && !tokens.is_empty(), "empty forward batch");
    ensure!(tokens.len() % batch == 0, "tokens not divisible by batch");
    let t_len = tokens.len() / batch;
    ensure!(
        t_len <= cfg.seq_len,
        "sequence length {t_len} exceeds model seq_len {}",
        cfg.seq_len
    );
    // d_model/n_heads divisibility is validated at ModelConfig load time.
    if let Some(p) = profile {
        ensure!(
            p.len() == cfg.n_fact_layers(),
            "profile has {} entries, model has {} factorized layers",
            p.len(),
            cfg.n_fact_layers()
        );
    }
    let d = cfg.d_model;
    let rows = batch * t_len;
    let rf = cfg.rank_full();
    let dims = cfg.layer_dims();

    // Embeddings.
    let tok_emb = params.get("tok_emb")?.as_f32()?;
    let pos_emb = params.get("pos_emb")?.as_f32()?;
    let mut x = vec![0f32; rows * d];
    for (i, &tok) in tokens.iter().enumerate() {
        ensure!(
            tok >= 0 && (tok as usize) < cfg.vocab,
            "token {tok} at position {i} outside vocab {}",
            cfg.vocab
        );
        let pos = i % t_len;
        let tv = &tok_emb[tok as usize * d..tok as usize * d + d];
        let pv = &pos_emb[pos * d..pos * d + d];
        let xr = &mut x[i * d..(i + 1) * d];
        for ((o, &a), &b) in xr.iter_mut().zip(tv).zip(pv) {
            *o = a + b;
        }
    }

    let rank_of = |li: usize| -> Option<usize> {
        profile.map(|p| p[li].min(rf))
    };

    let mut blocks = Vec::with_capacity(cfg.n_blocks);
    for b in 0..cfg.n_blocks {
        let g1 = params.get(&format!("blocks.{b}.ln1_g"))?.as_f32()?;
        let b1 = params.get(&format!("blocks.{b}.ln1_b"))?.as_f32()?;
        let (a1, ln1) = ln_forward(&x, rows, d, g1, b1);
        let (_, n_qkv, m_qkv) = dims[0];
        let (qkv, t_qkv) = lin_forward(
            params,
            &format!("blocks.{b}.qkv"),
            rank_of(b * 4),
            rf,
            &a1,
            rows,
            n_qkv,
            m_qkv,
        )?;
        let (att, probs) = attention_forward(&qkv, batch, t_len, d, cfg.n_heads, ws);
        let (_, n_proj, m_proj) = dims[1];
        let (o, t_proj) = lin_forward(
            params,
            &format!("blocks.{b}.proj"),
            rank_of(b * 4 + 1),
            rf,
            &att,
            rows,
            n_proj,
            m_proj,
        )?;
        add_assign(&mut x, &o);

        let g2 = params.get(&format!("blocks.{b}.ln2_g"))?.as_f32()?;
        let b2 = params.get(&format!("blocks.{b}.ln2_b"))?.as_f32()?;
        let (a2, ln2) = ln_forward(&x, rows, d, g2, b2);
        let (_, n_fc, m_fc) = dims[2];
        let (h_fc, t_fc) = lin_forward(
            params,
            &format!("blocks.{b}.fc"),
            rank_of(b * 4 + 2),
            rf,
            &a2,
            rows,
            n_fc,
            m_fc,
        )?;
        let f = gelu_forward(&h_fc);
        let (_, n_fcp, m_fcp) = dims[3];
        let (o2, t_fcp) = lin_forward(
            params,
            &format!("blocks.{b}.fcp"),
            rank_of(b * 4 + 3),
            rf,
            &f,
            rows,
            n_fcp,
            m_fcp,
        )?;
        add_assign(&mut x, &o2);

        blocks.push(BlockCache {
            ln1,
            a1,
            t_qkv,
            qkv,
            probs,
            att,
            t_proj,
            ln2,
            a2,
            t_fc,
            h_fc,
            f,
            t_fcp,
        });
    }

    let gf = params.get("lnf_g")?.as_f32()?;
    let bf = params.get("lnf_b")?.as_f32()?;
    let (xf, lnf) = ln_forward(&x, rows, d, gf, bf);
    let mut logits = vec![0f32; rows * cfg.vocab];
    kernels::matmul_nt_f32(&xf, tok_emb, rows, d, cfg.vocab, &mut logits);

    Ok(Cache {
        batch,
        t_len,
        tokens: tokens.to_vec(),
        blocks,
        lnf,
        xf,
        logits,
    })
}

/// Backward from `dlogits` (batch·t_len, vocab); returns parameter grads
/// keyed exactly like `params` (missing gradients are zero tensors).
///
/// Convenience wrapper; step loops use [`backward_ws`].
pub fn backward(
    cfg: &ModelConfig,
    params: &ParamSet,
    profile: Option<&RankProfile>,
    cache: &Cache,
    dlogits: &[f32],
) -> Result<ParamSet> {
    backward_ws(cfg, params, profile, cache, dlogits, &mut Workspace::new(cfg))
}

/// [`backward`] over a caller-supplied persistent workspace.
pub fn backward_ws(
    cfg: &ModelConfig,
    params: &ParamSet,
    profile: Option<&RankProfile>,
    cache: &Cache,
    dlogits: &[f32],
    ws: &mut Workspace,
) -> Result<ParamSet> {
    let d = cfg.d_model;
    let rows = cache.batch * cache.t_len;
    let rf = cfg.rank_full();
    let dims = cfg.layer_dims();
    ensure!(dlogits.len() == rows * cfg.vocab, "dlogits size mismatch");
    let mut grads = params.zeros_like();

    // Tied head: logits = xf·tok_embᵀ.
    let tok_emb = params.get("tok_emb")?.as_f32()?;
    {
        let dte = gmut(&mut grads, "tok_emb")?;
        kernels::matmul_tn_acc_f32(dlogits, &cache.xf, rows, cfg.vocab, d, dte);
    }
    let mut dxf = vec![0f32; rows * d];
    kernels::matmul_f32(dlogits, tok_emb, rows, cfg.vocab, d, &mut dxf);

    // Final LN.
    let gf = params.get("lnf_g")?.as_f32()?;
    let mut dx = {
        let mut dg = vec![0f32; d];
        let mut db = vec![0f32; d];
        let dx = ln_backward(&cache.lnf, rows, d, gf, &dxf, &mut dg, &mut db);
        add_assign(gmut(&mut grads, "lnf_g")?, &dg);
        add_assign(gmut(&mut grads, "lnf_b")?, &db);
        dx
    };

    let rank_of = |li: usize| -> Option<usize> { profile.map(|p| p[li].min(rf)) };

    for b in (0..cfg.n_blocks).rev() {
        let blk = &cache.blocks[b];

        // MLP half: x_out = x_mid + fcp(gelu(fc(ln2(x_mid)))).
        let (_, n_fcp, m_fcp) = dims[3];
        let df = lin_backward(
            params,
            &mut grads,
            &format!("blocks.{b}.fcp"),
            rank_of(b * 4 + 3),
            rf,
            &blk.f,
            blk.t_fcp.as_ref(),
            &dx,
            rows,
            n_fcp,
            m_fcp,
        )?;
        let dh = gelu_backward(&blk.h_fc, &df);
        let (_, n_fc, m_fc) = dims[2];
        let da2 = lin_backward(
            params,
            &mut grads,
            &format!("blocks.{b}.fc"),
            rank_of(b * 4 + 2),
            rf,
            &blk.a2,
            blk.t_fc.as_ref(),
            &dh,
            rows,
            n_fc,
            m_fc,
        )?;
        {
            let g2 = params.get(&format!("blocks.{b}.ln2_g"))?.as_f32()?;
            let mut dg = vec![0f32; d];
            let mut db = vec![0f32; d];
            let dx_mid = ln_backward(&blk.ln2, rows, d, g2, &da2, &mut dg, &mut db);
            add_assign(gmut(&mut grads, &format!("blocks.{b}.ln2_g"))?, &dg);
            add_assign(gmut(&mut grads, &format!("blocks.{b}.ln2_b"))?, &db);
            add_assign(&mut dx, &dx_mid);
        }

        // Attention half: x_mid = x_in + proj(attn(qkv(ln1(x_in)))).
        let (_, n_proj, m_proj) = dims[1];
        let datt = lin_backward(
            params,
            &mut grads,
            &format!("blocks.{b}.proj"),
            rank_of(b * 4 + 1),
            rf,
            &blk.att,
            blk.t_proj.as_ref(),
            &dx,
            rows,
            n_proj,
            m_proj,
        )?;
        let dqkv = attention_backward(
            &blk.qkv, &blk.probs, &datt, cache.batch, cache.t_len, d, cfg.n_heads, ws,
        );
        let (_, n_qkv, m_qkv) = dims[0];
        let da1 = lin_backward(
            params,
            &mut grads,
            &format!("blocks.{b}.qkv"),
            rank_of(b * 4),
            rf,
            &blk.a1,
            blk.t_qkv.as_ref(),
            &dqkv,
            rows,
            n_qkv,
            m_qkv,
        )?;
        {
            let g1 = params.get(&format!("blocks.{b}.ln1_g"))?.as_f32()?;
            let mut dg = vec![0f32; d];
            let mut db = vec![0f32; d];
            let dx_in = ln_backward(&blk.ln1, rows, d, g1, &da1, &mut dg, &mut db);
            add_assign(gmut(&mut grads, &format!("blocks.{b}.ln1_g"))?, &dg);
            add_assign(gmut(&mut grads, &format!("blocks.{b}.ln1_b"))?, &db);
            add_assign(&mut dx, &dx_in);
        }
    }

    // Embedding gathers.
    {
        let dte = gmut(&mut grads, "tok_emb")?;
        for (i, &tok) in cache.tokens.iter().enumerate() {
            let dst = &mut dte[tok as usize * d..tok as usize * d + d];
            add_assign(dst, &dx[i * d..(i + 1) * d]);
        }
    }
    {
        let dpe = gmut(&mut grads, "pos_emb")?;
        for i in 0..rows {
            let pos = i % cache.t_len;
            let dst = &mut dpe[pos * d..pos * d + d];
            add_assign(dst, &dx[i * d..(i + 1) * d]);
        }
    }
    Ok(grads)
}

// ---------------------------------------------------------------------------
// Losses
// ---------------------------------------------------------------------------

/// Mean next-token cross entropy over all rows.
pub fn ce_loss(logits: &[f32], targets: &[i32], vocab: usize) -> f32 {
    let rows = targets.len();
    let mut loss = 0f64;
    for (row, &y) in logits.chunks_exact(vocab).zip(targets).take(rows) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        let logz = z.ln() + mx;
        loss += (logz - row[y as usize]) as f64;
    }
    (loss / rows.max(1) as f64) as f32
}

/// CE loss + gradient w.r.t. logits (`(softmax − onehot)/rows`).
pub fn ce_loss_grad(logits: &[f32], targets: &[i32], vocab: usize) -> (f32, Vec<f32>) {
    let rows = targets.len();
    let mut grad = vec![0f32; rows * vocab];
    let mut loss = 0f64;
    for i in 0..rows {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        let logz = z.ln() + mx;
        let y = targets[i] as usize;
        loss += (logz - row[y]) as f64;
        let g = &mut grad[i * vocab..(i + 1) * vocab];
        for j in 0..vocab {
            let p = (row[j] - logz).exp();
            g[j] = (p - if j == y { 1.0 } else { 0.0 }) / rows as f32;
        }
    }
    ((loss / rows.max(1) as f64) as f32, grad)
}

/// Temperature-scaled KD loss of Eq. 5: `τ²·mean_rows KL(p_t‖p_s)` with
/// both distributions at temperature τ.  Returns (loss, dL/ds_logits);
/// the teacher side is frozen (no gradient), matching the python VJP.
pub fn kd_loss_grad(s_logits: &[f32], t_logits: &[f32], vocab: usize, tau: f32) -> (f32, Vec<f32>) {
    assert_eq!(s_logits.len(), t_logits.len());
    let rows = s_logits.len() / vocab;
    let mut grad = vec![0f32; rows * vocab];
    let mut ps = vec![0f32; vocab];
    let mut pt = vec![0f32; vocab];
    let mut loss = 0f64;
    for i in 0..rows {
        let srow = &s_logits[i * vocab..(i + 1) * vocab];
        let trow = &t_logits[i * vocab..(i + 1) * vocab];
        let softmax = |row: &[f32], out: &mut [f32]| -> f32 {
            let mx = row.iter().map(|&v| v / tau).fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for (o, &v) in out.iter_mut().zip(row) {
                *o = (v / tau - mx).exp();
                z += *o;
            }
            for o in out.iter_mut() {
                *o /= z;
            }
            z.ln() + mx // log-partition at temperature tau
        };
        let s_lse = softmax(srow, &mut ps);
        let t_lse = softmax(trow, &mut pt);
        let mut kl = 0f64;
        for j in 0..vocab {
            if pt[j] > 0.0 {
                let log_pt = trow[j] / tau - t_lse;
                let log_ps = srow[j] / tau - s_lse;
                kl += pt[j] as f64 * (log_pt - log_ps) as f64;
            }
        }
        loss += kl;
        let g = &mut grad[i * vocab..(i + 1) * vocab];
        for j in 0..vocab {
            g[j] = tau * (ps[j] - pt[j]) / rows as f32;
        }
    }
    (((loss / rows.max(1) as f64) * (tau as f64) * (tau as f64)) as f32, grad)
}

// ---------------------------------------------------------------------------
// AdamW (mirrors python `adamw_update`: decay applied to every parameter)
// ---------------------------------------------------------------------------

pub struct AdamW {
    pub lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    wd: f32,
    t: u64,
    m: ParamSet,
    v: ParamSet,
}

impl AdamW {
    pub fn new(cfg: &ModelConfig, params: &ParamSet) -> AdamW {
        AdamW {
            lr: cfg.lr as f32,
            beta1: cfg.beta1 as f32,
            beta2: cfg.beta2 as f32,
            eps: cfg.adam_eps as f32,
            wd: cfg.weight_decay as f32,
            t: 0,
            m: params.zeros_like(),
            v: params.zeros_like(),
        }
    }

    pub fn step(&mut self, params: &mut ParamSet, grads: &ParamSet) -> Result<()> {
        self.t += 1;
        let (b1, b2, eps, lr, wd) = (self.beta1, self.beta2, self.eps, self.lr, self.wd);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for (name, p) in params.map.iter_mut() {
            let pd = match p {
                Tensor::F32 { data, .. } => data,
                Tensor::I32 { .. } => continue,
            };
            let g = match grads.map.get(name) {
                Some(Tensor::F32 { data, .. }) => data,
                _ => bail!("adamw: missing f32 grad for '{name}'"),
            };
            ensure!(g.len() == pd.len(), "adamw: grad '{name}' size mismatch");
            let m = self
                .m
                .map
                .get_mut(name)
                .ok_or_else(|| anyhow!("adamw: missing m state '{name}'"))?
                .as_f32_mut()?;
            let v = self
                .v
                .map
                .get_mut(name)
                .ok_or_else(|| anyhow!("adamw: missing v state '{name}'"))?
                .as_f32_mut()?;
            for i in 0..pd.len() {
                m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                pd[i] -= lr * (mh / (vh.sqrt() + eps) + wd * pd[i]);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Stage drivers (native mirrors of `training::driver`)
// ---------------------------------------------------------------------------

/// Split `(batch, t+1)` token windows into flat inputs `[.., :t]` and
/// next-token targets `[.., 1:]`.
pub fn split_windows(window: &[i32], t: usize) -> (Vec<i32>, Vec<i32>) {
    let rows = window.len() / (t + 1);
    let mut x = Vec::with_capacity(rows * t);
    let mut y = Vec::with_capacity(rows * t);
    for w in window.chunks_exact(t + 1) {
        x.extend_from_slice(&w[..t]);
        y.extend_from_slice(&w[1..]);
    }
    (x, y)
}

/// Pretrain the dense teacher with AdamW on next-token CE.
pub fn pretrain_teacher(
    cfg: &ModelConfig,
    init: ParamSet,
    batcher: &mut TokenBatcher,
    steps: usize,
    log_every: usize,
) -> Result<TrainRun> {
    let mut p = init;
    let mut opt = AdamW::new(cfg, &p);
    let mut ws = Workspace::new(cfg);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let window = batcher.next_batch();
        let (x, y) = split_windows(&window, cfg.seq_len);
        let cache = forward_ws(cfg, &p, None, &x, batcher.batch, &mut ws)?;
        let (loss, dlogits) = ce_loss_grad(&cache.logits, &y, cfg.vocab);
        let grads = backward_ws(cfg, &p, None, &cache, &dlogits, &mut ws)?;
        opt.step(&mut p, &grads)?;
        losses.push(loss);
        if log_every > 0 && step % log_every == 0 {
            eprintln!("pretrain step {step}: loss {loss:.4}");
        }
    }
    Ok(TrainRun { params: p, losses })
}

/// Accumulate per-factorized-layer input covariances over `batches`
/// calibration batches (App. C.1 stage 1).  The covariance inputs are the
/// same four per block as python's `teacher_fwd_acts`: ln1 output (qkv),
/// merged attention (proj), ln2 output (fc), GELU output (fcp).
pub fn calibrate(
    cfg: &ModelConfig,
    teacher: &ParamSet,
    batcher: &mut TokenBatcher,
    batches: usize,
) -> Result<Vec<CovAccum>> {
    let d = cfg.d_model;
    let dims = cfg.layer_dims();
    let mut covs: Vec<CovAccum> = (0..cfg.n_blocks)
        .flat_map(|_| dims.iter().map(|&(_, n, _)| CovAccum::new(n)))
        .collect();
    let mut ws = Workspace::new(cfg);
    for _ in 0..batches {
        let window = batcher.next_batch();
        // Windows may be (t) or (t+1) wide; calibration only needs inputs.
        let t = cfg.seq_len.min(batcher.window);
        let x: Vec<i32> = window
            .chunks_exact(batcher.window)
            .flat_map(|w| w[..t].to_vec())
            .collect();
        let cache = forward_ws(cfg, teacher, None, &x, batcher.batch, &mut ws)?;
        let rows = batcher.batch * t;
        for (bi, blk) in cache.blocks.iter().enumerate() {
            let inputs: [(&[f32], usize); 4] =
                [(&blk.a1, d), (&blk.att, d), (&blk.a2, d), (&blk.f, 4 * d)];
            for (ki, (buf, width)) in inputs.iter().enumerate() {
                covs[bi * 4 + ki].add_batch(&Mat::from_f32(rows, *width, buf));
            }
        }
    }
    Ok(covs)
}

/// Masked-student CE loss at a profile, averaged over deterministic
/// held-out `(batch, t+1)` windows.
pub fn eval_student(
    cfg: &ModelConfig,
    student: &ParamSet,
    profile: &RankProfile,
    eval_batches: &[Vec<i32>],
) -> Result<f64> {
    eval_student_ws(cfg, student, profile, eval_batches, &mut Workspace::new(cfg))
}

/// [`eval_student`] over a caller-supplied persistent workspace (the DP
/// probe runs hundreds of evals back to back).
pub fn eval_student_ws(
    cfg: &ModelConfig,
    student: &ParamSet,
    profile: &RankProfile,
    eval_batches: &[Vec<i32>],
    ws: &mut Workspace,
) -> Result<f64> {
    let mut total = 0f64;
    for batch in eval_batches {
        let b = batch.len() / (cfg.seq_len + 1);
        let (x, y) = split_windows(batch, cfg.seq_len);
        let cache = forward_ws(cfg, student, Some(profile), &x, b, ws)?;
        total += ce_loss(&cache.logits, &y, cfg.vocab) as f64;
    }
    Ok(total / eval_batches.len().max(1) as f64)
}

/// ProbeModel over the native student — powers DP sensitivity probing
/// without PJRT.  Borrows the caller's persistent [`Workspace`] so the
/// probe's hundreds of evals reuse one panel set.
pub struct NativeProbe<'a> {
    pub cfg: &'a ModelConfig,
    pub student: &'a ParamSet,
    pub eval_batches: &'a [Vec<i32>],
    pub evals: usize,
    pub ws: &'a mut Workspace,
}

impl ProbeModel for NativeProbe<'_> {
    fn full_ranks(&self) -> Vec<usize> {
        vec![self.cfg.rank_full(); self.cfg.n_fact_layers()]
    }

    fn layer_dims(&self) -> Vec<(usize, usize)> {
        fact_layers(self.cfg).into_iter().map(|(_, _, n, m)| (n, m)).collect()
    }

    fn eval(&mut self, profile: &RankProfile) -> f64 {
        self.evals += 1;
        eval_student_ws(self.cfg, self.student, profile, self.eval_batches, self.ws)
            .expect("native probe eval failed")
    }
}

/// Nested KD consolidation (Alg. 1 lines 14–17): sample a budget profile
/// `∝ alphas` each step, distill the masked student against the frozen
/// teacher's logits at temperature `cfg.tau_kd`.
#[allow(clippy::too_many_arguments)]
pub fn consolidate(
    cfg: &ModelConfig,
    student: ParamSet,
    teacher: &ParamSet,
    profiles: &[RankProfile],
    alphas: &[f64],
    batcher: &mut TokenBatcher,
    steps: usize,
    seed: u64,
    log_every: usize,
) -> Result<TrainRun> {
    ensure!(profiles.len() == alphas.len() && !profiles.is_empty(), "bad profiles/alphas");
    let mut rng = Rng::new(seed);
    let mut p = student;
    let mut opt = AdamW::new(cfg, &p);
    let mut ws = Workspace::new(cfg);
    let tau = cfg.tau_kd as f32;
    let mut losses = Vec::with_capacity(steps);
    let t_loop = std::time::Instant::now();
    for step in 0..steps {
        let pi = rng.weighted(alphas);
        let window = batcher.next_batch();
        let (x, _) = split_windows(&window, cfg.seq_len);
        let t_cache = forward_ws(cfg, teacher, None, &x, batcher.batch, &mut ws)?;
        let s_cache = forward_ws(cfg, &p, Some(&profiles[pi]), &x, batcher.batch, &mut ws)?;
        let (loss, dlogits) = kd_loss_grad(&s_cache.logits, &t_cache.logits, cfg.vocab, tau);
        let grads = backward_ws(cfg, &p, Some(&profiles[pi]), &s_cache, &dlogits, &mut ws)?;
        opt.step(&mut p, &grads)?;
        losses.push(loss);
        if log_every > 0 && step % log_every == 0 {
            eprintln!("consolidate step {step}: profile {pi} kd-loss {loss:.5}");
        }
    }
    if steps > 0 {
        eprintln!(
            "[consolidate] {:.2} steps/s ({} steps, native)",
            steps as f64 / t_loop.elapsed().as_secs_f64(),
            steps
        );
    }
    Ok(TrainRun { params: p, losses })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::runtime::native::{uniform_budget_profile, GarSubmodel, Scratch};
    use crate::training::params::{decompose_teacher, random_teacher, student_from_factors};

    fn test_cfg() -> ModelConfig {
        ModelConfig {
            name: "nat-test".into(),
            vocab: 13,
            d_model: 8,
            n_blocks: 2,
            n_heads: 2,
            seq_len: 6,
            batch_train: 2,
            batch_eval: 2,
            batch_calib: 2,
            batch_serve: 2,
            tau_kd: 2.0,
            lr: 0.01,
            weight_decay: 0.0,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            serve_tiers: vec![0.5, 1.0],
            bench_ranks: vec![4],
            bench_dim: 8,
            bench_batch: 4,
            lora_rank: 2,
            attn_tile: 4,
            attn_streaming_min_seq: crate::runtime::attention::DEFAULT_STREAMING_MIN_SEQ,
            tier_precision: vec![crate::linalg::quant::Precision::F32; 2],
            kv_page_size: crate::runtime::kvcache::DEFAULT_KV_PAGE_SIZE,
            kv_max_pages: 0,
            serve_queue_cap: 0,
            serve_pressure_hi: 0,
            serve_pressure_lo: 0,
            serve_dwell_ms: 25.0,
        }
    }

    fn rand_tokens(cfg: &ModelConfig, rng: &mut Rng, batch: usize) -> Vec<i32> {
        (0..batch * cfg.seq_len).map(|_| rng.below(cfg.vocab) as i32).collect()
    }

    #[test]
    fn teacher_ce_at_init_near_uniform() {
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 21);
        let mut rng = Rng::new(22);
        let x = rand_tokens(&cfg, &mut rng, 2);
        let y: Vec<i32> = (0..x.len()).map(|_| rng.below(cfg.vocab) as i32).collect();
        let cache = forward(&cfg, &teacher, None, &x, 2).unwrap();
        let l = ce_loss(&cache.logits, &y, cfg.vocab);
        let uniform = (cfg.vocab as f32).ln();
        assert!((l - uniform).abs() < 0.2, "init CE {l} vs ln V {uniform}");
        assert!(cache.logits.iter().all(|v| v.is_finite()));
    }

    /// Central-difference check of dL/dθ for a handful of teacher params
    /// spanning every gradient path: embeddings, dense linears, LN, biases.
    #[test]
    fn teacher_grad_matches_finite_difference() {
        let cfg = test_cfg();
        let mut teacher = random_teacher(&cfg, 31);
        let mut rng = Rng::new(32);
        let x = rand_tokens(&cfg, &mut rng, 2);
        let y: Vec<i32> = (0..x.len()).map(|_| rng.below(cfg.vocab) as i32).collect();

        let loss_at = |p: &ParamSet| -> f32 {
            let cache = forward(&cfg, p, None, &x, 2).unwrap();
            ce_loss(&cache.logits, &y, cfg.vocab)
        };
        let cache = forward(&cfg, &teacher, None, &x, 2).unwrap();
        let (_, dlogits) = ce_loss_grad(&cache.logits, &y, cfg.vocab);
        let grads = backward(&cfg, &teacher, None, &cache, &dlogits).unwrap();

        let eps = 1e-2f32;
        for (name, idx) in [
            ("tok_emb", 3usize),
            ("pos_emb", 9),
            ("lnf_g", 2),
            ("blocks.0.qkv_w", 17),
            ("blocks.0.proj_w", 5),
            ("blocks.1.fc_w", 40),
            ("blocks.1.fcp_w", 33),
            ("blocks.0.ln1_g", 1),
            ("blocks.1.ln2_b", 4),
            ("blocks.0.fc_b", 7),
        ] {
            let ana = grads.get(name).unwrap().as_f32().unwrap()[idx];
            {
                let p = teacher.map.get_mut(name).unwrap().as_f32_mut().unwrap();
                p[idx] += eps;
            }
            let lp = loss_at(&teacher);
            {
                let p = teacher.map.get_mut(name).unwrap().as_f32_mut().unwrap();
                p[idx] -= 2.0 * eps;
            }
            let lm = loss_at(&teacher);
            {
                let p = teacher.map.get_mut(name).unwrap().as_f32_mut().unwrap();
                p[idx] += eps;
            }
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - ana).abs() < 2e-3 + 0.05 * ana.abs(),
                "{name}[{idx}]: numeric {num} vs analytic {ana}"
            );
        }
    }

    /// Same check through the masked factorized path, including that masked
    /// components receive exactly zero gradient.
    #[test]
    fn student_grad_matches_finite_difference_masked() {
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 41);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let mut student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let rf = cfg.rank_full();
        // Mixed ranks across the 8 layers.
        let profile: Vec<usize> = vec![5, 8, 3, 6, 4, 8, 5, 7];
        let mut rng = Rng::new(42);
        let x = rand_tokens(&cfg, &mut rng, 2);
        let y: Vec<i32> = (0..x.len()).map(|_| rng.below(cfg.vocab) as i32).collect();

        let loss_at = |p: &ParamSet| -> f32 {
            let cache = forward(&cfg, p, Some(&profile), &x, 2).unwrap();
            ce_loss(&cache.logits, &y, cfg.vocab)
        };
        let cache = forward(&cfg, &student, Some(&profile), &x, 2).unwrap();
        let (_, dlogits) = ce_loss_grad(&cache.logits, &y, cfg.vocab);
        let grads = backward(&cfg, &student, Some(&profile), &cache, &dlogits).unwrap();

        // Masked components (columns ≥ r) get zero gradient.  Layer 0 is
        // blocks.0.qkv at r = 5: check a column ≥ 5 of u and v.
        let du = grads.get("blocks.0.qkv_u").unwrap().as_f32().unwrap();
        let dv = grads.get("blocks.0.qkv_v").unwrap().as_f32().unwrap();
        for row in 0..4 {
            assert_eq!(du[row * rf + 6], 0.0, "masked u column must get zero grad");
            assert_eq!(dv[row * rf + 7], 0.0, "masked v column must get zero grad");
        }

        let eps = 1e-2f32;
        for (name, idx) in [
            // active columns (col = idx % rf < r for that layer)
            ("blocks.0.qkv_u", 2usize),  // col 2 < 5
            ("blocks.0.qkv_v", 11),      // col 3 < 5
            ("blocks.1.fc_u", 12),       // col 4 < 5 (layer 6, r=5)
            ("blocks.1.fcp_v", 21),      // col 5 < 7 (layer 7, r=7)
            ("blocks.0.proj_b", 3),
        ] {
            let ana = grads.get(name).unwrap().as_f32().unwrap()[idx];
            {
                let p = student.map.get_mut(name).unwrap().as_f32_mut().unwrap();
                p[idx] += eps;
            }
            let lp = loss_at(&student);
            {
                let p = student.map.get_mut(name).unwrap().as_f32_mut().unwrap();
                p[idx] -= 2.0 * eps;
            }
            let lm = loss_at(&student);
            {
                let p = student.map.get_mut(name).unwrap().as_f32_mut().unwrap();
                p[idx] += eps;
            }
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - ana).abs() < 2e-3 + 0.05 * ana.abs(),
                "{name}[{idx}]: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn student_full_rank_matches_teacher_logits() {
        // Plain SVD at full rank reconstructs the teacher weights exactly,
        // so the masked student at the full profile is the teacher.
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 51);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let full: Vec<usize> = vec![cfg.rank_full(); cfg.n_fact_layers()];
        let mut rng = Rng::new(52);
        let x = rand_tokens(&cfg, &mut rng, 2);
        let tc = forward(&cfg, &teacher, None, &x, 2).unwrap();
        let sc = forward(&cfg, &student, Some(&full), &x, 2).unwrap();
        for (a, b) in tc.logits.iter().zip(&sc.logits) {
            assert!((a - b).abs() < 5e-3, "teacher {a} vs full-rank student {b}");
        }
    }

    #[test]
    fn training_workspace_never_reallocates_across_steps() {
        // A KD-style loop (teacher forward + student forward + backward +
        // optimizer step) over one persistent Workspace must never grow it
        // — the per-layer attention allocations it replaced were the native
        // KD loop's throttle.
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 91);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let mut student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let profile: Vec<usize> = vec![5; cfg.n_fact_layers()];
        let mut rng = Rng::new(92);
        let mut ws = Workspace::new(&cfg);
        let mut opt = AdamW::new(&cfg, &student);
        let mut step = |p: &mut ParamSet, opt: &mut AdamW, ws: &mut Workspace, rng: &mut Rng| {
            let x = rand_tokens(&cfg, rng, 2);
            let t_cache = forward_ws(&cfg, &teacher, None, &x, 2, ws).unwrap();
            let s_cache = forward_ws(&cfg, p, Some(&profile), &x, 2, ws).unwrap();
            let (_, dlogits) =
                kd_loss_grad(&s_cache.logits, &t_cache.logits, cfg.vocab, cfg.tau_kd as f32);
            let grads = backward_ws(&cfg, p, Some(&profile), &s_cache, &dlogits, ws).unwrap();
            opt.step(p, &grads).unwrap();
        };
        step(&mut student, &mut opt, &mut ws, &mut rng);
        let fp = ws.fingerprint();
        for _ in 0..3 {
            step(&mut student, &mut opt, &mut ws, &mut rng);
        }
        assert_eq!(ws.fingerprint(), fp, "training workspace must not reallocate");
    }

    #[test]
    fn streaming_training_matches_blocked_forward_and_backward() {
        // The whole-model forward and every parameter gradient must agree
        // between the streaming workspace (no retained probs, recompute
        // backward) and the blocked one (retained probs) — the cross-path
        // pin that lets the crossover knob flip the training path safely.
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 71);
        let mut rng = Rng::new(72);
        let x = rand_tokens(&cfg, &mut rng, 2);
        let y: Vec<i32> = (0..x.len()).map(|_| rng.below(cfg.vocab) as i32).collect();

        let mut ws_b = Workspace::new_blocked(&cfg);
        let mut ws_s = Workspace::new_streaming(&cfg);
        assert!(!ws_b.is_streaming() && ws_s.is_streaming());

        let cache_b = forward_ws(&cfg, &teacher, None, &x, 2, &mut ws_b).unwrap();
        let cache_s = forward_ws(&cfg, &teacher, None, &x, 2, &mut ws_s).unwrap();
        assert!(
            cache_s.blocks.iter().all(|blk| blk.probs.is_empty()),
            "streaming forward must not retain (t, t) probs"
        );
        for (a, b) in cache_b.logits.iter().zip(&cache_s.logits) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "logits diverge: {a} vs {b}");
        }

        let (_, dlogits) = ce_loss_grad(&cache_b.logits, &y, cfg.vocab);
        let grads_b = backward_ws(&cfg, &teacher, None, &cache_b, &dlogits, &mut ws_b).unwrap();
        let (_, dlogits_s) = ce_loss_grad(&cache_s.logits, &y, cfg.vocab);
        let grads_s = backward_ws(&cfg, &teacher, None, &cache_s, &dlogits_s, &mut ws_s).unwrap();
        for (name, gb) in grads_b.map.iter() {
            let gb = gb.as_f32().unwrap();
            let gs = grads_s.get(name).unwrap().as_f32().unwrap();
            for (i, (a, b)) in gb.iter().zip(gs).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "grad {name}[{i}]: blocked {a} vs streaming {b}"
                );
            }
        }
    }

    #[test]
    fn streaming_training_workspace_never_reallocates_across_steps() {
        // The KD-style loop over a streaming Workspace (recompute backward,
        // lazily sized grad panels) must never grow it after the first
        // step — the streaming resize keeps the zero-realloc contract.
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 93);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let mut student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let profile: Vec<usize> = vec![5; cfg.n_fact_layers()];
        let mut rng = Rng::new(94);
        let mut ws = Workspace::new_streaming(&cfg);
        let mut opt = AdamW::new(&cfg, &student);
        let mut step = |p: &mut ParamSet, opt: &mut AdamW, ws: &mut Workspace, rng: &mut Rng| {
            let x = rand_tokens(&cfg, rng, 2);
            let t_cache = forward_ws(&cfg, &teacher, None, &x, 2, ws).unwrap();
            let s_cache = forward_ws(&cfg, p, Some(&profile), &x, 2, ws).unwrap();
            let (_, dlogits) =
                kd_loss_grad(&s_cache.logits, &t_cache.logits, cfg.vocab, cfg.tau_kd as f32);
            let grads = backward_ws(&cfg, p, Some(&profile), &s_cache, &dlogits, ws).unwrap();
            opt.step(p, &grads).unwrap();
        };
        step(&mut student, &mut opt, &mut ws, &mut rng);
        let fp = ws.fingerprint();
        for _ in 0..3 {
            step(&mut student, &mut opt, &mut ws, &mut rng);
        }
        assert_eq!(ws.fingerprint(), fp, "streaming training workspace must not reallocate");
    }

    #[test]
    fn native_training_forward_matches_serving_gar() {
        // The serving GAR re-gauge at a profile must compute the same
        // function the training path evaluated — pins that DP probe losses
        // describe what the coordinator actually serves.  Both sides now
        // run the one shared attention in `runtime::attention`, so this is
        // a whole-forward consistency check, not an attention one.
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 61);
        let factors = decompose_teacher(&cfg, &teacher, None).unwrap();
        let student = student_from_factors(&cfg, &teacher, &factors).unwrap();
        let profile = uniform_budget_profile(&cfg, 0.5);
        let batch = 2;
        let tokens: Vec<i32> =
            (0..batch * cfg.seq_len).map(|i| (i * 5 % cfg.vocab) as i32).collect();

        let cache = forward(&cfg, &student, Some(&profile), &tokens, batch).unwrap();
        let sub = GarSubmodel::from_student(&cfg, &student, &profile).unwrap();
        let mut scratch = Scratch::for_config(&cfg, batch * cfg.seq_len);
        sub.forward(&tokens, batch, &mut scratch).unwrap();
        let serve = scratch.logits(batch * cfg.seq_len, cfg.vocab);
        for (a, b) in cache.logits.iter().zip(serve) {
            assert!((a - b).abs() < 5e-3, "training {a} vs serving {b}");
        }
    }

    #[test]
    fn kd_loss_zero_when_equal_and_grad_checks() {
        let vocab = 7;
        let mut rng = Rng::new(71);
        let t: Vec<f32> = (0..2 * vocab).map(|_| rng.normal() as f32).collect();
        let (l0, g0) = kd_loss_grad(&t, &t, vocab, 2.0);
        assert!(l0.abs() < 1e-6, "KD(s=t) = {l0}");
        assert!(g0.iter().all(|g| g.abs() < 1e-6));

        let s: Vec<f32> = (0..2 * vocab).map(|_| rng.normal() as f32).collect();
        let (_, g) = kd_loss_grad(&s, &t, vocab, 2.0);
        let eps = 1e-2f32;
        for idx in [0usize, 5, 9] {
            let mut sp = s.clone();
            sp[idx] += eps;
            let (lp, _) = kd_loss_grad(&sp, &t, vocab, 2.0);
            sp[idx] -= 2.0 * eps;
            let (lm, _) = kd_loss_grad(&sp, &t, vocab, 2.0);
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - g[idx]).abs() < 1e-3 + 0.05 * g[idx].abs(),
                "kd grad[{idx}]: numeric {num} vs analytic {}",
                g[idx]
            );
        }
    }

    #[test]
    fn adamw_pretrain_reduces_loss() {
        let cfg = test_cfg();
        let corpus = Corpus::generate(20_000, 9);
        let mut batcher =
            TokenBatcher::new(&corpus.train, cfg.batch_train, cfg.seq_len + 1, cfg.vocab, 10);
        let init = random_teacher(&cfg, 11);
        let run = pretrain_teacher(&cfg, init, &mut batcher, 40, 0).unwrap();
        assert_eq!(run.losses.len(), 40);
        assert!(run.losses.iter().all(|l| l.is_finite()));
        let first: f32 = run.losses[..5].iter().sum::<f32>() / 5.0;
        let last: f32 = run.losses[35..].iter().sum::<f32>() / 5.0;
        assert!(last < first, "pretraining must reduce CE: {first} -> {last}");
    }

    #[test]
    fn calibrate_accumulates_psd_covariances() {
        let cfg = test_cfg();
        let teacher = random_teacher(&cfg, 81);
        let corpus = Corpus::generate(20_000, 12);
        let mut batcher =
            TokenBatcher::new(&corpus.train, cfg.batch_calib, cfg.seq_len + 1, cfg.vocab, 13);
        let covs = calibrate(&cfg, &teacher, &mut batcher, 2).unwrap();
        assert_eq!(covs.len(), cfg.n_fact_layers());
        let d = cfg.d_model;
        for (li, cov) in covs.iter().enumerate() {
            let want = if li % 4 == 3 { 4 * d } else { d };
            assert_eq!(cov.sigma.rows, want, "layer {li} cov dim");
            assert_eq!(cov.count, 2 * cfg.batch_calib * cfg.seq_len);
            // Diagonal of XᵀX is non-negative.
            for i in 0..cov.sigma.rows {
                assert!(cov.sigma[(i, i)] >= 0.0);
            }
        }
    }
}
