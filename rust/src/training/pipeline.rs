//! The full FlexRank pipeline (Alg. 1), orchestrated from rust:
//!
//!   pretrain teacher → calibrate (covariances) → DataSVD decomposition →
//!   sensitivity probe → DP rank selection → nested KD consolidation →
//!   evaluation across budgets → profiles.json for the serving tiers.
//!
//! The stage orchestration lives **once**, in `run_stages`, behind the
//! `StageBackend` trait: the native backend ([`crate::training::native`] —
//! manual backprop over `linalg::kernels`, fully offline) is the default,
//! and the PJRT-artifact drivers implement the same trait behind the
//! `pjrt` feature (`repro pipeline --backend pjrt`).  Both used to carry a
//! byte-duplicated copy of the skeleton.
//!
//! Stages checkpoint under [`stage_dir`] (`teacher`, `student_init`,
//! `student_kd` — `ckpt` JSON+blob pairs) so reruns resume and the serving
//! CLI can pick up the consolidated student.  The DP output is persisted as
//! `stage_dir()/profiles.json`: one rank profile per serving tier, which
//! `SubmodelRegistry::load_native` consumes via
//! `coordinator::load_tier_profiles` (uniform fallback when absent).

use std::path::PathBuf;

use anyhow::{ensure, Result};

use crate::cli::Args;
use crate::config::RunConfig;
use crate::data::{Corpus, TokenBatcher};
use crate::flexrank::decompose::CovAccum;
use crate::flexrank::dp::dp_rank_selection;
use crate::flexrank::masks::{NestedChain, RankProfile};
use crate::flexrank::sensitivity::{probe, uniform_grid, Sensitivity};
use crate::json::{self, Value};
use crate::runtime::ModelConfig;
use crate::training::params::{
    decompose_teacher, random_teacher, student_from_factors, ParamSet,
};
use crate::training::{ckpt, native, TrainRun, CORPUS_BYTES};

/// Everything a pipeline run produces.
pub struct PipelineOut {
    pub teacher: ParamSet,
    pub student: ParamSet,
    pub student_init: ParamSet,
    pub chain: NestedChain,
    pub full_cost: u64,
    /// (budget, profile, eval loss before KD, eval loss after KD)
    pub budget_rows: Vec<(f64, Vec<usize>, f64, f64)>,
    pub pretrain_losses: Vec<f32>,
    pub kd_losses: Vec<f32>,
    /// DP-selected rank profile per serving tier (ascending budgets),
    /// exactly what `profiles.json` records.
    pub tier_profiles: Vec<RankProfile>,
}

/// Stage outputs directory (shared with the serving CLI).
pub fn stage_dir() -> PathBuf {
    crate::training::stage_dir()
}

/// Persisted DP tier profiles (consumed by `repro serve`).
pub fn profiles_path() -> PathBuf {
    stage_dir().join("profiles.json")
}

/// Stage checkpoints live in one shared dir un-keyed by config; a resumed
/// parameter set from a *different* config would slice in-bounds but
/// compute garbage (or panic opaquely), so validate the embedding shapes
/// against the active config before trusting a checkpoint.
fn ensure_ckpt_matches(cfg: &ModelConfig, ps: &ParamSet, what: &str) -> Result<()> {
    for (name, want) in [
        ("tok_emb", [cfg.vocab, cfg.d_model]),
        ("pos_emb", [cfg.seq_len, cfg.d_model]),
    ] {
        let got = ps.get(name)?.shape().to_vec();
        ensure!(
            got == want,
            "{what} checkpoint under {} has {name} shape {got:?} but config '{}' \
             needs {want:?} — it was written for a different config; rerun with --fresh",
            stage_dir().display(),
            cfg.name
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The stage skeleton, shared across training backends
// ---------------------------------------------------------------------------

/// One training backend behind the pipeline seam.  The pretrain →
/// calibrate → DataSVD → probe → DP → KD → eval orchestration (checkpoint
/// reuse, stage ordering, profile persistence) lives once in
/// [`run_stages`]; a backend only supplies the per-stage compute — native
/// manual backprop by default, the PJRT artifact drivers behind `pjrt`.
trait StageBackend {
    /// Short tag for stage log lines ("native", "pjrt").
    fn label(&self) -> &'static str;

    /// Teacher parameters to pretrain from.
    fn teacher_init(&mut self, cfg: &ModelConfig, seed: u64) -> Result<ParamSet>;

    fn pretrain(
        &mut self,
        cfg: &ModelConfig,
        init: ParamSet,
        batcher: &mut TokenBatcher,
        steps: usize,
        log_every: usize,
    ) -> Result<TrainRun>;

    fn calibrate(
        &mut self,
        cfg: &ModelConfig,
        teacher: &ParamSet,
        batcher: &mut TokenBatcher,
        batches: usize,
    ) -> Result<Vec<CovAccum>>;

    /// Sensitivity probe over `grids` (App. C.2); implementations print
    /// their own eval count.
    fn sensitivity(
        &mut self,
        cfg: &ModelConfig,
        student: &ParamSet,
        eval_batches: &[Vec<i32>],
        grids: &[Vec<usize>],
    ) -> Result<Sensitivity>;

    #[allow(clippy::too_many_arguments)]
    fn consolidate(
        &mut self,
        cfg: &ModelConfig,
        student: ParamSet,
        teacher: &ParamSet,
        profiles: &[RankProfile],
        alphas: &[f64],
        batcher: &mut TokenBatcher,
        steps: usize,
        seed: u64,
        log_every: usize,
    ) -> Result<TrainRun>;

    fn eval_student(
        &mut self,
        cfg: &ModelConfig,
        student: &ParamSet,
        profile: &RankProfile,
        eval_batches: &[Vec<i32>],
    ) -> Result<f64>;
}

/// Run (or resume) the full Algorithm-1 pipeline over any stage backend.
fn run_stages(
    backend: &mut dyn StageBackend,
    cfg: &ModelConfig,
    rc: &RunConfig,
    fresh: bool,
) -> Result<PipelineOut> {
    let label = backend.label();
    let dir = stage_dir();
    std::fs::create_dir_all(&dir)?;

    let corpus = Corpus::generate(CORPUS_BYTES, rc.seed);
    let mut train_b = TokenBatcher::new(
        &corpus.train,
        cfg.batch_train,
        cfg.seq_len + 1,
        cfg.vocab,
        rc.seed ^ 0xA5,
    );
    let eval_b = TokenBatcher::new(
        &corpus.heldout,
        cfg.batch_eval,
        cfg.seq_len + 1,
        cfg.vocab,
        rc.seed ^ 0x5A,
    );
    let eval_batches = eval_b.eval_batches(rc.eval_batches);

    // --- Stage 1: teacher pretraining --------------------------------------
    let teacher_stem = dir.join("teacher");
    let (teacher, pretrain_losses) = if !fresh && ckpt::exists(&teacher_stem) {
        eprintln!("[pipeline] reusing teacher checkpoint");
        let t = ckpt::load(&teacher_stem)?;
        ensure_ckpt_matches(cfg, &t, "teacher")?;
        (t, Vec::new())
    } else {
        eprintln!(
            "[pipeline] pretraining teacher for {} steps ({label})",
            rc.pretrain_steps
        );
        let init = backend.teacher_init(cfg, rc.seed)?;
        let run = backend.pretrain(cfg, init, &mut train_b, rc.pretrain_steps, rc.log_every)?;
        ckpt::save(&run.params, &teacher_stem)?;
        (run.params, run.losses)
    };

    // --- Stage 2: calibration + DataSVD decomposition ----------------------
    let student_stem = dir.join("student_init");
    let student0 = if !fresh && ckpt::exists(&student_stem) {
        eprintln!("[pipeline] reusing DataSVD student init");
        let s = ckpt::load(&student_stem)?;
        ensure_ckpt_matches(cfg, &s, "student_init")?;
        s
    } else {
        eprintln!("[pipeline] calibrating covariances ({} batches)", rc.calib_batches);
        let mut calib_b = TokenBatcher::new(
            &corpus.train,
            cfg.batch_calib,
            cfg.seq_len + 1,
            cfg.vocab,
            rc.seed ^ 0x33,
        );
        let covs = backend.calibrate(cfg, &teacher, &mut calib_b, rc.calib_batches)?;
        eprintln!("[pipeline] DataSVD decomposition of {} layers", cfg.n_fact_layers());
        let factors = decompose_teacher(cfg, &teacher, Some(&covs))?;
        let s = student_from_factors(cfg, &teacher, &factors)?;
        ckpt::save(&s, &student_stem)?;
        s
    };

    // --- Stage 3: sensitivity probe + DP selection -------------------------
    eprintln!("[pipeline] probing layer sensitivities ({label})");
    let grids: Vec<Vec<usize>> = (0..cfg.n_fact_layers())
        .map(|_| uniform_grid(cfg.rank_full(), rc.probe_levels))
        .collect();
    let sens = backend.sensitivity(cfg, &student0, &eval_batches, &grids)?;
    let quant = (sens.full_cost / 4096).max(1);
    let dp = dp_rank_selection(&sens.candidates, sens.full_cost, quant)?;
    eprintln!(
        "[pipeline] DP: {} pareto states, chain of {}",
        dp.pareto.len(),
        dp.chain.profiles.len()
    );

    // --- Stage 4: consolidation over budget profiles -----------------------
    let budget_profiles = dp.chain.select(&rc.budgets, sens.full_cost as usize);
    let consolidated_stem = dir.join("student_kd");
    let (student, kd_losses) = if !fresh && ckpt::exists(&consolidated_stem) {
        eprintln!("[pipeline] reusing consolidated student");
        let s = ckpt::load(&consolidated_stem)?;
        ensure_ckpt_matches(cfg, &s, "student_kd")?;
        (s, Vec::new())
    } else {
        eprintln!("[pipeline] consolidating for {} steps ({label})", rc.consolidate_steps);
        let run = backend.consolidate(
            cfg,
            student0.clone(),
            &teacher,
            &budget_profiles,
            &rc.alphas,
            &mut train_b,
            rc.consolidate_steps,
            rc.seed ^ 0x77,
            rc.log_every,
        )?;
        ckpt::save(&run.params, &consolidated_stem)?;
        (run.params, run.losses)
    };

    // --- Stage 5: evaluation across budgets ---------------------------------
    eprintln!("[pipeline] evaluating across {} budgets", rc.budgets.len());
    let mut budget_rows = Vec::new();
    for (beta, profile) in rc.budgets.iter().zip(&budget_profiles) {
        let before = backend.eval_student(cfg, &student0, profile, &eval_batches)?;
        let after = backend.eval_student(cfg, &student, profile, &eval_batches)?;
        eprintln!(
            "  budget {beta:.2}: ranks {:?}.. loss {before:.4} -> {after:.4}",
            &profile[..4.min(profile.len())]
        );
        budget_rows.push((*beta, profile.clone(), before, after));
    }

    // --- Stage 6: per-tier DP profiles for serving --------------------------
    // Fingerprint the *consolidated* student: that is what `repro serve`
    // loads next to profiles.json, and what the staleness check compares.
    let (ppath, tier_profiles) = write_profiles_json(cfg, &dp.chain, sens.full_cost, &student)?;
    eprintln!("[pipeline] wrote {} ({} tiers)", ppath.display(), tier_profiles.len());

    Ok(PipelineOut {
        teacher,
        student,
        student_init: student0,
        chain: dp.chain,
        full_cost: sens.full_cost,
        budget_rows,
        pretrain_losses,
        kd_losses,
        tier_profiles,
    })
}

/// The default backend: `training::native` manual backprop over the f32
/// kernels, fully offline.  Holds one persistent [`native::Workspace`] so
/// repeated stage-5 evals reuse the attention panels instead of
/// re-allocating them per call (the probe and train loops carry their own).
struct NativeStage {
    ws: native::Workspace,
}

impl StageBackend for NativeStage {
    fn label(&self) -> &'static str {
        "native"
    }

    fn teacher_init(&mut self, cfg: &ModelConfig, seed: u64) -> Result<ParamSet> {
        Ok(random_teacher(cfg, seed))
    }

    fn pretrain(
        &mut self,
        cfg: &ModelConfig,
        init: ParamSet,
        batcher: &mut TokenBatcher,
        steps: usize,
        log_every: usize,
    ) -> Result<TrainRun> {
        native::pretrain_teacher(cfg, init, batcher, steps, log_every)
    }

    fn calibrate(
        &mut self,
        cfg: &ModelConfig,
        teacher: &ParamSet,
        batcher: &mut TokenBatcher,
        batches: usize,
    ) -> Result<Vec<CovAccum>> {
        native::calibrate(cfg, teacher, batcher, batches)
    }

    fn sensitivity(
        &mut self,
        cfg: &ModelConfig,
        student: &ParamSet,
        eval_batches: &[Vec<i32>],
        grids: &[Vec<usize>],
    ) -> Result<Sensitivity> {
        let mut probe_model = native::NativeProbe {
            cfg,
            student,
            eval_batches,
            evals: 0,
            ws: &mut self.ws,
        };
        let sens = probe(&mut probe_model, grids);
        eprintln!(
            "[pipeline] probe done ({} evals, full loss {:.4})",
            probe_model.evals, sens.full_loss
        );
        Ok(sens)
    }

    #[allow(clippy::too_many_arguments)]
    fn consolidate(
        &mut self,
        cfg: &ModelConfig,
        student: ParamSet,
        teacher: &ParamSet,
        profiles: &[RankProfile],
        alphas: &[f64],
        batcher: &mut TokenBatcher,
        steps: usize,
        seed: u64,
        log_every: usize,
    ) -> Result<TrainRun> {
        native::consolidate(cfg, student, teacher, profiles, alphas, batcher, steps, seed, log_every)
    }

    fn eval_student(
        &mut self,
        cfg: &ModelConfig,
        student: &ParamSet,
        profile: &RankProfile,
        eval_batches: &[Vec<i32>],
    ) -> Result<f64> {
        native::eval_student_ws(cfg, student, profile, eval_batches, &mut self.ws)
    }
}

/// Run (or resume) the full pipeline on the native backend.
pub fn run_native(cfg: &ModelConfig, rc: &RunConfig, fresh: bool) -> Result<PipelineOut> {
    run_stages(&mut NativeStage { ws: native::Workspace::new(cfg) }, cfg, rc, fresh)
}

/// Pick one chain index per serving tier: the largest-cost profile fitting
/// the tier's budget, then bumped so indices ascend strictly (two close
/// tiers must never serve the same submodel — `load_native` rejects
/// duplicate tiers).
fn select_tier_indices(chain: &NestedChain, tiers: &[f64], full_cost: usize) -> Result<Vec<usize>> {
    let n = chain.profiles.len();
    ensure!(n > 0, "empty DP chain");
    ensure!(
        n >= tiers.len(),
        "DP chain has {n} profiles for {} serving tiers — rerun the probe \
         with more levels (--probe-levels)",
        tiers.len()
    );
    let mut idxs: Vec<usize> = tiers
        .iter()
        .map(|&beta| {
            let cap = (beta * full_cost as f64).round() as usize;
            let mut best = 0usize;
            for (i, &c) in chain.costs.iter().enumerate() {
                if c <= cap {
                    best = i;
                }
            }
            best
        })
        .collect();
    // Cap from the top so every later tier still has headroom, then bump
    // forward so indices ascend strictly.
    let len = idxs.len();
    for (i, idx) in idxs.iter_mut().enumerate() {
        let cap = n - len + i;
        if *idx > cap {
            *idx = cap;
        }
    }
    for i in 1..len {
        if idxs[i] <= idxs[i - 1] {
            idxs[i] = idxs[i - 1] + 1;
        }
    }
    Ok(idxs)
}

/// Persist the DP-selected per-tier profiles as `stage_dir()/profiles.json`.
///
/// Schema (documented in ROADMAP.md):
/// ```json
/// {
///   "config": "tiny",            // model config the profiles were DP'd for
///   "full_cost": 24576,          // full-model GAR parameter cost
///   "params_fp": "a1b2c3d4e5f60718",  // student content fingerprint (hex)
///   "tiers": [                   // one entry per cfg.serve_tiers, ascending
///     {"budget": 0.5, "cost": 117, "error": 0.012,
///      "precision": "f32",       // tier factor storage (f32 | bf16 | i8)
///      "profile": [11, 21, ...]},
///     ...
///   ]
/// }
/// ```
///
/// `params_fp` is [`ParamSet::content_fingerprint`] of the student these
/// profiles describe (the consolidated `student_kd`); `load_tier_profiles`
/// rejects the file when the served student fingerprints differently — a
/// re-trained same-shape student silently invalidating its DP profiles was
/// the one staleness class the `full_cost` dimensional check could not see.
///
/// `error` is the DP chain's measured calibration loss for the tier and
/// doubles as the serving router's **difficulty signal**: the
/// input-adaptive router interpolates per-SLO quality bars over these
/// values and maps each request to the smallest tier whose error clears
/// its bar ([`crate::coordinator::TierRouter`]).  Absent (legacy files),
/// loading falls back to the `1 - budget` ordering proxy; present, it must
/// be finite and non-negative or the load fails loudly.
pub fn write_profiles_json(
    cfg: &ModelConfig,
    chain: &NestedChain,
    full_cost: u64,
    student: &ParamSet,
) -> Result<(PathBuf, Vec<RankProfile>)> {
    let idxs = select_tier_indices(chain, &cfg.serve_tiers, full_cost as usize)?;
    let tiers: Vec<Value> = idxs
        .iter()
        .enumerate()
        .zip(&cfg.serve_tiers)
        .map(|((i, &ci), &budget)| {
            let prec = cfg
                .tier_precision
                .get(i)
                .copied()
                .unwrap_or(crate::linalg::quant::Precision::F32);
            json::obj(vec![
                ("budget", Value::Num(budget)),
                ("cost", Value::Num(chain.costs[ci] as f64)),
                ("error", Value::Num(chain.errors[ci])),
                ("precision", Value::Str(prec.label().to_string())),
                ("profile", json::arr_usize(&chain.profiles[ci])),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("config", Value::Str(cfg.name.clone())),
        ("full_cost", Value::Num(full_cost as f64)),
        // Hex string, not a JSON number: the fingerprint is a full u64 and
        // f64 round-tripping would corrupt it.
        ("params_fp", Value::Str(format!("{:016x}", student.content_fingerprint()))),
        ("tiers", Value::Arr(tiers)),
    ]);
    let path = profiles_path();
    std::fs::create_dir_all(stage_dir())?;
    std::fs::write(&path, json::to_string(&doc))?;
    Ok((path, idxs.into_iter().map(|i| chain.profiles[i].clone()).collect()))
}

fn parse_run_config(args: &Args) -> Result<RunConfig> {
    if args.flag("smoke") {
        RunConfig::smoke().with_args(args)
    } else {
        RunConfig::default().with_args(args)
    }
}

/// `repro pipeline [--config base|tiny] [--smoke] [--fresh]
/// [--pretrain-steps N] ...` — native backend by default; `--backend pjrt`
/// drives the AOT artifacts when compiled with the feature.
pub fn run_cli(args: &Args) -> Result<()> {
    #[cfg(feature = "pjrt")]
    if args.get_or("backend", "native") == "pjrt" {
        return run_cli_pjrt(args);
    }
    ensure!(
        args.get_or("backend", "native") == "native",
        "unknown --backend (this build supports: native{})",
        if cfg!(feature = "pjrt") { ", pjrt" } else { "" }
    );
    let rc = parse_run_config(args)?;
    let cfg = crate::config::load_model_config(args.get_or("config", "base"))?;
    let out = run_native(&cfg, &rc, args.flag("fresh"))?;
    write_summary(&out)
}

/// Persist the budget table for figures/EXPERIMENTS.md.
fn write_summary(out: &PipelineOut) -> Result<()> {
    let rows: Vec<Value> = out
        .budget_rows
        .iter()
        .map(|(b, prof, before, after)| {
            json::obj(vec![
                ("budget", Value::Num(*b)),
                ("profile", json::arr_usize(prof)),
                ("loss_datasvd_init", Value::Num(*before)),
                ("loss_flexrank", Value::Num(*after)),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("full_cost", Value::Num(out.full_cost as f64)),
        (
            "pretrain_losses",
            json::arr_f64(&out.pretrain_losses.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        ),
        (
            "kd_losses",
            json::arr_f64(&out.kd_losses.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        ),
        ("budgets", Value::Arr(rows)),
    ]);
    let path = crate::results_dir().join("pipeline_summary.json");
    std::fs::write(&path, json::to_string(&doc))?;
    println!("pipeline complete -> {}", path.display());
    Ok(())
}

/// `repro profiles` — run (or resume) stages 1–3 and refresh
/// `stage_dir()/profiles.json` with one DP rank profile per serving tier.
pub fn write_profiles_cli(args: &Args) -> Result<()> {
    #[cfg(feature = "pjrt")]
    if args.get_or("backend", "native") == "pjrt" {
        return write_profiles_cli_pjrt(args);
    }
    ensure!(
        args.get_or("backend", "native") == "native",
        "unknown --backend (this build supports: native{})",
        if cfg!(feature = "pjrt") { ", pjrt" } else { "" }
    );
    let rc = parse_run_config(args)?;
    let cfg = crate::config::load_model_config(args.get_or("config", "base"))?;
    let out = run_native(&cfg, &rc, args.flag("fresh"))?;
    println!(
        "wrote {} ({} tiers; `repro serve --config {}` now uses DP profiles)",
        profiles_path().display(),
        out.tier_profiles.len(),
        cfg.name
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// PJRT-artifact variant (feature `pjrt`; used by the figure harnesses)
// ---------------------------------------------------------------------------

/// The PJRT backend: every stage runs the AOT artifact drivers
/// ([`crate::training::driver`]) on the engine; the orchestration is the
/// same shared `run_stages` skeleton the native backend uses.
#[cfg(feature = "pjrt")]
struct PjrtStage<'e> {
    engine: &'e crate::runtime::Engine,
}

#[cfg(feature = "pjrt")]
impl StageBackend for PjrtStage<'_> {
    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn teacher_init(&mut self, _cfg: &ModelConfig, _seed: u64) -> Result<ParamSet> {
        // The AOT chain pins the init the artifacts were lowered with.
        Ok(ParamSet::from_specs(
            &self.engine.manifest.teacher_init,
            self.engine.manifest.load_teacher_init()?,
        ))
    }

    fn pretrain(
        &mut self,
        _cfg: &ModelConfig,
        init: ParamSet,
        batcher: &mut TokenBatcher,
        steps: usize,
        log_every: usize,
    ) -> Result<TrainRun> {
        crate::training::driver::pretrain_teacher(self.engine, init, batcher, steps, log_every)
    }

    fn calibrate(
        &mut self,
        _cfg: &ModelConfig,
        teacher: &ParamSet,
        batcher: &mut TokenBatcher,
        batches: usize,
    ) -> Result<Vec<CovAccum>> {
        crate::training::driver::calibrate(self.engine, teacher, batcher, batches)
    }

    fn sensitivity(
        &mut self,
        _cfg: &ModelConfig,
        student: &ParamSet,
        eval_batches: &[Vec<i32>],
        grids: &[Vec<usize>],
    ) -> Result<Sensitivity> {
        let mut probe_model = crate::training::driver::StudentProbe {
            engine: self.engine,
            student,
            eval_batches: eval_batches.to_vec(),
            evals: 0,
        };
        let sens = probe(&mut probe_model, grids);
        eprintln!(
            "[pipeline] probe done ({} evals, full loss {:.4})",
            probe_model.evals, sens.full_loss
        );
        Ok(sens)
    }

    #[allow(clippy::too_many_arguments)]
    fn consolidate(
        &mut self,
        _cfg: &ModelConfig,
        student: ParamSet,
        teacher: &ParamSet,
        profiles: &[RankProfile],
        alphas: &[f64],
        batcher: &mut TokenBatcher,
        steps: usize,
        seed: u64,
        log_every: usize,
    ) -> Result<TrainRun> {
        crate::training::driver::consolidate(
            self.engine, student, teacher, profiles, alphas, batcher, steps, seed, log_every,
        )
    }

    fn eval_student(
        &mut self,
        _cfg: &ModelConfig,
        student: &ParamSet,
        profile: &RankProfile,
        eval_batches: &[Vec<i32>],
    ) -> Result<f64> {
        crate::training::driver::eval_student(self.engine, student, profile, eval_batches)
    }
}

/// Run (or resume) the full pipeline over the PJRT artifacts.
#[cfg(feature = "pjrt")]
pub fn run(engine: &crate::runtime::Engine, rc: &RunConfig, fresh: bool) -> Result<PipelineOut> {
    let cfg = engine.manifest.config.clone();
    run_stages(&mut PjrtStage { engine }, &cfg, rc, fresh)
}

#[cfg(feature = "pjrt")]
fn run_cli_pjrt(args: &Args) -> Result<()> {
    use anyhow::Context;
    let rc = parse_run_config(args)?;
    let engine = crate::runtime::Engine::new(crate::artifacts_dir()).context("engine init")?;
    let out = run(&engine, &rc, args.flag("fresh"))?;
    write_summary(&out)
}

/// PJRT `repro profiles --backend pjrt` — additionally mirrors the tier
/// profiles into artifacts/profiles.json (the phase-2 AOT input).
#[cfg(feature = "pjrt")]
fn write_profiles_cli_pjrt(args: &Args) -> Result<()> {
    let rc = parse_run_config(args)?;
    let engine = crate::runtime::Engine::new(crate::artifacts_dir())?;
    let out = run(&engine, &rc, args.flag("fresh"))?;
    let doc = json::obj(vec![(
        "tiers",
        Value::Arr(out.tier_profiles.iter().map(|p| json::arr_usize(p)).collect()),
    )]);
    let path = crate::artifacts_dir().join("profiles.json");
    std::fs::write(&path, json::to_string(&doc))?;
    println!(
        "wrote {} (run `make serve-artifacts` to re-lower serving forwards)",
        path.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(costs: Vec<usize>) -> NestedChain {
        // Strictly nested profiles with the given costs (test scaffolding:
        // profile content is irrelevant to index selection).
        let profiles = (0..costs.len()).map(|i| vec![i + 1]).collect();
        let errors = costs.iter().rev().map(|&c| c as f64).collect();
        NestedChain { profiles, costs, errors }
    }

    #[test]
    fn tier_indices_ascend_strictly_and_fit_budgets() {
        let c = chain(vec![10, 20, 30, 40]);
        let idx = select_tier_indices(&c, &[0.25, 0.5, 1.0], 40).unwrap();
        assert_eq!(idx, vec![0, 1, 3]);
    }

    #[test]
    fn close_tiers_bump_instead_of_collapsing() {
        let c = chain(vec![10, 20, 30, 40]);
        // Both budgets select cost 20 (index 1); the second must bump to 2.
        let idx = select_tier_indices(&c, &[0.5, 0.55], 40).unwrap();
        assert_eq!(idx, vec![1, 2]);
    }

    #[test]
    fn top_heavy_tiers_cap_from_the_top() {
        let c = chain(vec![10, 20, 30]);
        // All three select the last profile; capping must spread them.
        let idx = select_tier_indices(&c, &[0.9, 0.95, 1.0], 30).unwrap();
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn too_many_tiers_for_chain_is_an_error() {
        let c = chain(vec![10]);
        assert!(select_tier_indices(&c, &[0.5, 1.0], 10).is_err());
    }
}
