//! The full FlexRank pipeline (Alg. 1), orchestrated from rust:
//!
//!   pretrain teacher → calibrate (covariances) → DataSVD decomposition →
//!   sensitivity probe → DP rank selection → nested KD consolidation →
//!   evaluation across budgets → profiles.json for the serving AOT phase.
//!
//! Stages checkpoint under `results/` so figure harnesses can reuse them.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::config::RunConfig;
use crate::data::{Corpus, TokenBatcher};
use crate::flexrank::dp::dp_rank_selection;
use crate::flexrank::masks::NestedChain;
use crate::flexrank::sensitivity::{probe, uniform_grid};
use crate::json::{self, Value};
use crate::runtime::Engine;
use crate::training::driver;
use crate::training::params::{decompose_teacher, student_from_factors, ParamSet};
use crate::training::{ckpt, CORPUS_BYTES};

/// Everything a pipeline run produces.
pub struct PipelineOut {
    pub teacher: ParamSet,
    pub student: ParamSet,
    pub student_init: ParamSet,
    pub chain: NestedChain,
    pub full_cost: u64,
    /// (budget, profile, eval loss before KD, eval loss after KD)
    pub budget_rows: Vec<(f64, Vec<usize>, f64, f64)>,
    pub pretrain_losses: Vec<f32>,
    pub kd_losses: Vec<f32>,
}

/// Stage outputs directory (shared with the serving CLI).
pub fn stage_dir() -> PathBuf {
    crate::training::stage_dir()
}

/// Run (or resume) the full pipeline.
pub fn run(engine: &Engine, rc: &RunConfig, fresh: bool) -> Result<PipelineOut> {
    let cfg = engine.manifest.config.clone();
    let dir = stage_dir();
    std::fs::create_dir_all(&dir)?;

    let corpus = Corpus::generate(CORPUS_BYTES, rc.seed);
    let mut train_b = TokenBatcher::new(
        &corpus.train,
        cfg.batch_train,
        cfg.seq_len + 1,
        cfg.vocab,
        rc.seed ^ 0xA5,
    );
    let eval_b = TokenBatcher::new(
        &corpus.heldout,
        cfg.batch_eval,
        cfg.seq_len + 1,
        cfg.vocab,
        rc.seed ^ 0x5A,
    );
    let eval_batches = eval_b.eval_batches(rc.eval_batches);

    // --- Stage 1: teacher pretraining --------------------------------------
    let teacher_stem = dir.join("teacher");
    let (teacher, pretrain_losses) = if !fresh && ckpt::exists(&teacher_stem) {
        eprintln!("[pipeline] reusing teacher checkpoint");
        (ckpt::load(&teacher_stem)?, Vec::new())
    } else {
        eprintln!("[pipeline] pretraining teacher for {} steps", rc.pretrain_steps);
        let init = ParamSet::from_specs(
            &engine.manifest.teacher_init,
            engine.manifest.load_teacher_init()?,
        );
        let run = driver::pretrain_teacher(
            engine,
            init,
            &mut train_b,
            rc.pretrain_steps,
            rc.log_every,
        )?;
        ckpt::save(&run.params, &teacher_stem)?;
        (run.params, run.losses)
    };

    // --- Stage 2: calibration + DataSVD decomposition ----------------------
    let student_stem = dir.join("student_init");
    let student0 = if !fresh && ckpt::exists(&student_stem) {
        eprintln!("[pipeline] reusing DataSVD student init");
        ckpt::load(&student_stem)?
    } else {
        eprintln!("[pipeline] calibrating covariances ({} batches)", rc.calib_batches);
        let mut calib_b = TokenBatcher::new(
            &corpus.train,
            cfg.batch_train, // batcher batch; calibrate() slices what it needs
            cfg.seq_len + 1,
            cfg.vocab,
            rc.seed ^ 0x33,
        );
        let covs = driver::calibrate(engine, &teacher, &mut calib_b, rc.calib_batches)?;
        eprintln!("[pipeline] DataSVD decomposition of {} layers", cfg.n_fact_layers());
        let factors = decompose_teacher(&cfg, &teacher, Some(&covs))?;
        let s = student_from_factors(&cfg, &teacher, &factors)?;
        ckpt::save(&s, &student_stem)?;
        s
    };

    // --- Stage 3: sensitivity probe + DP selection -------------------------
    eprintln!("[pipeline] probing layer sensitivities");
    let mut probe_model = driver::StudentProbe {
        engine,
        student: &student0,
        eval_batches: eval_batches.clone(),
        evals: 0,
    };
    let k_levels = rc.probe_levels;
    let grids: Vec<Vec<usize>> =
        (0..cfg.n_fact_layers()).map(|_| uniform_grid(cfg.rank_full(), k_levels)).collect();
    let sens = probe(&mut probe_model, &grids);
    eprintln!(
        "[pipeline] probe done ({} evals, full loss {:.4})",
        probe_model.evals, sens.full_loss
    );
    let quant = (sens.full_cost / 4096).max(1);
    let dp = dp_rank_selection(&sens.candidates, sens.full_cost, quant)?;
    eprintln!(
        "[pipeline] DP: {} pareto states, chain of {}",
        dp.pareto.len(),
        dp.chain.profiles.len()
    );

    // --- Stage 4: consolidation over budget profiles -----------------------
    let budget_profiles = dp.chain.select(&rc.budgets, sens.full_cost as usize);
    let consolidated_stem = dir.join("student_kd");
    let (student, kd_losses) = if !fresh && ckpt::exists(&consolidated_stem) {
        eprintln!("[pipeline] reusing consolidated student");
        (ckpt::load(&consolidated_stem)?, Vec::new())
    } else {
        eprintln!("[pipeline] consolidating for {} steps", rc.consolidate_steps);
        let run = driver::consolidate(
            engine,
            student0.clone(),
            &teacher,
            &budget_profiles,
            &rc.alphas,
            &mut train_b,
            rc.consolidate_steps,
            rc.seed ^ 0x77,
            rc.log_every,
        )?;
        ckpt::save(&run.params, &consolidated_stem)?;
        (run.params, run.losses)
    };

    // --- Stage 5: evaluation across budgets ---------------------------------
    eprintln!("[pipeline] evaluating across {} budgets", rc.budgets.len());
    let mut budget_rows = Vec::new();
    for (beta, profile) in rc.budgets.iter().zip(&budget_profiles) {
        let before = driver::eval_student(engine, &student0, profile, &eval_batches)?;
        let after = driver::eval_student(engine, &student, profile, &eval_batches)?;
        eprintln!(
            "  budget {beta:.2}: ranks {:?}.. loss {before:.4} -> {after:.4}",
            &profile[..4.min(profile.len())]
        );
        budget_rows.push((*beta, profile.clone(), before, after));
    }

    Ok(PipelineOut {
        teacher,
        student,
        student_init: student0,
        chain: dp.chain,
        full_cost: sens.full_cost,
        budget_rows,
        pretrain_losses,
        kd_losses,
    })
}

/// `repro pipeline [--smoke] [--fresh] [--pretrain-steps N] ...`
pub fn run_cli(args: &Args) -> Result<()> {
    let rc = if args.flag("smoke") {
        RunConfig::smoke().with_args(args)?
    } else {
        RunConfig::default().with_args(args)?
    };
    let engine = Engine::new(crate::artifacts_dir()).context("engine init")?;
    let out = run(&engine, &rc, args.flag("fresh"))?;

    // Persist the budget table for figures/EXPERIMENTS.md.
    let rows: Vec<Value> = out
        .budget_rows
        .iter()
        .map(|(b, prof, before, after)| {
            json::obj(vec![
                ("budget", Value::Num(*b)),
                ("profile", json::arr_usize(prof)),
                ("loss_datasvd_init", Value::Num(*before)),
                ("loss_flexrank", Value::Num(*after)),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("full_cost", Value::Num(out.full_cost as f64)),
        (
            "pretrain_losses",
            json::arr_f64(&out.pretrain_losses.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        ),
        (
            "kd_losses",
            json::arr_f64(&out.kd_losses.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        ),
        ("budgets", Value::Arr(rows)),
    ]);
    let path = crate::results_dir().join("pipeline_summary.json");
    std::fs::write(&path, json::to_string(&doc))?;
    println!("pipeline complete -> {}", path.display());
    Ok(())
}

/// `repro profiles` — run stages 1–3 and write artifacts/profiles.json with
/// the DP profiles for the serving tiers (phase-2 AOT input).
pub fn write_profiles_cli(args: &Args) -> Result<()> {
    let rc = if args.flag("smoke") {
        RunConfig::smoke().with_args(args)?
    } else {
        RunConfig::default().with_args(args)?
    };
    let engine = Engine::new(crate::artifacts_dir())?;
    let cfg = engine.manifest.config.clone();
    let out = run(&engine, &rc, args.flag("fresh"))?;
    let tier_profiles = out.chain.select(&cfg.serve_tiers, out.full_cost as usize);
    let doc = json::obj(vec![(
        "tiers",
        Value::Arr(tier_profiles.iter().map(|p| json::arr_usize(p)).collect()),
    )]);
    let path = crate::artifacts_dir().join("profiles.json");
    std::fs::write(&path, json::to_string(&doc))?;
    println!(
        "wrote {} (run `make serve-artifacts` to re-lower serving forwards)",
        path.display()
    );
    Ok(())
}
