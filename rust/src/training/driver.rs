//! Training flows over the PJRT artifacts: teacher pretraining, calibration,
//! knowledge consolidation, and evaluation.
//!
//! Hot-loop layout (DESIGN.md §Perf): the frozen teacher parameters are
//! uploaded to device buffers **once**; per step only the step-varying
//! tensors (student params/opt-state from the previous step's outputs,
//! masks, tokens, step counter) cross the host boundary — outputs arrive as
//! one tuple buffer (xla_extension 0.5.1 doesn't untuple), so a per-step
//! host round-trip of the student state is unavoidable at this API level.

use anyhow::{ensure, Context, Result};

use crate::data::TokenBatcher;
use crate::flexrank::decompose::CovAccum;
use crate::flexrank::masks::{profile_to_masks, RankProfile};
use crate::flexrank::sensitivity::ProbeModel;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::runtime::{Engine, Tensor};

use super::params::ParamSet;
pub use super::TrainRun;

/// Pretrain the dense teacher (builds the "pretrained base model").
pub fn pretrain_teacher(
    engine: &Engine,
    init: ParamSet,
    batcher: &mut TokenBatcher,
    steps: usize,
    log_every: usize,
) -> Result<TrainRun> {
    let exe = engine.load("teacher_train_step")?;
    let spec = exe.spec.clone();
    let cfg = engine.manifest.config.clone();

    let mut p = init;
    let mut m = p.zeros_like();
    let mut v = p.zeros_like();
    let mut losses = Vec::with_capacity(steps);
    let n_params = p.map.len();

    for step in 0..steps {
        let tokens = Tensor::i32(
            vec![cfg.batch_train, cfg.seq_len + 1],
            batcher.next_batch(),
        );
        let mut inputs = p.ordered_for(&spec, 0)?;
        inputs.extend(m.ordered_for(&spec, 1)?);
        inputs.extend(v.ordered_for(&spec, 2)?);
        inputs.push(Tensor::scalar_f32((step + 1) as f32));
        inputs.push(tokens);
        let out = exe.run(&inputs)?;
        p = ParamSet::from_outputs(&spec, 0, &out, 0)?;
        m = ParamSet::from_outputs(&spec, 1, &out, n_params)?;
        v = ParamSet::from_outputs(&spec, 2, &out, 2 * n_params)?;
        let loss = out[3 * n_params].item_f32()?;
        losses.push(loss);
        if log_every > 0 && step % log_every == 0 {
            eprintln!("pretrain step {step}: loss {loss:.4}");
        }
    }
    Ok(TrainRun { params: p, losses })
}

/// Accumulate per-layer activation covariances over `batches` calibration
/// batches via the `teacher_acts` artifact (App. C.1 stage 1).
pub fn calibrate(
    engine: &Engine,
    teacher: &ParamSet,
    batcher: &mut TokenBatcher,
    batches: usize,
) -> Result<Vec<CovAccum>> {
    let exe = engine.load("teacher_acts")?;
    let spec = exe.spec.clone();
    let cfg = engine.manifest.config.clone();
    let n_layers = cfg.n_fact_layers();
    ensure!(
        spec.outputs.len() == 1 + n_layers,
        "teacher_acts outputs {} != 1+{n_layers}",
        spec.outputs.len()
    );

    // Covariance dims from the output specs (skip logits at index 0).
    let mut covs: Vec<CovAccum> = spec.outputs[1..]
        .iter()
        .map(|s| CovAccum::new(s.shape[0]))
        .collect();

    let tparams = teacher.ordered_for(&spec, 0)?;
    let rows_per_batch = cfg.batch_calib * cfg.seq_len;
    for _ in 0..batches {
        let tokens: Vec<i32> = batcher.next_batch()[..cfg.batch_calib * (cfg.seq_len + 1)]
            .chunks(cfg.seq_len + 1)
            .flat_map(|w| w[..cfg.seq_len].to_vec())
            .collect();
        let mut inputs = tparams.clone();
        inputs.push(Tensor::i32(vec![cfg.batch_calib, cfg.seq_len], tokens));
        let out = exe.run(&inputs)?;
        for (li, cov) in covs.iter_mut().enumerate() {
            let t = &out[1 + li];
            let n = cov.sigma.rows;
            cov.add_gram(&Mat::from_f32(n, n, t.as_f32()?), rows_per_batch);
        }
    }
    Ok(covs)
}

/// Evaluate the masked student's CE loss at a profile, averaged over
/// deterministic held-out batches.
pub fn eval_student(
    engine: &Engine,
    student: &ParamSet,
    profile: &RankProfile,
    eval_batches: &[Vec<i32>],
) -> Result<f64> {
    let exe = engine.load("student_eval")?;
    let spec = exe.spec.clone();
    let cfg = engine.manifest.config.clone();
    let masks = Tensor::f32(
        vec![cfg.n_blocks, 4, cfg.rank_full()],
        profile_to_masks(profile, cfg.rank_full()),
    );
    let sp = student.ordered_for(&spec, 0)?;
    let mut total = 0.0f64;
    for batch in eval_batches {
        let mut inputs = sp.clone();
        inputs.push(masks.clone());
        inputs.push(Tensor::i32(vec![cfg.batch_eval, cfg.seq_len + 1], batch.clone()));
        let out = exe.run(&inputs)?;
        total += out[0].item_f32()? as f64;
    }
    Ok(total / eval_batches.len().max(1) as f64)
}

/// Next-byte top-1 accuracy of the masked student (the repo's stand-in for
/// the paper's zero-shot commonsense accuracy — DESIGN.md §substitutions).
pub fn student_accuracy(
    engine: &Engine,
    student: &ParamSet,
    profile: &RankProfile,
    eval_batches: &[Vec<i32>],
) -> Result<f64> {
    let exe = engine.load("student_logits")?;
    let spec = exe.spec.clone();
    let cfg = engine.manifest.config.clone();
    let masks = Tensor::f32(
        vec![cfg.n_blocks, 4, cfg.rank_full()],
        profile_to_masks(profile, cfg.rank_full()),
    );
    let sp = student.ordered_for(&spec, 0)?;
    let (b, t, v) = (cfg.batch_eval, cfg.seq_len, cfg.vocab);
    let mut correct = 0usize;
    let mut total = 0usize;
    for batch in eval_batches {
        // eval batches are (b, t+1): inputs are [.., :t], targets [.., 1:].
        let mut x = Vec::with_capacity(b * t);
        for row in batch.chunks(t + 1) {
            x.extend_from_slice(&row[..t]);
        }
        let mut inputs = sp.clone();
        inputs.push(masks.clone());
        inputs.push(Tensor::i32(vec![b, t], x));
        let out = exe.run(&inputs)?;
        let lf = out[0].as_f32()?;
        for (ri, row) in batch.chunks(t + 1).enumerate() {
            for pos in 0..t {
                let logits = &lf[(ri * t + pos) * v..(ri * t + pos + 1) * v];
                let arg = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                total += 1;
                if arg as i32 == row[pos + 1] {
                    correct += 1;
                }
            }
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// ProbeModel over the PJRT student — powers DP sensitivity probing.
pub struct StudentProbe<'a> {
    pub engine: &'a Engine,
    pub student: &'a ParamSet,
    pub eval_batches: Vec<Vec<i32>>,
    pub evals: usize,
}

impl ProbeModel for StudentProbe<'_> {
    fn full_ranks(&self) -> Vec<usize> {
        let cfg = &self.engine.manifest.config;
        vec![cfg.rank_full(); cfg.n_fact_layers()]
    }

    fn layer_dims(&self) -> Vec<(usize, usize)> {
        let cfg = &self.engine.manifest.config;
        super::params::fact_layers(cfg)
            .into_iter()
            .map(|(_, _, n, m)| (n, m))
            .collect()
    }

    fn eval(&mut self, profile: &RankProfile) -> f64 {
        self.evals += 1;
        eval_student(self.engine, self.student, profile, &self.eval_batches)
            .expect("student probe eval failed")
    }
}

/// Knowledge consolidation (Alg. 1 lines 14–17): sample a profile ∝ alphas
/// each step, run the fused KD train step.  Teacher params are device-
/// resident for the whole run.
#[allow(clippy::too_many_arguments)]
pub fn consolidate(
    engine: &Engine,
    student: ParamSet,
    teacher: &ParamSet,
    profiles: &[RankProfile],
    alphas: &[f64],
    batcher: &mut TokenBatcher,
    steps: usize,
    seed: u64,
    log_every: usize,
) -> Result<TrainRun> {
    ensure!(profiles.len() == alphas.len() && !profiles.is_empty(), "bad profiles/alphas");
    let exe = engine.load("kd_train_step")?;
    let spec = exe.spec.clone();
    let cfg = engine.manifest.config.clone();
    let mut rng = Rng::new(seed);

    // Teacher stays on device for the whole run.
    let teacher_host = teacher.ordered_for(&spec, 4)?;
    let teacher_bufs = engine.to_device_all(&teacher_host)?;

    // Pre-build mask tensors per profile.
    let mask_tensors: Vec<Tensor> = profiles
        .iter()
        .map(|p| {
            Tensor::f32(
                vec![cfg.n_blocks, 4, cfg.rank_full()],
                profile_to_masks(p, cfg.rank_full()),
            )
        })
        .collect();

    // §Perf: the train step echoes (params, m, v) in its input order, so the
    // student state cycles as raw literals — no per-step Tensor conversions
    // or name matching on the hot path (before/after in EXPERIMENTS.md).
    let n_params = student.map.len();
    let mut state_lits: Vec<xla::Literal> = Vec::with_capacity(3 * n_params);
    for t in student.ordered_for(&spec, 0)? {
        state_lits.push(t.to_literal()?);
    }
    let zeros = student.zeros_like();
    for arg in [1usize, 2] {
        for t in zeros.ordered_for(&spec, arg)? {
            state_lits.push(t.to_literal()?);
        }
    }

    let mut losses = Vec::with_capacity(steps);
    let t_loop = std::time::Instant::now();
    for step in 0..steps {
        let pi = rng.weighted(alphas);
        let tokens = Tensor::i32(vec![cfg.batch_train, cfg.seq_len + 1], batcher.next_batch());

        // Upload step-varying inputs; reuse persistent teacher buffers.
        let mut bufs = Vec::with_capacity(spec.inputs.len());
        for lit in state_lits.drain(..) {
            bufs.push(engine.literal_to_device(lit)?);
        }
        bufs.push(engine.to_device(&Tensor::scalar_f32((step + 1) as f32))?);
        let masks_buf = engine.to_device(&mask_tensors[pi])?;
        let tokens_buf = engine.to_device(&tokens)?;
        let mut refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|d| d.buffer()).collect();
        refs.extend(teacher_bufs.iter().map(|d| d.buffer()));
        refs.push(masks_buf.buffer());
        refs.push(tokens_buf.buffer());

        let mut out_lits = exe.run_b(&refs).context("kd step")?;
        let loss_lit = out_lits.pop().expect("loss output");
        let loss = Tensor::from_literal(&loss_lit)?.item_f32()?;
        state_lits = out_lits; // (params', m', v') cycle back verbatim
        losses.push(loss);
        if log_every > 0 && step % log_every == 0 {
            eprintln!("consolidate step {step}: profile {pi} kd-loss {loss:.5}");
        }
    }
    if steps > 0 {
        eprintln!(
            "[consolidate] {:.2} steps/s ({} steps)",
            steps as f64 / t_loop.elapsed().as_secs_f64(),
            steps
        );
    }

    // Materialize the final parameter set from the cycled literals.
    let out: Vec<Tensor> = state_lits
        .iter()
        .take(n_params)
        .map(Tensor::from_literal)
        .collect::<Result<Vec<_>>>()?;
    let p = ParamSet::from_outputs(&spec, 0, &out, 0)?;
    Ok(TrainRun { params: p, losses })
}
