//! Parameter-tree plumbing between rust and the AOT artifacts.
//!
//! jax flattens dict pytrees in sorted-key order; the manifest records the
//! exact flattened names per artifact (e.g. `0.blocks.2.qkv_u` for the first
//! argument's tree).  This module holds named parameter sets and assembles
//! ordered input vectors for any artifact by name matching — rust never
//! re-derives jax's ordering.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Result};

use crate::flexrank::decompose::{CovAccum, DataSvd};
use crate::flexrank::gar::gar_solve;
use crate::linalg::Mat;
use crate::runtime::{ArtifactSpec, ModelConfig, Tensor};

/// A named set of tensors (one model's parameters).
#[derive(Debug, Clone, Default)]
pub struct ParamSet {
    pub map: BTreeMap<String, Tensor>,
}

impl ParamSet {
    /// Build from parallel name/tensor lists.
    pub fn from_named(names: &[String], tensors: Vec<Tensor>) -> Self {
        assert_eq!(names.len(), tensors.len());
        ParamSet { map: names.iter().cloned().zip(tensors).collect() }
    }

    /// Build from the manifest's teacher_init spec + blob tensors.
    pub fn from_specs(specs: &[crate::runtime::TensorSpec], tensors: Vec<Tensor>) -> Self {
        let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        Self::from_named(&names, tensors)
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("param '{name}' missing"))
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    /// Matrix view of an f32 2-D param.
    pub fn mat(&self, name: &str) -> Result<Mat> {
        let t = self.get(name)?;
        let sh = t.shape();
        ensure!(sh.len() == 2, "param '{name}' not 2-D: {sh:?}");
        Ok(Mat::from_f32(sh[0], sh[1], t.as_f32()?))
    }

    /// Total f32 element count.
    pub fn numel(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Content fingerprint over every parameter's name, shape, and exact
    /// value bits (FNV-1a 64).  Deterministic across runs and checkpoint
    /// round trips (ckpt save/load is byte-exact); any retrained parameter
    /// — even one with identical shapes — flips it.  Recorded as
    /// `params_fp` in profiles.json so serving can detect DP profiles
    /// probed on a different student (`coordinator::load_tier_profiles`).
    pub fn content_fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        for (name, t) in &self.map {
            eat(name.as_bytes());
            for &dim in t.shape() {
                eat(&(dim as u64).to_le_bytes());
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for v in data {
                        eat(&v.to_bits().to_le_bytes());
                    }
                }
                Tensor::I32 { data, .. } => {
                    for v in data {
                        eat(&v.to_le_bytes());
                    }
                }
            }
        }
        h
    }

    /// Ordered inputs for artifact argument `arg_idx`, matched by name.
    /// Spec names look like `"{arg_idx}.{param_path}"`; scalar/plain args
    /// have just `"{arg_idx}"`.
    pub fn ordered_for(&self, spec: &ArtifactSpec, arg_idx: usize) -> Result<Vec<Tensor>> {
        let prefix = format!("{arg_idx}.");
        let mut out = Vec::new();
        for inp in &spec.inputs {
            if let Some(rest) = inp.name.strip_prefix(&prefix) {
                let t = self
                    .map
                    .get(rest)
                    .ok_or_else(|| anyhow!("{}: missing param '{rest}'", spec.name))?;
                ensure!(
                    t.shape() == inp.shape.as_slice(),
                    "{}: param '{rest}' shape {:?} != spec {:?}",
                    spec.name,
                    t.shape(),
                    inp.shape
                );
                out.push(t.clone());
            }
        }
        if out.is_empty() {
            bail!("{}: no inputs under arg {arg_idx}", spec.name);
        }
        Ok(out)
    }

    /// Rebuild a ParamSet from artifact *outputs* `[lo, lo+n)` given the
    /// naming of input arg `arg_idx` (train steps echo the param tree).
    pub fn from_outputs(
        spec: &ArtifactSpec,
        arg_idx: usize,
        outputs: &[Tensor],
        out_lo: usize,
    ) -> Result<ParamSet> {
        let prefix = format!("{arg_idx}.");
        let names: Vec<String> = spec
            .inputs
            .iter()
            .filter_map(|i| i.name.strip_prefix(&prefix).map(String::from))
            .collect();
        ensure!(
            out_lo + names.len() <= outputs.len(),
            "{}: outputs too short",
            spec.name
        );
        Ok(ParamSet {
            map: names
                .iter()
                .cloned()
                .zip(outputs[out_lo..out_lo + names.len()].iter().cloned())
                .collect(),
        })
    }

    /// All-zeros clone (optimizer-state init).
    pub fn zeros_like(&self) -> ParamSet {
        ParamSet {
            map: self
                .map
                .iter()
                .map(|(k, t)| (k.clone(), Tensor::zeros(t.shape())))
                .collect(),
        }
    }
}

/// The four factorized-layer kinds per block, canonical order (matches
/// python's `LAYER_KINDS`).
pub const LAYER_KINDS: [&str; 4] = ["qkv", "proj", "fc", "fcp"];

/// Randomly initialized dense teacher — the rust mirror of python's
/// `init_teacher` (GPT-2-style N(0, 0.02), residual projections scaled by
/// `1/√(2L)`).  Lets the native serving/bench stack bootstrap without AOT
/// artifacts or checkpoints.
pub fn random_teacher(cfg: &ModelConfig, seed: u64) -> ParamSet {
    let mut rng = crate::rng::Rng::new(seed);
    let d = cfg.d_model;
    let f = 4 * d;
    let std = 0.02f32;
    let resid_std = std / ((2 * cfg.n_blocks) as f32).sqrt();
    let mut p = ParamSet::default();
    let nrm = |rng: &mut crate::rng::Rng, shape: Vec<usize>, s: f32| {
        let n: usize = shape.iter().product();
        Tensor::f32(shape, rng.normal_vec(n, s))
    };
    p.insert("tok_emb", nrm(&mut rng, vec![cfg.vocab, d], std));
    p.insert("pos_emb", nrm(&mut rng, vec![cfg.seq_len, d], std));
    p.insert("lnf_g", Tensor::f32(vec![d], vec![1.0; d]));
    p.insert("lnf_b", Tensor::f32(vec![d], vec![0.0; d]));
    for b in 0..cfg.n_blocks {
        for g in ["ln1_g", "ln2_g"] {
            p.insert(&format!("blocks.{b}.{g}"), Tensor::f32(vec![d], vec![1.0; d]));
        }
        for g in ["ln1_b", "ln2_b"] {
            p.insert(&format!("blocks.{b}.{g}"), Tensor::f32(vec![d], vec![0.0; d]));
        }
        for (kind, n_in, m_out, s) in [
            ("qkv", d, 3 * d, std),
            ("proj", d, d, resid_std),
            ("fc", d, f, std),
            ("fcp", f, d, resid_std),
        ] {
            p.insert(&format!("blocks.{b}.{kind}_w"), nrm(&mut rng, vec![n_in, m_out], s));
            p.insert(&format!("blocks.{b}.{kind}_b"), Tensor::f32(vec![m_out], vec![0.0; m_out]));
        }
    }
    p
}

/// Canonical factorized-layer list: (block, kind, n_in, m_out).
pub fn fact_layers(cfg: &ModelConfig) -> Vec<(usize, &'static str, usize, usize)> {
    let dims = cfg.layer_dims();
    let mut out = Vec::with_capacity(cfg.n_fact_layers());
    for b in 0..cfg.n_blocks {
        for &(kind, n, m) in &dims {
            out.push((b, kind, n, m));
        }
    }
    out
}

/// Build student params from teacher params + per-layer DataSVD factors
/// (canonical layer order).  Copies embeddings/LN/biases, replaces each
/// `{kind}_w` with `{kind}_u` / `{kind}_v`.
pub fn student_from_factors(
    cfg: &ModelConfig,
    teacher: &ParamSet,
    factors: &[(Mat, Mat)],
) -> Result<ParamSet> {
    ensure!(factors.len() == cfg.n_fact_layers(), "factor count mismatch");
    let mut out = ParamSet::default();
    for name in ["tok_emb", "pos_emb", "lnf_g", "lnf_b"] {
        out.insert(name, teacher.get(name)?.clone());
    }
    let r = cfg.rank_full();
    for (li, (b, kind, n, m)) in fact_layers(cfg).into_iter().enumerate() {
        let (u, v) = &factors[li];
        ensure!(u.rows == m && v.rows == n, "factor dims for {kind} wrong");
        let uc = u.slice_cols(0, r.min(u.cols));
        let vc = v.slice_cols(0, r.min(v.cols));
        out.insert(
            &format!("blocks.{b}.{kind}_u"),
            Tensor::f32(vec![m, r], pad_cols_f32(&uc, r)),
        );
        out.insert(
            &format!("blocks.{b}.{kind}_v"),
            Tensor::f32(vec![n, r], pad_cols_f32(&vc, r)),
        );
        out.insert(
            &format!("blocks.{b}.{kind}_b"),
            teacher.get(&format!("blocks.{b}.{kind}_b"))?.clone(),
        );
    }
    for b in 0..cfg.n_blocks {
        for g in ["ln1_g", "ln1_b", "ln2_g", "ln2_b"] {
            out.insert(
                &format!("blocks.{b}.{g}"),
                teacher.get(&format!("blocks.{b}.{g}"))?.clone(),
            );
        }
    }
    Ok(out)
}

fn pad_cols_f32(m: &Mat, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; m.rows * cols];
    for i in 0..m.rows {
        for j in 0..m.cols.min(cols) {
            out[i * cols + j] = m[(i, j)] as f32;
        }
    }
    out
}

/// DataSVD-decompose every factorized layer of a teacher.
/// `covs` are per-layer covariance accumulators (canonical order); pass
/// `None` for plain weight-SVD (the "SVD" baseline).
pub fn decompose_teacher(
    cfg: &ModelConfig,
    teacher: &ParamSet,
    covs: Option<&[CovAccum]>,
) -> Result<Vec<(Mat, Mat)>> {
    let mut out = Vec::with_capacity(cfg.n_fact_layers());
    for (li, (b, kind, _n, _m)) in fact_layers(cfg).into_iter().enumerate() {
        let w = teacher.mat(&format!("blocks.{b}.{kind}_w"))?; // (n, m) row conv
        let d = match covs {
            Some(cs) => DataSvd::compute(&w, &cs[li], 1e-7),
            None => DataSvd::compute_plain(&w),
        };
        out.push((d.u, d.v));
    }
    Ok(out)
}

/// Build the GAR flat parameter list for a serving artifact at `profile`
/// from student params (Sec. 3.5 — gauge per layer, identity block first).
pub fn gar_params_for(
    cfg: &ModelConfig,
    student: &ParamSet,
    spec: &ArtifactSpec,
) -> Result<Vec<Tensor>> {
    let profile = spec
        .profile
        .as_ref()
        .ok_or_else(|| anyhow!("{} has no profile", spec.name))?;
    ensure!(profile.len() == cfg.n_fact_layers(), "profile length mismatch");

    let mut named = ParamSet::default();
    for name in ["tok_emb", "pos_emb", "lnf_g", "lnf_b"] {
        named.insert(name, student.get(name)?.clone());
    }
    for (li, (b, kind, n, m)) in fact_layers(cfg).into_iter().enumerate() {
        let r = profile[li];
        let u = student.mat(&format!("blocks.{b}.{kind}_u"))?;
        let v = student.mat(&format!("blocks.{b}.{kind}_v"))?;
        let gar = gar_solve(&u, &v, r)?;
        if m - r > 0 {
            // Full-rank square layers have an empty Û — the artifact does not
            // declare the zero-size arg (see gar_param_spec in model.py).
            named.insert(
                &format!("b{b}.{kind}_uhat"),
                Tensor::f32(vec![m - r, r], gar.u_hat.to_f32()),
            );
        }
        named.insert(
            &format!("b{b}.{kind}_vt"),
            Tensor::f32(vec![n, r], gar.v_tilde.to_f32()),
        );
        named.insert(
            &format!("b{b}.{kind}_b"),
            student.get(&format!("blocks.{b}.{kind}_b"))?.clone(),
        );
    }
    for b in 0..cfg.n_blocks {
        for g in ["ln1_g", "ln1_b", "ln2_g", "ln2_b"] {
            named.insert(&format!("b{b}.{g}"), student.get(&format!("blocks.{b}.{g}"))?.clone());
        }
    }

    // Order per the artifact's arg-0 spec (names are "0.<idx>" for a flat
    // list input — match by *shape-compatible sequence* instead: gar specs
    // are lowered from a plain list, so names are "0.0", "0.1", ...  We
    // reconstruct the canonical order from gar_param_spec's known layout.)
    let mut ordered: Vec<Tensor> = Vec::new();
    let push = |ordered: &mut Vec<Tensor>, t: &Tensor| ordered.push(t.clone());
    push(&mut ordered, named.get("tok_emb")?);
    push(&mut ordered, named.get("pos_emb")?);
    push(&mut ordered, named.get("lnf_g")?);
    push(&mut ordered, named.get("lnf_b")?);
    for b in 0..cfg.n_blocks {
        for g in ["ln1_g", "ln1_b", "ln2_g", "ln2_b"] {
            push(&mut ordered, named.get(&format!("b{b}.{g}"))?);
        }
        for kind in LAYER_KINDS {
            if let Ok(uhat) = named.get(&format!("b{b}.{kind}_uhat")) {
                push(&mut ordered, uhat);
            }
            push(&mut ordered, named.get(&format!("b{b}.{kind}_vt"))?);
            push(&mut ordered, named.get(&format!("b{b}.{kind}_b"))?);
        }
    }
    // Validate against the spec's leading shapes (arg 0 count = ordered len).
    for (t, s) in ordered.iter().zip(&spec.inputs) {
        ensure!(
            t.shape() == s.shape.as_slice(),
            "{}: gar param '{}' shape {:?} != spec {:?}",
            spec.name,
            s.name,
            t.shape(),
            s.shape
        );
    }
    Ok(ordered)
}

/// Stored bytes of one tier's factor set at `profile` / `prec` — the
/// shape-only view of per-tier precision that the serving registry realizes
/// via [`crate::linalg::quant::QuantMat`].  Counts `û (m−r × r)` and
/// `Ṽ (n × r)` per factorized layer at the precision's element width; i8
/// adds the 4-byte per-column scale of each stored factor.
pub fn quantized_profile_bytes(
    cfg: &ModelConfig,
    profile: &[usize],
    prec: crate::linalg::quant::Precision,
) -> usize {
    let scale_bytes = match prec {
        crate::linalg::quant::Precision::I8 => 4,
        _ => 0,
    };
    fact_layers(cfg)
        .into_iter()
        .zip(profile)
        .map(|((_, _, n, m), &r)| {
            let r = r.clamp(1, n.min(m));
            ((m - r) * r + n * r) * prec.bytes_per_elem() + 2 * r * scale_bytes
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_fingerprint_is_stable_and_flips_on_any_change() {
        let mut ps = ParamSet::default();
        ps.insert("w", Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        ps.insert("b", Tensor::f32(vec![2], vec![0.5, -0.5]));
        let fp = ps.content_fingerprint();
        assert_eq!(fp, ps.content_fingerprint(), "fingerprint must be deterministic");
        assert_eq!(fp, ps.clone().content_fingerprint(), "fingerprint survives a copy");

        // A retrained value with identical shapes flips it — the case the
        // full_cost dimensional check cannot see.
        let mut retrained = ps.clone();
        retrained.map.get_mut("w").unwrap().as_f32_mut().unwrap()[3] = 4.0 + 1e-6;
        assert_ne!(fp, retrained.content_fingerprint(), "value change must flip params_fp");

        // Same values under a different name flip it too.
        let mut renamed = ParamSet::default();
        renamed.insert("w2", Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        renamed.insert("b", Tensor::f32(vec![2], vec![0.5, -0.5]));
        assert_ne!(fp, renamed.content_fingerprint());

        // And a reshape of the same flat data flips it.
        let mut reshaped = ParamSet::default();
        reshaped.insert("w", Tensor::f32(vec![4, 1], vec![1.0, 2.0, 3.0, 4.0]));
        reshaped.insert("b", Tensor::f32(vec![2], vec![0.5, -0.5]));
        assert_ne!(fp, reshaped.content_fingerprint());
    }

    #[test]
    fn quantized_profile_bytes_orders_precisions() {
        use crate::linalg::quant::Precision;
        let cfg = crate::config::load_model_config("tiny").expect("configs/model_tiny.json");
        let profile = vec![3usize; cfg.n_fact_layers()];
        let f32b = quantized_profile_bytes(&cfg, &profile, Precision::F32);
        let bf16b = quantized_profile_bytes(&cfg, &profile, Precision::Bf16);
        let i8b = quantized_profile_bytes(&cfg, &profile, Precision::I8);
        assert_eq!(f32b, 2 * bf16b, "bf16 halves factor traffic exactly");
        assert!(
            i8b < bf16b && bf16b < f32b,
            "per-tier bytes must order i8 < bf16 < f32: {i8b} {bf16b} {f32b}"
        );
        // The shape-only count matches what the registry actually stores.
        let elems: usize = fact_layers(&cfg)
            .into_iter()
            .zip(&profile)
            .map(|((_, _, n, m), &r)| (m - r) * r + n * r)
            .sum();
        assert_eq!(f32b, elems * 4);
    }
}
