//! One-sided Jacobi SVD: A = U diag(s) Vᵀ with singular values sorted
//! descending.  The workhorse of DataSVD (Sec. 3.1) and every SVD baseline.

use super::Mat;

/// SVD result: `a ≈ u * diag(s) * vt` with `u: m×k`, `s: k`, `vt: k×n`,
/// `k = min(m, n)`.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub vt: Mat,
}

impl Svd {
    /// Rank-r truncation `A_r = Σ_{i<r} s_i u_i v_iᵀ` (Eckart–Young optimum).
    pub fn truncate(&self, r: usize) -> Mat {
        let r = r.min(self.s.len());
        let ur = self.u.slice_cols(0, r);
        let mut svt = self.vt.slice_rows(0, r);
        for i in 0..r {
            for j in 0..svt.cols {
                svt[(i, j)] *= self.s[i];
            }
        }
        &ur * &svt
    }

    /// Paper-form factors `U = P Σ^{1/2}` (m×k), `V = Q Σ^{1/2}` (n×k) so
    /// that `A = U Vᵀ` with components ordered by importance.
    pub fn balanced_factors(&self) -> (Mat, Mat) {
        let k = self.s.len();
        let mut u = self.u.clone();
        let mut v = self.vt.t();
        for i in 0..k {
            let sh = self.s[i].max(0.0).sqrt();
            u.scale_col(i, sh);
            v.scale_col(i, sh);
        }
        (u, v)
    }
}

/// One-sided Jacobi SVD.  Orthogonalizes columns of a working copy of A by
/// Givens rotations until convergence; column norms become singular values.
pub fn svd(a: &Mat) -> Svd {
    // Work on the transposed problem when m < n so the iteration always sees
    // columns of the tall matrix.
    if a.rows < a.cols {
        let s = svd(&a.t());
        return Svd { u: s.vt.t(), s: s.s, vt: s.u.t() };
    }

    let m = a.rows;
    let n = a.cols;
    let mut u = a.clone(); // m×n, columns will become s_j * u_j
    let mut v = Mat::eye(n);

    let eps = 1e-12;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 Gram entries.
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() + 1e-300 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation that annihilates the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // Extract singular values = column norms; normalize U columns.
    let mut svals: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm = (0..m).map(|i| u[(i, j)] * u[(i, j)]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    svals.sort_by(|a, b| b.0.total_cmp(&a.0));

    let mut uu = Mat::zeros(m, n);
    let mut vv = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (dst, &(norm, j)) in svals.iter().enumerate() {
        s.push(norm);
        let inv = if norm > 1e-300 { 1.0 / norm } else { 0.0 };
        for i in 0..m {
            uu[(i, dst)] = u[(i, j)] * inv;
        }
        for i in 0..n {
            vv[(i, dst)] = v[(i, j)];
        }
    }
    Svd { u: uu, s, vt: vv.t() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::rng::Rng;

    fn check_svd(a: &Mat, tol: f64) -> Result<(), String> {
        let d = svd(a);
        let k = a.rows.min(a.cols);
        // Reconstruction.
        let recon = d.truncate(k);
        if !recon.close_to(a, tol) {
            return Err(format!("reconstruction err {}", recon.frob_dist(a)));
        }
        // Orthonormality.
        let utu = &d.u.t() * &d.u;
        if !utu.close_to(&Mat::eye(k), 1e-7) {
            return Err("U not orthonormal".into());
        }
        let vvt = &d.vt * &d.vt.t();
        if !vvt.close_to(&Mat::eye(k), 1e-7) {
            return Err("V not orthonormal".into());
        }
        // Descending s.
        if !d.s.windows(2).all(|w| w[0] >= w[1] - 1e-12) {
            return Err("singular values not sorted".into());
        }
        Ok(())
    }

    #[test]
    fn svd_tall_square_wide() {
        let mut rng = Rng::new(8);
        for (m, n) in [(10, 4), (6, 6), (4, 10)] {
            let a = Mat::randn(m, n, &mut rng);
            check_svd(&a, 1e-8).unwrap();
        }
    }

    #[test]
    fn truncation_is_eckart_young() {
        // For known singular values, truncation error² = sum of dropped s².
        let mut rng = Rng::new(9);
        let sv = vec![4.0, 2.0, 1.0, 0.5];
        let a = Mat::with_singular_values(8, 6, &sv, &mut rng);
        let d = svd(&a);
        for r in 0..4 {
            let err = d.truncate(r).frob_dist(&a);
            let want = sv[r..].iter().map(|s| s * s).sum::<f64>().sqrt();
            assert!((err - want).abs() < 1e-7, "r={r}: {err} vs {want}");
        }
    }

    #[test]
    fn balanced_factors_multiply_back() {
        let mut rng = Rng::new(10);
        let a = Mat::randn(7, 5, &mut rng);
        let d = svd(&a);
        let (u, v) = d.balanced_factors();
        assert!((&u * &v.t()).close_to(&a, 1e-8));
    }

    #[test]
    fn property_svd_random_shapes() {
        prop::forall(
            21,
            15,
            |r| {
                let m = prop::gen::dim(r, 1, 24);
                let n = prop::gen::dim(r, 1, 24);
                Mat::randn(m, n, r)
            },
            |a| check_svd(a, 1e-7),
        );
    }

    #[test]
    fn property_rank_deficient() {
        prop::forall(
            22,
            10,
            |r| {
                let m = prop::gen::dim(r, 3, 16);
                let n = prop::gen::dim(r, 3, 16);
                let k = prop::gen::dim(r, 1, m.min(n));
                let b = Mat::randn(m, k, r);
                let c = Mat::randn(k, n, r);
                (&b * &c, k)
            },
            |(a, k)| {
                let d = svd(a);
                // All singular values beyond rank k must be ~0.
                for (i, s) in d.s.iter().enumerate().skip(*k) {
                    if *s > 1e-6 * d.s[0].max(1.0) {
                        return Err(format!("s[{i}]={s} nonzero beyond rank {k}"));
                    }
                }
                // Full orthonormality does not hold for the zero-sv columns
                // (they are left as zero vectors); reconstruction must still
                // be exact and the rank-k truncation must match A.
                let recon = d.truncate(*k);
                if !recon.close_to(a, 1e-6) {
                    return Err(format!("rank-k reconstruction err {}", recon.frob_dist(a)));
                }
                Ok(())
            },
        );
    }
}
