//! Runtime-dispatched SIMD micro-kernels for the f32 hot paths.
//!
//! Dispatch tiers:
//!
//! * **x86_64 AVX2+FMA** — 8-lane f32 vectors with fused multiply-add for
//!   the dot/axpy micro-kernels plus a vectorized polynomial `exp` for the
//!   softmax row loops.
//! * **aarch64 NEON** — 4-lane f32 dot/axpy micro-kernels plus the same
//!   polynomial-`exp` softmax helpers at NEON width.
//! * **scalar** — the pre-SIMD loops, kept verbatim as the oracle the
//!   `simd ≡ scalar` property tests compare against.
//!
//! The active tier is detected once per process and cached;
//! `FLEXRANK_SIMD=scalar` pins the scalar fallback regardless of hardware
//! (the CI matrix runs a scalar-forced job).  [`crate::linalg::pool`]
//! resolves the dispatch at worker-pool init so the first hot call never
//! pays the detection, and [`isa_label`] is the capability string the
//! `repro` binary and the serving bench report.
//!
//! The f64 micro-kernels intentionally stay scalar: the 1e-10
//! `kernels ≡ reference` property suite pins their exact summation order.

use std::sync::OnceLock;

/// Instruction-set tier the f32 micro-kernels dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// x86_64 with AVX2 + FMA (8 × f32 lanes).
    Avx2Fma,
    /// aarch64 with NEON (4 × f32 lanes).
    Neon,
    /// Portable scalar fallback — identical to the pre-SIMD kernels.
    Scalar,
}

static ISA: OnceLock<Isa> = OnceLock::new();

/// The dispatch tier, detected once per process.  `FLEXRANK_SIMD=scalar`
/// forces the scalar fallback regardless of hardware.
pub fn isa() -> Isa {
    *ISA.get_or_init(|| {
        if std::env::var("FLEXRANK_SIMD").as_deref() == Ok("scalar") {
            return Isa::Scalar;
        }
        detect()
    })
}

fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Isa::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Scalar
}

/// Human-readable capability label for startup banners and bench output.
pub fn isa_label() -> &'static str {
    match isa() {
        Isa::Avx2Fma => "x86_64/avx2+fma",
        Isa::Neon => "aarch64/neon",
        Isa::Scalar => "scalar",
    }
}

// ---------------------------------------------------------------------------
// Scalar micro-kernels (f64 always; f32 as the dispatch fallback + oracle).
// ---------------------------------------------------------------------------

macro_rules! scalar_micro {
    ($ty:ty, $dot:ident, $axpy4:ident) => {
        /// Four-accumulator dot product (scalar).
        #[inline]
        pub fn $dot(a: &[$ty], b: &[$ty]) -> $ty {
            debug_assert_eq!(a.len(), b.len());
            let n4 = a.len() & !3;
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            let mut i = 0;
            while i < n4 {
                s0 += a[i] * b[i];
                s1 += a[i + 1] * b[i + 1];
                s2 += a[i + 2] * b[i + 2];
                s3 += a[i + 3] * b[i + 3];
                i += 4;
            }
            let mut s = (s0 + s1) + (s2 + s3);
            while i < a.len() {
                s += a[i] * b[i];
                i += 1;
            }
            s
        }

        /// Micro-kernel: `orow += Σ_kk aseg[kk] · b_panel_row(kk)`, four B
        /// rows per pass (scalar).  The k-tail is branchless so the FLOP
        /// count is shape-only, matching the SIMD tails exactly.
        #[inline]
        pub fn $axpy4(aseg: &[$ty], b_panel: &[$ty], n: usize, orow: &mut [$ty]) {
            debug_assert_eq!(b_panel.len(), aseg.len() * n);
            debug_assert_eq!(orow.len(), n);
            let k4 = aseg.len() & !3;
            let mut kk = 0;
            while kk < k4 {
                let a0 = aseg[kk];
                let a1 = aseg[kk + 1];
                let a2 = aseg[kk + 2];
                let a3 = aseg[kk + 3];
                let b0 = &b_panel[kk * n..kk * n + n];
                let b1 = &b_panel[(kk + 1) * n..(kk + 1) * n + n];
                let b2 = &b_panel[(kk + 2) * n..(kk + 2) * n + n];
                let b3 = &b_panel[(kk + 3) * n..(kk + 3) * n + n];
                for ((((o, v0), v1), v2), v3) in
                    orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                {
                    *o += a0 * *v0 + a1 * *v1 + a2 * *v2 + a3 * *v3;
                }
                kk += 4;
            }
            while kk < aseg.len() {
                let av = aseg[kk];
                let brow = &b_panel[kk * n..kk * n + n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
                kk += 1;
            }
        }
    };
}

scalar_micro!(f64, dot_f64, axpy4_f64);
scalar_micro!(f32, dot_f32_scalar, axpy4_f32_scalar);

// ---------------------------------------------------------------------------
// Dispatched f32 micro-kernels.
// ---------------------------------------------------------------------------

/// Dispatched f32 dot product.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    match isa() {
        // SAFETY: `isa()` only ever returns an ISA tier after the one-time
        // runtime probe confirmed the CPU supports it, which is exactly the
        // caller contract of these `#[target_feature]` kernels; the kernels
        // take slices and handle bounds/tails internally.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot(a, b) },
        _ => dot_f32_scalar(a, b),
    }
}

/// Dispatched f32 axpy micro-kernel (four B rows per pass).
#[inline]
pub fn axpy4_f32(aseg: &[f32], b_panel: &[f32], n: usize, orow: &mut [f32]) {
    match isa() {
        // SAFETY: `isa()` only ever returns an ISA tier after the one-time
        // runtime probe confirmed the CPU supports it, which is exactly the
        // caller contract of these `#[target_feature]` kernels; the kernels
        // take slices and handle bounds/tails internally.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::axpy4(aseg, b_panel, n, orow) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::axpy4(aseg, b_panel, n, orow) },
        _ => axpy4_f32_scalar(aseg, b_panel, n, orow),
    }
}

// ---------------------------------------------------------------------------
// Softmax row helpers (dispatched).  Scalar bodies are verbatim the loops
// the attention paths ran before SIMD dispatch existed, so the scalar tier
// reproduces the legacy numerics bit for bit.
// ---------------------------------------------------------------------------

/// `row[i] *= scale`; returns the running max (−∞ for an empty row).
#[inline]
pub fn scale_max(row: &mut [f32], scale: f32) -> f32 {
    match isa() {
        // SAFETY: `isa()` only ever returns an ISA tier after the one-time
        // runtime probe confirmed the CPU supports it, which is exactly the
        // caller contract of these `#[target_feature]` kernels; the kernels
        // take slices and handle bounds/tails internally.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::scale_max(row, scale) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::scale_max(row, scale) },
        _ => scale_max_scalar(row, scale),
    }
}

#[inline]
pub fn scale_max_scalar(row: &mut [f32], scale: f32) -> f32 {
    let mut mx = f32::NEG_INFINITY;
    for s in row.iter_mut() {
        *s *= scale;
        if *s > mx {
            mx = *s;
        }
    }
    mx
}

/// `row[i] = exp(row[i] − mx)`; returns the sum.
#[inline]
pub fn exp_sub_sum(row: &mut [f32], mx: f32) -> f32 {
    match isa() {
        // SAFETY: `isa()` only ever returns an ISA tier after the one-time
        // runtime probe confirmed the CPU supports it, which is exactly the
        // caller contract of these `#[target_feature]` kernels; the kernels
        // take slices and handle bounds/tails internally.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::exp_sub_sum(row, mx) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::exp_sub_sum(row, mx) },
        _ => exp_sub_sum_scalar(row, mx),
    }
}

#[inline]
pub fn exp_sub_sum_scalar(row: &mut [f32], mx: f32) -> f32 {
    let mut sum = 0f32;
    for s in row.iter_mut() {
        *s = (*s - mx).exp();
        sum += *s;
    }
    sum
}

/// `row[i] *= c` (softmax normalization pass).
#[inline]
pub fn scale_in_place(row: &mut [f32], c: f32) {
    match isa() {
        // SAFETY: `isa()` only ever returns an ISA tier after the one-time
        // runtime probe confirmed the CPU supports it, which is exactly the
        // caller contract of these `#[target_feature]` kernels; the kernels
        // take slices and handle bounds/tails internally.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::scale_in_place(row, c) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::scale_in_place(row, c) },
        _ => scale_in_place_scalar(row, c),
    }
}

#[inline]
pub fn scale_in_place_scalar(row: &mut [f32], c: f32) {
    for s in row.iter_mut() {
        *s *= c;
    }
}

/// Online-softmax output rescale: `out[i] = out[i] * corr + add[i]`.
#[inline]
pub fn rescale_add(out: &mut [f32], add: &[f32], corr: f32) {
    match isa() {
        // SAFETY: `isa()` only ever returns an ISA tier after the one-time
        // runtime probe confirmed the CPU supports it, which is exactly the
        // caller contract of these `#[target_feature]` kernels; the kernels
        // take slices and handle bounds/tails internally.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::rescale_add(out, add, corr) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::rescale_add(out, add, corr) },
        _ => rescale_add_scalar(out, add, corr),
    }
}

#[inline]
pub fn rescale_add_scalar(out: &mut [f32], add: &[f32], corr: f32) {
    debug_assert_eq!(out.len(), add.len());
    for (o, &a) in out.iter_mut().zip(add) {
        *o = *o * corr + a;
    }
}

/// Streaming-backward probability recompute:
/// `row[i] = exp(row[i] * scale − mi) * inv_l`.
#[inline]
pub fn exp_recompute(row: &mut [f32], scale: f32, mi: f32, inv_l: f32) {
    match isa() {
        // SAFETY: `isa()` only ever returns an ISA tier after the one-time
        // runtime probe confirmed the CPU supports it, which is exactly the
        // caller contract of these `#[target_feature]` kernels; the kernels
        // take slices and handle bounds/tails internally.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::exp_recompute(row, scale, mi, inv_l) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::exp_recompute(row, scale, mi, inv_l) },
        _ => exp_recompute_scalar(row, scale, mi, inv_l),
    }
}

#[inline]
pub fn exp_recompute_scalar(row: &mut [f32], scale: f32, mi: f32, inv_l: f32) {
    for s in row.iter_mut() {
        *s = (*s * scale - mi).exp() * inv_l;
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA implementations (x86_64).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut t = [0f32; 8];
        _mm256_storeu_ps(t.as_mut_ptr(), v);
        ((t[0] + t[4]) + (t[1] + t[5])) + ((t[2] + t[6]) + (t[3] + t[7]))
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hmax(v: __m256) -> f32 {
        let mut t = [0f32; 8];
        _mm256_storeu_ps(t.as_mut_ptr(), v);
        t.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x))
    }

    /// Polynomial exp for 8 lanes: `2^n · P(r)` with `x = n·ln2 + r`,
    /// `|r| ≤ ln2/2`, degree-6 Taylor `P` (≈1e-7 relative error).  Inputs
    /// are clamped to the finite range; the softmax callers only pass
    /// `x ≤ 0`, where the clamp never fires.
    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp8(x: __m256) -> __m256 {
        let x = _mm256_min_ps(x, _mm256_set1_ps(88.0));
        let x = _mm256_max_ps(x, _mm256_set1_ps(-87.0));
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        let ln2_hi = _mm256_set1_ps(0.693_359_375);
        let ln2_lo = _mm256_set1_ps(-2.121_944_4e-4);
        // n = round-to-nearest(x · log2(e)) via the cvt rounding mode.
        let ni = _mm256_cvtps_epi32(_mm256_mul_ps(x, log2e));
        let nf = _mm256_cvtepi32_ps(ni);
        // r = x − n·ln2, split ln2 so the subtraction stays exact.
        let r = _mm256_fnmadd_ps(nf, ln2_hi, x);
        let r = _mm256_fnmadd_ps(nf, ln2_lo, r);
        // Horner over 1 + r + r²/2! + … + r⁶/6!.
        let mut p = _mm256_set1_ps(1.0 / 720.0);
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 120.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 24.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 6.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(0.5));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
        // Scale by 2^n through the exponent bits.
        let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32(
            _mm256_add_epi32(ni, _mm256_set1_epi32(127)),
            23,
        ));
        _mm256_mul_ps(p, pow2)
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 16)),
                _mm256_loadu_ps(pb.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 24)),
                _mm256_loadu_ps(pb.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
        while i < n {
            s += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        s
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy4(aseg: &[f32], b_panel: &[f32], n: usize, orow: &mut [f32]) {
        debug_assert_eq!(b_panel.len(), aseg.len() * n);
        debug_assert_eq!(orow.len(), n);
        let bp = b_panel.as_ptr();
        let op = orow.as_mut_ptr();
        let k4 = aseg.len() & !3;
        let mut kk = 0;
        while kk < k4 {
            let a0 = _mm256_set1_ps(aseg[kk]);
            let a1 = _mm256_set1_ps(aseg[kk + 1]);
            let a2 = _mm256_set1_ps(aseg[kk + 2]);
            let a3 = _mm256_set1_ps(aseg[kk + 3]);
            let b0 = bp.add(kk * n);
            let b1 = bp.add((kk + 1) * n);
            let b2 = bp.add((kk + 2) * n);
            let b3 = bp.add((kk + 3) * n);
            let mut j = 0;
            while j + 8 <= n {
                let mut o = _mm256_loadu_ps(op.add(j));
                o = _mm256_fmadd_ps(a0, _mm256_loadu_ps(b0.add(j)), o);
                o = _mm256_fmadd_ps(a1, _mm256_loadu_ps(b1.add(j)), o);
                o = _mm256_fmadd_ps(a2, _mm256_loadu_ps(b2.add(j)), o);
                o = _mm256_fmadd_ps(a3, _mm256_loadu_ps(b3.add(j)), o);
                _mm256_storeu_ps(op.add(j), o);
                j += 8;
            }
            while j < n {
                *op.add(j) += aseg[kk] * *b0.add(j)
                    + aseg[kk + 1] * *b1.add(j)
                    + aseg[kk + 2] * *b2.add(j)
                    + aseg[kk + 3] * *b3.add(j);
                j += 1;
            }
            kk += 4;
        }
        while kk < aseg.len() {
            let av = aseg[kk];
            let a0 = _mm256_set1_ps(av);
            let b0 = bp.add(kk * n);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_fmadd_ps(a0, _mm256_loadu_ps(b0.add(j)), _mm256_loadu_ps(op.add(j)));
                _mm256_storeu_ps(op.add(j), o);
                j += 8;
            }
            while j < n {
                *op.add(j) += av * *b0.add(j);
                j += 1;
            }
            kk += 1;
        }
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale_max(row: &mut [f32], scale: f32) -> f32 {
        let n = row.len();
        let p = row.as_mut_ptr();
        let sv = _mm256_set1_ps(scale);
        let mut mv = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), sv);
            _mm256_storeu_ps(p.add(i), v);
            mv = _mm256_max_ps(mv, v);
            i += 8;
        }
        let mut mx = hmax(mv);
        while i < n {
            let v = *p.add(i) * scale;
            *p.add(i) = v;
            if v > mx {
                mx = v;
            }
            i += 1;
        }
        mx
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp_sub_sum(row: &mut [f32], mx: f32) -> f32 {
        let n = row.len();
        let p = row.as_mut_ptr();
        let mv = _mm256_set1_ps(mx);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let e = exp8(_mm256_sub_ps(_mm256_loadu_ps(p.add(i)), mv));
            _mm256_storeu_ps(p.add(i), e);
            acc = _mm256_add_ps(acc, e);
            i += 8;
        }
        let mut sum = hsum(acc);
        while i < n {
            let e = (*p.add(i) - mx).exp();
            *p.add(i) = e;
            sum += e;
            i += 1;
        }
        sum
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale_in_place(row: &mut [f32], c: f32) {
        let n = row.len();
        let p = row.as_mut_ptr();
        let cv = _mm256_set1_ps(c);
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(p.add(i), _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), cv));
            i += 8;
        }
        while i < n {
            *p.add(i) *= c;
            i += 1;
        }
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn rescale_add(out: &mut [f32], add: &[f32], corr: f32) {
        debug_assert_eq!(out.len(), add.len());
        let n = out.len();
        let po = out.as_mut_ptr();
        let pa = add.as_ptr();
        let cv = _mm256_set1_ps(corr);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_fmadd_ps(_mm256_loadu_ps(po.add(i)), cv, _mm256_loadu_ps(pa.add(i)));
            _mm256_storeu_ps(po.add(i), v);
            i += 8;
        }
        while i < n {
            *po.add(i) = *po.add(i) * corr + *pa.add(i);
            i += 1;
        }
    }

    // SAFETY: callers must ensure AVX2+FMA are available (dispatch does,
    // via `isa()`); beyond that the body uses unaligned loads/stores on
    // in-bounds slice ranges only.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp_recompute(row: &mut [f32], scale: f32, mi: f32, inv_l: f32) {
        let n = row.len();
        let p = row.as_mut_ptr();
        let sv = _mm256_set1_ps(scale);
        let miv = _mm256_set1_ps(mi);
        let lv = _mm256_set1_ps(inv_l);
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_fmsub_ps(_mm256_loadu_ps(p.add(i)), sv, miv);
            _mm256_storeu_ps(p.add(i), _mm256_mul_ps(exp8(x), lv));
            i += 8;
        }
        while i < n {
            *p.add(i) = (*p.add(i) * scale - mi).exp() * inv_l;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON implementations (aarch64).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut acc2 = vdupq_n_f32(0.0);
        let mut acc3 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 16 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            acc2 = vfmaq_f32(acc2, vld1q_f32(pa.add(i + 8)), vld1q_f32(pb.add(i + 8)));
            acc3 = vfmaq_f32(acc3, vld1q_f32(pa.add(i + 12)), vld1q_f32(pb.add(i + 12)));
            i += 16;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
        while i < n {
            s += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        s
    }

    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy4(aseg: &[f32], b_panel: &[f32], n: usize, orow: &mut [f32]) {
        debug_assert_eq!(b_panel.len(), aseg.len() * n);
        debug_assert_eq!(orow.len(), n);
        let bp = b_panel.as_ptr();
        let op = orow.as_mut_ptr();
        let k4 = aseg.len() & !3;
        let mut kk = 0;
        while kk < k4 {
            let a0 = vdupq_n_f32(aseg[kk]);
            let a1 = vdupq_n_f32(aseg[kk + 1]);
            let a2 = vdupq_n_f32(aseg[kk + 2]);
            let a3 = vdupq_n_f32(aseg[kk + 3]);
            let b0 = bp.add(kk * n);
            let b1 = bp.add((kk + 1) * n);
            let b2 = bp.add((kk + 2) * n);
            let b3 = bp.add((kk + 3) * n);
            let mut j = 0;
            while j + 4 <= n {
                let mut o = vld1q_f32(op.add(j));
                o = vfmaq_f32(o, a0, vld1q_f32(b0.add(j)));
                o = vfmaq_f32(o, a1, vld1q_f32(b1.add(j)));
                o = vfmaq_f32(o, a2, vld1q_f32(b2.add(j)));
                o = vfmaq_f32(o, a3, vld1q_f32(b3.add(j)));
                vst1q_f32(op.add(j), o);
                j += 4;
            }
            while j < n {
                *op.add(j) += aseg[kk] * *b0.add(j)
                    + aseg[kk + 1] * *b1.add(j)
                    + aseg[kk + 2] * *b2.add(j)
                    + aseg[kk + 3] * *b3.add(j);
                j += 1;
            }
            kk += 4;
        }
        while kk < aseg.len() {
            let av = aseg[kk];
            let a0 = vdupq_n_f32(av);
            let b0 = bp.add(kk * n);
            let mut j = 0;
            while j + 4 <= n {
                let o = vfmaq_f32(vld1q_f32(op.add(j)), a0, vld1q_f32(b0.add(j)));
                vst1q_f32(op.add(j), o);
                j += 4;
            }
            while j < n {
                *op.add(j) += av * *b0.add(j);
                j += 1;
            }
            kk += 1;
        }
    }

    /// Polynomial exp for 4 lanes — the NEON mirror of `avx2::exp8`: same
    /// clamp, same ln2 split, same degree-6 Horner, so the two ISAs agree
    /// to the last coefficient (≈1e-7 relative error).
    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    unsafe fn exp4(x: float32x4_t) -> float32x4_t {
        let x = vminq_f32(x, vdupq_n_f32(88.0));
        let x = vmaxq_f32(x, vdupq_n_f32(-87.0));
        let log2e = vdupq_n_f32(std::f32::consts::LOG2_E);
        let ln2_hi = vdupq_n_f32(0.693_359_375);
        let ln2_lo = vdupq_n_f32(-2.121_944_4e-4);
        // n = round-to-nearest(x · log2(e)).
        let ni = vcvtnq_s32_f32(vmulq_f32(x, log2e));
        let nf = vcvtq_f32_s32(ni);
        // r = x − n·ln2, split ln2 so the subtraction stays exact.
        let r = vfmsq_f32(x, nf, ln2_hi);
        let r = vfmsq_f32(r, nf, ln2_lo);
        // Horner over 1 + r + r²/2! + … + r⁶/6!.
        let mut p = vdupq_n_f32(1.0 / 720.0);
        p = vfmaq_f32(vdupq_n_f32(1.0 / 120.0), p, r);
        p = vfmaq_f32(vdupq_n_f32(1.0 / 24.0), p, r);
        p = vfmaq_f32(vdupq_n_f32(1.0 / 6.0), p, r);
        p = vfmaq_f32(vdupq_n_f32(0.5), p, r);
        p = vfmaq_f32(vdupq_n_f32(1.0), p, r);
        p = vfmaq_f32(vdupq_n_f32(1.0), p, r);
        // Scale by 2^n through the exponent bits.
        let pow2 = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(ni, vdupq_n_s32(127))));
        vmulq_f32(p, pow2)
    }

    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale_max(row: &mut [f32], scale: f32) -> f32 {
        let n = row.len();
        let p = row.as_mut_ptr();
        let sv = vdupq_n_f32(scale);
        let mut mv = vdupq_n_f32(f32::NEG_INFINITY);
        let mut i = 0;
        while i + 4 <= n {
            let v = vmulq_f32(vld1q_f32(p.add(i)), sv);
            vst1q_f32(p.add(i), v);
            mv = vmaxq_f32(mv, v);
            i += 4;
        }
        let mut mx = vmaxvq_f32(mv);
        while i < n {
            let v = *p.add(i) * scale;
            *p.add(i) = v;
            if v > mx {
                mx = v;
            }
            i += 1;
        }
        mx
    }

    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    pub unsafe fn exp_sub_sum(row: &mut [f32], mx: f32) -> f32 {
        let n = row.len();
        let p = row.as_mut_ptr();
        let mv = vdupq_n_f32(mx);
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            let e = exp4(vsubq_f32(vld1q_f32(p.add(i)), mv));
            vst1q_f32(p.add(i), e);
            acc = vaddq_f32(acc, e);
            i += 4;
        }
        let mut sum = vaddvq_f32(acc);
        while i < n {
            let e = (*p.add(i) - mx).exp();
            *p.add(i) = e;
            sum += e;
            i += 1;
        }
        sum
    }

    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale_in_place(row: &mut [f32], c: f32) {
        let n = row.len();
        let p = row.as_mut_ptr();
        let cv = vdupq_n_f32(c);
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(p.add(i), vmulq_f32(vld1q_f32(p.add(i)), cv));
            i += 4;
        }
        while i < n {
            *p.add(i) *= c;
            i += 1;
        }
    }

    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    pub unsafe fn rescale_add(out: &mut [f32], add: &[f32], corr: f32) {
        debug_assert_eq!(out.len(), add.len());
        let n = out.len();
        let po = out.as_mut_ptr();
        let pa = add.as_ptr();
        let cv = vdupq_n_f32(corr);
        let mut i = 0;
        while i + 4 <= n {
            let v = vfmaq_f32(vld1q_f32(pa.add(i)), vld1q_f32(po.add(i)), cv);
            vst1q_f32(po.add(i), v);
            i += 4;
        }
        while i < n {
            *po.add(i) = *po.add(i) * corr + *pa.add(i);
            i += 1;
        }
    }

    // SAFETY: callers must ensure NEON is available (dispatch does, via
    // `isa()`; it is also baseline on aarch64); the body uses unaligned
    // loads/stores on in-bounds slice ranges only.
    #[target_feature(enable = "neon")]
    pub unsafe fn exp_recompute(row: &mut [f32], scale: f32, mi: f32, inv_l: f32) {
        let n = row.len();
        let p = row.as_mut_ptr();
        let sv = vdupq_n_f32(scale);
        let miv = vdupq_n_f32(mi);
        let lv = vdupq_n_f32(inv_l);
        let mut i = 0;
        while i + 4 <= n {
            let x = vsubq_f32(vmulq_f32(vld1q_f32(p.add(i)), sv), miv);
            vst1q_f32(p.add(i), vmulq_f32(exp4(x), lv));
            i += 4;
        }
        while i < n {
            *p.add(i) = (*p.add(i) * scale - mi).exp() * inv_l;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    #[test]
    fn isa_label_is_reported() {
        let label = isa_label();
        assert!(!label.is_empty());
        match isa() {
            Isa::Scalar => assert_eq!(label, "scalar"),
            Isa::Avx2Fma => assert_eq!(label, "x86_64/avx2+fma"),
            Isa::Neon => assert_eq!(label, "aarch64/neon"),
        }
    }

    #[test]
    fn dispatched_dot_matches_scalar_over_off_width_lengths() {
        // Lengths off the vector width (1..70 covers <1 vector, partial
        // tails, and multi-vector bodies for both 8-lane and 4-lane ISAs).
        let mut rng = Rng::new(900);
        for n in (0..70).chain([128, 129, 255, 1024]) {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let want = dot_f32_scalar(&a, &b);
            let got = dot_f32(&a, &b);
            assert!(close(got, want, 1e-4), "dot len {n}: {got} vs {want}");
        }
    }

    #[test]
    fn dispatched_axpy4_matches_scalar_over_off_width_shapes() {
        let mut rng = Rng::new(901);
        for &(k, n) in &[
            (1usize, 1usize),
            (3, 5),
            (4, 8),
            (5, 7),
            (7, 9),
            (8, 16),
            (13, 33),
            (31, 64),
            (64, 65),
        ] {
            let aseg = randv(&mut rng, k);
            let b_panel = randv(&mut rng, k * n);
            let base = randv(&mut rng, n);
            let mut want = base.clone();
            axpy4_f32_scalar(&aseg, &b_panel, n, &mut want);
            let mut got = base.clone();
            axpy4_f32(&aseg, &b_panel, n, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!(close(*g, *w, 1e-4), "axpy4 ({k},{n}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn axpy4_scalar_tail_is_branchless_on_zero_coefficients() {
        // A zero k-tail coefficient must still touch the output (no
        // data-dependent skip): the result is identical either way, but the
        // FLOP count — and the SIMD/scalar equivalence — must be shape-only.
        let aseg = [0.0f32; 3];
        let b_panel = [1.0f32; 6];
        let mut o = [2.0f32, 3.0];
        axpy4_f32_scalar(&aseg, &b_panel, 2, &mut o);
        assert_eq!(o, [2.0, 3.0]);
        let mut o = [2.0f32, 3.0];
        axpy4_f32(&aseg, &b_panel, 2, &mut o);
        assert_eq!(o, [2.0, 3.0]);
    }

    #[test]
    fn softmax_helpers_match_scalar() {
        let mut rng = Rng::new(902);
        for n in [0usize, 1, 3, 7, 8, 9, 16, 33, 100] {
            let base = randv(&mut rng, n);
            let scale = 0.37f32;

            let mut a = base.clone();
            let mut b = base.clone();
            let ma = scale_max(&mut a, scale);
            let mb = scale_max_scalar(&mut b, scale);
            assert_eq!(a, b, "scale_max len {n}");
            assert_eq!(ma, mb, "scale_max max len {n}");

            let sa = exp_sub_sum(&mut a, ma);
            let sb = exp_sub_sum_scalar(&mut b, mb);
            assert!(close(sa, sb, 1e-5), "exp_sub_sum len {n}: {sa} vs {sb}");
            for (x, y) in a.iter().zip(&b) {
                assert!(close(*x, *y, 1e-5), "exp_sub_sum elem len {n}: {x} vs {y}");
            }

            if sa > 0.0 {
                scale_in_place(&mut a, 1.0 / sa);
                scale_in_place_scalar(&mut b, 1.0 / sb);
                for (x, y) in a.iter().zip(&b) {
                    assert!(close(*x, *y, 1e-5), "scale_in_place len {n}");
                }
            }

            let add = randv(&mut rng, n);
            let mut oa = base.clone();
            let mut ob = base.clone();
            rescale_add(&mut oa, &add, 0.73);
            rescale_add_scalar(&mut ob, &add, 0.73);
            for (x, y) in oa.iter().zip(&ob) {
                assert!(close(*x, *y, 1e-5), "rescale_add len {n}");
            }

            let mut ra = base.clone();
            let mut rb = base.clone();
            // mi above the scaled max keeps arguments ≤ 0 like real callers.
            let mi = 1.0 + base.iter().fold(0f32, |m, x| m.max(x.abs()));
            exp_recompute(&mut ra, 0.25, mi, 0.5);
            exp_recompute_scalar(&mut rb, 0.25, mi, 0.5);
            for (x, y) in ra.iter().zip(&rb) {
                assert!(close(*x, *y, 1e-5), "exp_recompute len {n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn exp_helpers_handle_large_negative_arguments() {
        // Far-below-max scores must underflow toward 0, never to NaN/∞.
        let mut row = vec![-200.0f32, -50.0, 0.0];
        let sum = exp_sub_sum(&mut row, 0.0);
        assert!(row.iter().all(|x| x.is_finite() && *x >= 0.0), "{row:?}");
        assert!((row[2] - 1.0).abs() < 1e-6);
        assert!(sum >= 1.0 && sum.is_finite());
        assert!(row[0] < 1e-20, "exp(-200) must underflow: {}", row[0]);
    }
}
