//! Naive reference implementations — the seed's scalar loops, preserved
//! verbatim as the correctness oracle for [`super::kernels`].
//!
//! Property tests assert `kernels ≡ reference` to 1e-10 over random and
//! degenerate shapes; benches report kernel speedup relative to these.
//! Nothing on a hot path should call into this module.

use crate::linalg::Mat;

/// ikj-ordered scalar matmul (the seed `Mul` impl, zero-skip included).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let av = a[(i, k)];
            if av == 0.0 {
                continue;
            }
            let rrow = b.row(k);
            let orow = out.row_mut(i);
            for (o, r) in orow.iter_mut().zip(rrow) {
                *o += av * r;
            }
        }
    }
    out
}

/// Elementwise double-loop transpose (the seed `Mat::t`).
pub fn transpose(a: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols, a.rows);
    for i in 0..a.rows {
        for j in 0..a.cols {
            out[(j, i)] = a[(i, j)];
        }
    }
    out
}

/// Row-wise scalar matvec (the seed `Mat::matvec`).
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), a.cols);
    (0..a.rows)
        .map(|i| a.row(i).iter().zip(x).map(|(p, q)| p * q).sum::<f64>())
        .collect()
}

/// The seed GAR forward: two full matmuls (`t = x·Ṽ`, `rest = t·ûᵀ`) plus a
/// row-copy loop assembling `[t, rest]` — three intermediate allocations.
pub fn gar_forward(u_hat: &Mat, v_tilde: &Mat, rank: usize, x: &Mat) -> Mat {
    let t = matmul(x, v_tilde); // (B, r)
    if u_hat.rows == 0 {
        return t;
    }
    let rest = matmul(&t, &transpose(u_hat)); // (B, m - r)
    let m = rank + u_hat.rows;
    let mut y = Mat::zeros(x.rows, m);
    for i in 0..x.rows {
        y.row_mut(i)[..rank].copy_from_slice(t.row(i));
        y.row_mut(i)[rank..].copy_from_slice(rest.row(i));
    }
    y
}
