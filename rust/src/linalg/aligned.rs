//! 64-byte-aligned scratch buffers.
//!
//! The SIMD micro-kernels use unaligned loads, so alignment is a
//! performance property, not a correctness one — but a 64-byte base keeps
//! vector loads off cache-line straddles and leaves headroom for 512-bit
//! ISAs.  `Vec<T>` cannot be realigned after the fact (its deallocation
//! layout is pinned at allocation), so the scratch owners ([`Arena`],
//! attention workspaces, the serving `Scratch`) hold [`AlignedVec`]
//! instead.  Every allocation site carries a debug assertion on the
//! alignment actually returned.
//!
//! [`Arena`]: crate::linalg::kernels::Arena

use std::alloc::{self, Layout};
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Allocation boundary: one cache line (and the widest vector register
/// this crate targets).
pub const ALIGN: usize = 64;

/// A `Vec`-like owned buffer of plain scalars whose storage is 64-byte
/// aligned.  Derefs to `[T]`, so slice-consuming kernels take it directly.
pub struct AlignedVec<T: Copy + Default> {
    ptr: NonNull<T>,
    len: usize,
    cap: usize,
}

impl<T: Copy + Default> AlignedVec<T> {
    /// An empty buffer; allocates nothing until the first [`resize`].
    ///
    /// [`resize`]: AlignedVec::resize
    pub fn new() -> AlignedVec<T> {
        AlignedVec { ptr: NonNull::dangling(), len: 0, cap: 0 }
    }

    /// A zero-filled buffer of `len` elements.
    pub fn zeroed(len: usize) -> AlignedVec<T> {
        let mut v = AlignedVec::new();
        v.resize(len, T::default());
        v
    }

    /// An aligned copy of `s`.
    pub fn from_slice(s: &[T]) -> AlignedVec<T> {
        let mut v = AlignedVec::zeroed(s.len());
        v.copy_from_slice(s);
        v
    }

    /// Grow or shrink to exactly `new_len` elements, filling any new tail
    /// with `fill`.  The prefix is preserved; shrinking keeps the
    /// allocation for reuse (like `Vec`).
    pub fn resize(&mut self, new_len: usize, fill: T) {
        if new_len > self.cap {
            self.grow(new_len);
        }
        while self.len < new_len {
            // SAFETY: `grow` above guarantees `cap >= new_len`, so every
            // index written here is inside the live allocation.
            unsafe { self.ptr.as_ptr().add(self.len).write(fill) };
            self.len += 1;
        }
        self.len = new_len;
    }

    /// Elements the current allocation can hold without reallocating.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * std::mem::size_of::<T>(), ALIGN)
            .expect("AlignedVec: layout overflow")
    }

    fn grow(&mut self, new_cap: usize) {
        debug_assert!(std::mem::align_of::<T>() <= ALIGN, "AlignedVec: over-aligned element");
        let layout = Self::layout(new_cap);
        // SAFETY: `layout` has nonzero size (new_cap > cap >= 0 elements of
        // a sized `T`) and a valid 64-byte alignment from `Self::layout`.
        let raw = unsafe { alloc::alloc(layout) } as *mut T;
        let Some(ptr) = NonNull::new(raw) else { alloc::handle_alloc_error(layout) };
        debug_assert_eq!(
            ptr.as_ptr() as usize % ALIGN,
            0,
            "scratch allocation must be 64-byte aligned"
        );
        // SAFETY: both regions hold at least `len` initialized `T`s —
        // the source by the struct invariant (len <= cap), the destination
        // because new_cap >= len — and a fresh allocation cannot overlap.
        unsafe { std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), ptr.as_ptr(), self.len) };
        self.release();
        self.ptr = ptr;
        self.cap = new_cap;
    }

    fn release(&mut self) {
        if self.cap > 0 {
            // SAFETY: `cap > 0` means `ptr` came from `alloc` with exactly
            // `Self::layout(self.cap)`, and it is deallocated only once
            // (release() resets through grow()/Drop ownership).
            unsafe { alloc::dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
        }
    }
}

impl<T: Copy + Default> Drop for AlignedVec<T> {
    fn drop(&mut self) {
        self.release();
    }
}

impl<T: Copy + Default> Default for AlignedVec<T> {
    fn default() -> AlignedVec<T> {
        AlignedVec::new()
    }
}

impl<T: Copy + Default> Clone for AlignedVec<T> {
    fn clone(&self) -> AlignedVec<T> {
        AlignedVec::from_slice(self)
    }
}

impl<T: Copy + Default> Deref for AlignedVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        // SAFETY: struct invariant — the first `len` elements are
        // initialized and live for as long as `self` borrows them.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy + Default> DerefMut for AlignedVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // SAFETY: same invariant as `deref`, and `&mut self` guarantees
        // exclusive access to the buffer.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy + Default + fmt::Debug> fmt::Debug for AlignedVec<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        <[T] as fmt::Debug>::fmt(self, f)
    }
}

impl<T: Copy + Default + PartialEq> PartialEq for AlignedVec<T> {
    fn eq(&self, other: &AlignedVec<T>) -> bool {
        self[..] == other[..]
    }
}

// SAFETY: the buffer owns its (plain-scalar) elements exactly like
// `Vec<T>` — sending or sharing the vec sends/shares only `T`s, so the
// usual `T: Send` / `T: Sync` bounds carry over unchanged.
unsafe impl<T: Copy + Default + Send> Send for AlignedVec<T> {}
unsafe impl<T: Copy + Default + Sync> Sync for AlignedVec<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocations_are_64_byte_aligned() {
        for len in [1usize, 7, 63, 64, 65, 1000] {
            let v: AlignedVec<f32> = AlignedVec::zeroed(len);
            assert_eq!(v.as_ptr() as usize % ALIGN, 0, "len {len}");
            assert_eq!(v.len(), len);
            assert!(v.iter().all(|&x| x == 0.0));
            let d: AlignedVec<f64> = AlignedVec::zeroed(len);
            assert_eq!(d.as_ptr() as usize % ALIGN, 0, "f64 len {len}");
        }
    }

    #[test]
    fn resize_preserves_prefix_and_reuses_capacity() {
        let mut v: AlignedVec<f64> = AlignedVec::new();
        v.resize(8, 1.5);
        assert!(v.iter().all(|&x| x == 1.5));
        let p = v.as_ptr() as usize;
        v.resize(4, 0.0);
        assert_eq!(v.len(), 4);
        assert_eq!(v.as_ptr() as usize, p, "shrink must keep the allocation");
        v.resize(8, 2.5);
        assert_eq!(v.as_ptr() as usize, p, "regrow within capacity must not realloc");
        assert_eq!(&v[..4], &[1.5; 4]);
        assert_eq!(&v[4..], &[2.5; 4]);
        v.resize(64, 0.0);
        assert_eq!(&v[..4], &[1.5; 4], "grow must copy the prefix");
        assert_eq!(v.as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn clone_is_independent() {
        let v: AlignedVec<f32> = AlignedVec::from_slice(&[1.0, 2.0, 3.0]);
        let mut w = v.clone();
        w[0] = 9.0;
        assert_eq!(v[0], 1.0);
        assert_eq!(w.as_ptr() as usize % ALIGN, 0);
        assert_eq!(&v[1..], &w[1..]);
    }

    #[test]
    fn empty_buffer_is_usable() {
        let v: AlignedVec<f32> = AlignedVec::new();
        assert!(v.is_empty());
        assert_eq!(&v[..], &[] as &[f32]);
        let w = v.clone();
        assert!(w.is_empty());
    }
}
