//! Process-wide persistent worker pool for the compute kernels.
//!
//! [`parallel_for`] replaces the per-call `std::thread::scope` fan-out the
//! kernels used to pay (~tens of µs of spawn/join per matmul — pure
//! overhead on exactly the small/medium shapes low-budget serving tiers
//! produce): workers are spawned once, lazily, on first pooled dispatch,
//! and park on a condvar between jobs.  Dispatching a job costs one mutex
//! lock plus a `notify_all`, and chunk assignment is an atomic counter
//! every participant claims from (`fetch_add`), so uneven chunks
//! load-balance for free and the submitting thread works alongside the
//! pool instead of idling.
//!
//! One job runs at a time.  A `parallel_for` issued while another thread's
//! job is in flight runs its chunks on the calling thread instead of
//! queueing — concurrent submitters are already the unit of parallelism in
//! that case, and the inline fallback keeps the pool deadlock-free by
//! construction (nested `parallel_for` from inside a chunk degrades to the
//! same serial path).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once, OnceLock};

/// Upper bound on pool parallelism (submitter + parked workers).
pub const MAX_THREADS: usize = 16;

/// One dispatched job: a type-erased chunk closure plus its claim/finish
/// counters.
///
/// Safety: the raw closure pointer is dereferenced only for successfully
/// claimed chunk indices (`next.fetch_add() < n_chunks`), and such a claim
/// can only happen while the submitting `parallel_for` is still blocked
/// waiting for `done == n_chunks` — so the borrowed closure (and everything
/// it borrows from the submitter's stack) outlives every dereference.
/// Late-waking workers holding a retired job's `Arc` find `next` already
/// exhausted and never touch the pointer.
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    n_chunks: usize,
    /// Next unclaimed chunk index.
    next: AtomicUsize,
    /// Chunks that finished executing (panicked chunks count too, so the
    /// submitter's completion wait can never hang).
    done: AtomicUsize,
    /// First chunk panic, re-raised on the submitting thread — the same
    /// propagation the old `std::thread::scope` fan-out gave at join.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// SAFETY: the raw `task` pointer is the only non-auto field; the doc
// comment above pins the claim protocol under which it is dereferenced
// (closure outlives every claim), and the closure itself is `Sync`.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct State {
    /// Monotone job counter; workers wake when it moves past what they saw.
    epoch: u64,
    job: Option<Arc<Job>>,
}

/// The pool singleton: parked workers plus the current-job slot.
struct Pool {
    state: Mutex<State>,
    bell: Condvar,
    /// Serializes submitters; held for the full duration of one job.
    dispatch: Mutex<()>,
    /// Completion signal: the worker that finishes a job's last chunk
    /// rings this so the submitter parks instead of spinning.
    done_lock: Mutex<()>,
    done_bell: Condvar,
    /// Worker threads ever spawned (tests assert this stops moving).
    spawned: AtomicUsize,
    /// Worker-thread target: `size() − 1`, the submitter participates.
    workers: usize,
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, MAX_THREADS)
}

/// Maximum useful parallelism (hardware threads, capped at
/// [`MAX_THREADS`]).  Cheap; does not start the pool.
pub fn size() -> usize {
    static SIZE: OnceLock<usize> = OnceLock::new();
    *SIZE.get_or_init(hardware_threads)
}

/// Workspace-slot count that saturates the pool for a slot-strided loop
/// over `items` independent work items (the attention pair loops size their
/// per-chunk panel sets with this): more slots than pool threads only waste
/// memory, more slots than items never run.  Cheap; does not start the
/// pool.
pub fn saturating_slots(items: usize) -> usize {
    size().min(items).max(1)
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    static SPAWN: Once = Once::new();
    let p = POOL.get_or_init(|| {
        // Resolve the SIMD dispatch tier exactly once, before any worker can
        // touch a micro-kernel — every pooled chunk then reads a settled
        // cache line instead of racing the first detection.
        let _ = crate::linalg::simd::isa();
        Pool {
            state: Mutex::new(State { epoch: 0, job: None }),
            bell: Condvar::new(),
            dispatch: Mutex::new(()),
            done_lock: Mutex::new(()),
            done_bell: Condvar::new(),
            spawned: AtomicUsize::new(0),
            workers: size() - 1,
        }
    });
    SPAWN.call_once(|| {
        for i in 0..p.workers {
            p.spawned.fetch_add(1, Ordering::Relaxed);
            std::thread::Builder::new()
                .name(format!("flexrank-pool-{i}"))
                .spawn(move || worker_loop(p))
                .expect("spawn pool worker");
        }
    });
    p
}

/// Worker threads ever created by the pool (diagnostics/tests).  Starts the
/// pool if it is not running yet.
pub fn threads_spawned() -> usize {
    pool().spawned.load(Ordering::Relaxed)
}

fn worker_loop(pool: &'static Pool) {
    let mut seen = 0u64;
    loop {
        // Park until a job newer than the last one we saw is published.
        let job = {
            let mut st = pool.state.lock().unwrap();
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(job) = st.job.clone() {
                        break job;
                    }
                }
                st = pool.bell.wait(st).unwrap();
            }
        };
        run_chunks(pool, &job);
    }
}

/// Claim and execute chunks until the job's claim counter is exhausted.
/// A panicking chunk is caught (keeping the worker alive and the `done`
/// counter advancing); its payload is stashed for the submitter to re-raise.
/// Whoever completes the last chunk rings the pool's done bell.
fn run_chunks(pool: &Pool, job: &Job) {
    loop {
        let ci = job.next.fetch_add(1, Ordering::AcqRel);
        if ci >= job.n_chunks {
            break;
        }
        // SAFETY: deref only after a successful claim — the claim proves
        // this chunk has not run, so the submitter is still blocked on
        // `done < n_chunks` and the borrowed closure is alive.  A retired
        // job's counter is exhausted, so its (dangling) pointer is never
        // even reconstituted into a reference.
        let task = unsafe { &*job.task };
        if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(ci))) {
            let mut slot = job.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        if job.done.fetch_add(1, Ordering::AcqRel) + 1 == job.n_chunks {
            // Taking the lock before notifying closes the race with a
            // submitter that checked `done` and is about to wait.
            let _g = pool.done_lock.lock().unwrap();
            pool.done_bell.notify_all();
        }
    }
}

/// Raw pointer wrapper so chunk closures can carry a mutable output base
/// across threads.  Safety contract for every user: a `SendPtr` may only
/// be dereferenced for regions that are disjoint across chunk indices —
/// here that is enforced by the row-range math in [`parallel_for_rows`];
/// `runtime::attention` reuses it with per-slot / per-(batch, head)
/// disjointness arguments documented at each dereference.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
// SAFETY: `SendPtr` is an address, not an access — every dereference
// happens under the per-chunk disjointness contract documented above, so
// moving/sharing the wrapper across worker threads is sound.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Row-blocked fan-out: split `out` (≥ `rows · row_len` elements) into
/// chunks of `rows_per` rows and run `body(first_row, chunk)` for each
/// through [`parallel_for`].  This is the single place that turns disjoint
/// chunk indices into disjoint `&mut` sub-slices — every pooled kernel
/// routes its output through here instead of carrying its own unsafe
/// pointer arithmetic.
pub fn parallel_for_rows<T: Send + Sync>(
    out: &mut [T],
    rows: usize,
    row_len: usize,
    rows_per: usize,
    body: &(dyn Fn(usize, &mut [T]) + Sync),
) {
    assert!(out.len() >= rows * row_len, "parallel_for_rows: out too small");
    assert!(rows_per > 0, "parallel_for_rows: empty chunks");
    let base = SendPtr(out.as_mut_ptr());
    parallel_for(rows.div_ceil(rows_per), &|ci| {
        let i0 = ci * rows_per;
        let rows_c = rows_per.min(rows - i0);
        // SAFETY: chunk `ci` covers elements [i0·row_len, (i0+rows_c)·row_len)
        // — in-bounds by the assert above, disjoint across chunk indices.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(i0 * row_len), rows_c * row_len)
        };
        body(i0, chunk);
    });
}

/// Run `task(ci)` for every chunk index in `0..n_chunks` and return once all
/// of them have executed.  Uses the persistent pool when it is free, the
/// calling thread alone otherwise (single-core machines, one-chunk jobs,
/// or a pool already busy with another submitter's job).
///
/// A panic inside a chunk does not kill a worker or hang the submitter:
/// it is caught on the executing thread and re-raised here after the job
/// drains, matching the join-time propagation of the `std::thread::scope`
/// fan-out this pool replaced.
pub fn parallel_for(n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
    if n_chunks == 0 {
        return;
    }
    let run_serial = || {
        for ci in 0..n_chunks {
            task(ci);
        }
    };
    if n_chunks == 1 || size() <= 1 {
        run_serial();
        return;
    }
    let p = pool();
    let Ok(guard) = p.dispatch.try_lock() else {
        run_serial();
        return;
    };
    let job = Arc::new(Job {
        task: task as *const (dyn Fn(usize) + Sync),
        n_chunks,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        panic: Mutex::new(None),
    });
    {
        let mut st = p.state.lock().unwrap();
        st.epoch += 1;
        st.job = Some(job.clone());
        p.bell.notify_all();
    }
    // The submitter claims chunks like any worker.
    run_chunks(p, &job);
    // Stragglers may still be inside their last claimed chunk; park on the
    // done bell instead of spinning (`done` advances even for panicked
    // chunks, so this cannot hang).
    {
        let mut g = p.done_lock.lock().unwrap();
        while job.done.load(Ordering::Acquire) < n_chunks {
            g = p.done_bell.wait(g).unwrap();
        }
    }
    // Retire the job so late-waking workers see an empty slot, release the
    // dispatch slot, then surface any chunk panic on this thread.
    p.state.lock().unwrap().job = None;
    drop(guard);
    if let Some(payload) = job.panic.lock().unwrap().take() {
        std::panic::resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{kernels, reference, Mat};
    use crate::rng::Rng;

    #[test]
    fn parallel_for_covers_every_chunk_exactly_once() {
        let counts: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(97, &|ci| {
            counts[ci].fetch_add(1, Ordering::Relaxed);
        });
        for (ci, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "chunk {ci} run count");
        }
    }

    #[test]
    fn pool_spawns_no_workers_after_warmup() {
        // Warm up with a matmul big enough to force pooled dispatch.
        let mut rng = Rng::new(900);
        let (m, k, n) = (64, 128, 64); // 512K MACs ≥ PAR_MIN_OPS
        assert!(m * k * n >= kernels::PAR_MIN_OPS);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let _ = kernels::matmul(&a, &b);
        let spawned = threads_spawned();
        assert_eq!(spawned, size() - 1, "pool spawns hardware−1 workers, once");
        for _ in 0..32 {
            let _ = kernels::matmul(&a, &b);
        }
        assert_eq!(threads_spawned(), spawned, "steady state must reuse workers");
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let boom = std::panic::catch_unwind(|| {
            parallel_for(8, &|ci| {
                if ci == 3 {
                    panic!("chunk 3 exploded");
                }
            });
        });
        assert!(boom.is_err(), "chunk panic must re-raise on the submitter");
        // All workers survived and the counters reset: later jobs complete.
        let counts: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(16, &|ci| {
            counts[ci].fetch_add(1, Ordering::Relaxed);
        });
        for c in &counts {
            assert_eq!(c.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn concurrent_matmuls_through_shared_pool_match_reference() {
        // Two threads hammer the one shared pool with above-threshold
        // problems; whichever loses the dispatch race runs inline.  Every
        // result must still match the serial reference exactly.
        let work = |seed: u64| {
            let mut rng = Rng::new(seed);
            for _ in 0..6 {
                let (m, k, n) = (48, 160, 52); // ~400K MACs ≥ PAR_MIN_OPS
                let a = Mat::randn(m, k, &mut rng);
                let b = Mat::randn(k, n, &mut rng);
                let got = kernels::matmul(&a, &b);
                let want = reference::matmul(&a, &b);
                assert!(got.close_to(&want, 1e-10), "pooled matmul diverged");
                let bt = Mat::randn(n, k, &mut rng);
                let got = kernels::matmul_nt(&a, &bt);
                let want = reference::matmul(&a, &reference::transpose(&bt));
                assert!(got.close_to(&want, 1e-10), "pooled matmul_nt diverged");
            }
        };
        std::thread::scope(|s| {
            s.spawn(|| work(901));
            s.spawn(|| work(902));
        });
    }
}
