//! Native compute kernels: cache-blocked, panel-packed, multi-threaded
//! matmul (f64 and f32 paths), blocked transpose, unrolled matvec, the fused
//! GAR emit, and a reusable scratch [`Arena`] so hot-path ops stop
//! allocating per call.
//!
//! Design (CPU, row-major):
//!
//! * **k-panel blocking** — the inner product dimension is processed in
//!   panels of [`KC`] rows of B, so the streamed B panel stays L2-resident
//!   while a block of output rows is updated.  For row-major `A·B` both
//!   operands stream contiguously, so the classic pack step reduces to
//!   panel streaming; the one kernel whose access pattern is genuinely
//!   strided — `Aᵀ·B` (gradient accumulation, covariance grams) — packs the
//!   A column panel into a thread-local contiguous buffer first.
//! * **4-way unrolled micro-kernels** — the axpy update accumulates four
//!   B rows per pass over the output row (4× less write traffic, enough
//!   independent streams for the FP pipelines to auto-vectorize), and dot
//!   products carry four accumulators.
//! * **persistent-pool outer loops** — output row blocks are dispatched to
//!   the process-wide worker [`pool`](super::pool) (parked workers, atomic
//!   chunk claiming — no per-call thread spawn) above [`PAR_MIN_OPS`] MACs;
//!   below that even the ~µs pool dispatch dominates and the kernels stay
//!   serial.
//!
//! The pre-existing naive loops live on in [`super::reference`]; property
//! tests assert the two agree to 1e-10 across random and degenerate shapes.

use crate::linalg::pool;
use crate::linalg::Mat;

/// Depth of one k-panel (B panel of `KC × n` stays cache-resident).
pub const KC: usize = 256;

/// MAC count below which kernels stay single-threaded.  With the persistent
/// pool this is the dispatch floor (~µs of wake/claim latency), an order of
/// magnitude below the old scoped-thread spawn floor of `1 << 20`.
pub const PAR_MIN_OPS: usize = 1 << 17;

/// Rows per pooled chunk for a kernel over `m` output rows and `ops` MACs;
/// `None` keeps the call single-threaded (below the dispatch floor, tiny
/// outputs, or no hardware parallelism).  `packed` kernels get one chunk
/// per pool thread (each chunk invocation packs a private panel buffer);
/// streaming kernels get ~4× finer chunks so the pool's atomic claim loop
/// load-balances ragged shapes.
fn chunk_rows(m: usize, ops: usize, packed: bool) -> Option<usize> {
    let threads = pool::size();
    if ops < PAR_MIN_OPS || threads <= 1 || m <= 1 {
        return None;
    }
    let chunks = if packed { threads } else { 4 * threads }.min(m);
    Some(m.div_ceil(chunks))
}

// ---------------------------------------------------------------------------
// Slice-level kernels, generated for f64 and f32.
// ---------------------------------------------------------------------------

macro_rules! kernels_for {
    ($ty:ty, $dot:ident, $axpy4:ident, $mm:ident, $mm_rows:ident,
     $nt:ident, $nt_rows:ident, $tn_acc:ident) => {
        /// Four-accumulator dot product.
        #[inline]
        pub fn $dot(a: &[$ty], b: &[$ty]) -> $ty {
            debug_assert_eq!(a.len(), b.len());
            let n4 = a.len() & !3;
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            let mut i = 0;
            while i < n4 {
                s0 += a[i] * b[i];
                s1 += a[i + 1] * b[i + 1];
                s2 += a[i + 2] * b[i + 2];
                s3 += a[i + 3] * b[i + 3];
                i += 4;
            }
            let mut s = (s0 + s1) + (s2 + s3);
            while i < a.len() {
                s += a[i] * b[i];
                i += 1;
            }
            s
        }

        /// Micro-kernel: `orow += Σ_kk aseg[kk] · b_panel_row(kk)`, four B
        /// rows per pass.  `aseg` and `b_panel` cover the same k-range
        /// (`b_panel` holds `aseg.len()` rows of length `n`).
        #[inline]
        fn $axpy4(aseg: &[$ty], b_panel: &[$ty], n: usize, orow: &mut [$ty]) {
            debug_assert_eq!(b_panel.len(), aseg.len() * n);
            debug_assert_eq!(orow.len(), n);
            let k4 = aseg.len() & !3;
            let mut kk = 0;
            while kk < k4 {
                let a0 = aseg[kk];
                let a1 = aseg[kk + 1];
                let a2 = aseg[kk + 2];
                let a3 = aseg[kk + 3];
                let b0 = &b_panel[kk * n..kk * n + n];
                let b1 = &b_panel[(kk + 1) * n..(kk + 1) * n + n];
                let b2 = &b_panel[(kk + 2) * n..(kk + 2) * n + n];
                let b3 = &b_panel[(kk + 3) * n..(kk + 3) * n + n];
                for ((((o, v0), v1), v2), v3) in
                    orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                {
                    *o += a0 * *v0 + a1 * *v1 + a2 * *v2 + a3 * *v3;
                }
                kk += 4;
            }
            while kk < aseg.len() {
                let av = aseg[kk];
                if av != 0.0 {
                    let brow = &b_panel[kk * n..kk * n + n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
                kk += 1;
            }
        }

        /// `out = A·B` with `A (m×k)`, `B (k×n)`, all row-major slices.
        pub fn $mm(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize, out: &mut [$ty]) {
            assert_eq!(a.len(), m * k, "matmul: A size");
            assert_eq!(b.len(), k * n, "matmul: B size");
            assert_eq!(out.len(), m * n, "matmul: out size");
            for o in out.iter_mut() {
                *o = 0.0;
            }
            if m == 0 || n == 0 || k == 0 {
                return;
            }
            let Some(rows_per) = chunk_rows(m, m * k * n, false) else {
                $mm_rows(a, b, k, n, 0, out);
                return;
            };
            pool::parallel_for_rows(out, m, n, rows_per, &|i0, chunk| {
                $mm_rows(a, b, k, n, i0, chunk)
            });
        }

        /// Serial worker over output rows `[i0, i0 + chunk.len()/n)`.
        fn $mm_rows(a: &[$ty], b: &[$ty], k: usize, n: usize, i0: usize, chunk: &mut [$ty]) {
            let rows = chunk.len() / n;
            let mut kb = 0;
            while kb < k {
                let kend = (kb + KC).min(k);
                let b_panel = &b[kb * n..kend * n];
                for i in 0..rows {
                    let aseg = &a[(i0 + i) * k + kb..(i0 + i) * k + kend];
                    let orow = &mut chunk[i * n..(i + 1) * n];
                    $axpy4(aseg, b_panel, n, orow);
                }
                kb += KC;
            }
        }

        /// `out = A·Bᵀ` with `A (m×k)`, `B (n×k)` — both stream contiguous
        /// rows, so each output entry is one unrolled dot product.
        pub fn $nt(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize, out: &mut [$ty]) {
            assert_eq!(a.len(), m * k, "matmul_nt: A size");
            assert_eq!(b.len(), n * k, "matmul_nt: B size");
            assert_eq!(out.len(), m * n, "matmul_nt: out size");
            if m == 0 || n == 0 {
                return;
            }
            if k == 0 {
                for o in out.iter_mut() {
                    *o = 0.0;
                }
                return;
            }
            let Some(rows_per) = chunk_rows(m, m * k * n, false) else {
                $nt_rows(a, b, k, n, 0, out);
                return;
            };
            pool::parallel_for_rows(out, m, n, rows_per, &|i0, chunk| {
                $nt_rows(a, b, k, n, i0, chunk)
            });
        }

        fn $nt_rows(a: &[$ty], b: &[$ty], k: usize, n: usize, i0: usize, chunk: &mut [$ty]) {
            let rows = chunk.len() / n;
            for i in 0..rows {
                let arow = &a[(i0 + i) * k..(i0 + i + 1) * k];
                let orow = &mut chunk[i * n..(i + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = $dot(arow, &b[j * k..(j + 1) * k]);
                }
            }
        }

        /// `out += Aᵀ·B` with `A (k×m)`, `B (k×n)` — the one layout where A
        /// access is column-strided, so each worker packs its A column panel
        /// into a contiguous buffer before running the axpy micro-kernel.
        pub fn $tn_acc(a: &[$ty], b: &[$ty], k: usize, m: usize, n: usize, out: &mut [$ty]) {
            assert_eq!(a.len(), k * m, "matmul_tn: A size");
            assert_eq!(b.len(), k * n, "matmul_tn: B size");
            assert_eq!(out.len(), m * n, "matmul_tn: out size");
            if m == 0 || n == 0 || k == 0 {
                return;
            }
            let worker = |i0: usize, chunk: &mut [$ty]| {
                let rows = chunk.len() / n;
                let mut pack = vec![0.0; KC.min(k) * rows];
                let mut kb = 0;
                while kb < k {
                    let kend = (kb + KC).min(k);
                    let klen = kend - kb;
                    // Pack A[kb..kend, i0..i0+rows] transposed: row i of the
                    // pack holds column (i0+i) of A over this k-panel.
                    for i in 0..rows {
                        let prow = &mut pack[i * klen..(i + 1) * klen];
                        for (kk, p) in prow.iter_mut().enumerate() {
                            *p = a[(kb + kk) * m + i0 + i];
                        }
                    }
                    let b_panel = &b[kb * n..kend * n];
                    for i in 0..rows {
                        let aseg = &pack[i * klen..(i + 1) * klen];
                        let orow = &mut chunk[i * n..(i + 1) * n];
                        $axpy4(aseg, b_panel, n, orow);
                    }
                    kb += KC;
                }
            };
            // One chunk per pool thread: every chunk invocation packs its
            // own A-panel buffer, so finer chunking would just re-pack.
            let Some(rows_per) = chunk_rows(m, m * k * n, true) else {
                worker(0, out);
                return;
            };
            pool::parallel_for_rows(out, m, n, rows_per, &worker);
        }
    };
}

kernels_for!(f64, dot_f64, axpy4_f64, matmul_f64, mm_rows_f64, matmul_nt_f64, nt_rows_f64, matmul_tn_acc_f64);
kernels_for!(f32, dot_f32, axpy4_f32, matmul_f32, mm_rows_f32, matmul_nt_f32, nt_rows_f32, matmul_tn_acc_f32);

// ---------------------------------------------------------------------------
// Mat-level wrappers (f64 path used by linalg/nn/flexrank).
// ---------------------------------------------------------------------------

/// Blocked parallel `a · b`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut out);
    out
}

/// Allocation-free `out = a · b` (out must be pre-sized `a.rows × b.cols`).
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    assert_eq!((out.rows, out.cols), (a.rows, b.cols), "matmul out dims");
    matmul_f64(&a.data, &b.data, a.rows, a.cols, b.cols, &mut out.data);
}

/// `a · bᵀ` without materializing the transpose.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt dim mismatch");
    let mut out = Mat::zeros(a.rows, b.rows);
    matmul_nt_f64(&a.data, &b.data, a.rows, a.cols, b.rows, &mut out.data);
    out
}

/// `aᵀ · b` without materializing the transpose (panel-packed).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols, b.cols);
    matmul_tn_acc(a, b, &mut out);
    out
}

/// `out += aᵀ · b` (gram/gradient accumulation without temporaries).
pub fn matmul_tn_acc(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.rows, b.rows, "matmul_tn dim mismatch");
    assert_eq!((out.rows, out.cols), (a.cols, b.cols), "matmul_tn out dims");
    matmul_tn_acc_f64(&a.data, &b.data, a.rows, a.cols, b.cols, &mut out.data);
}

/// Tile edge for the blocked transpose (fits two f64 tiles in L1).
const TB: usize = 32;

/// Cache-blocked transpose.
pub fn transpose(a: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols, a.rows);
    for ib in (0..a.rows).step_by(TB) {
        let iend = (ib + TB).min(a.rows);
        for jb in (0..a.cols).step_by(TB) {
            let jend = (jb + TB).min(a.cols);
            for i in ib..iend {
                let arow = &a.data[i * a.cols..(i + 1) * a.cols];
                for j in jb..jend {
                    out.data[j * a.rows + i] = arow[j];
                }
            }
        }
    }
    out
}

/// Allocation-free matvec: `y = a · x`.
pub fn matvec_into(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.cols, "matvec dim mismatch");
    assert_eq!(y.len(), a.rows, "matvec out dims");
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = dot_f64(&a.data[i * a.cols..(i + 1) * a.cols], x);
    }
}

// ---------------------------------------------------------------------------
// Fused GAR emit
// ---------------------------------------------------------------------------

/// Fused GAR output stage: given `t = x·Ṽ` `(B × r)` and `û (m−r × r)`,
/// stream `y = [t, t·ûᵀ]` `(B × m)` directly — no intermediate `rest`
/// matrix, no second pass over the output.
pub fn gar_emit(t: &Mat, u_hat: &Mat, y: &mut Mat) {
    let r = t.cols;
    let mr = u_hat.rows;
    let m = r + mr;
    assert!(mr == 0 || u_hat.cols == r, "gar_emit: û rank mismatch");
    assert_eq!((y.rows, y.cols), (t.rows, m), "gar_emit: out dims");
    if t.rows == 0 || m == 0 {
        return;
    }
    let worker = |i0: usize, chunk: &mut [f64]| {
        let rows = chunk.len() / m;
        for i in 0..rows {
            let trow = &t.data[(i0 + i) * r..(i0 + i + 1) * r];
            let yrow = &mut chunk[i * m..(i + 1) * m];
            yrow[..r].copy_from_slice(trow);
            for (j, o) in yrow[r..].iter_mut().enumerate() {
                *o = dot_f64(trow, &u_hat.data[j * r..(j + 1) * r]);
            }
        }
    };
    let Some(rows_per) = chunk_rows(t.rows, t.rows * r * (mr + 1), false) else {
        worker(0, &mut y.data);
        return;
    };
    pool::parallel_for_rows(&mut y.data, t.rows, m, rows_per, &worker);
}

/// f32 fused GAR emit with an output column offset and stride: writes
/// `[t, t·ûᵀ]` into `y[row*stride + off ..]` — lets the native serving
/// backend stream layer outputs straight into a wider activation buffer.
/// Fans out over the worker pool above [`PAR_MIN_OPS`] MACs like the
/// matmul kernels.
#[allow(clippy::too_many_arguments)]
pub fn gar_emit_f32(
    t: &[f32],
    rows: usize,
    r: usize,
    u_hat: &[f32],
    mr: usize,
    y: &mut [f32],
    stride: usize,
    off: usize,
) {
    let m = r + mr;
    assert_eq!(t.len(), rows * r, "gar_emit_f32: t size");
    assert_eq!(u_hat.len(), mr * r, "gar_emit_f32: û size");
    assert!(off + m <= stride || (rows == 0), "gar_emit_f32: stride too small");
    assert!(y.len() >= rows * stride, "gar_emit_f32: out size");
    if rows == 0 || m == 0 {
        return;
    }
    // `chunk` starts at absolute row `i0` and holds whole strided rows.
    let worker = |i0: usize, chunk: &mut [f32]| {
        for i in 0..chunk.len() / stride {
            let trow = &t[(i0 + i) * r..(i0 + i + 1) * r];
            let yrow = &mut chunk[i * stride + off..i * stride + off + m];
            yrow[..r].copy_from_slice(trow);
            for (j, o) in yrow[r..].iter_mut().enumerate() {
                *o = dot_f32(trow, &u_hat[j * r..(j + 1) * r]);
            }
        }
    };
    let Some(rows_per) = chunk_rows(rows, rows * r * (mr + 1), false) else {
        worker(0, &mut y[..rows * stride]);
        return;
    };
    pool::parallel_for_rows(y, rows, stride, rows_per, &worker);
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Reusable pool of f64 buffers: `take` hands out a zero-length-agnostic
/// buffer resized to the request, `give` returns it for reuse.  After
/// warmup, a fixed take/give pattern performs zero heap allocations.
#[derive(Debug, Default)]
pub struct Arena {
    free: Vec<Vec<f64>>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena { free: Vec::new() }
    }

    /// Check out a buffer of exactly `len` elements (contents unspecified —
    /// callers overwrite).  Reuses the most recently returned buffer, so a
    /// fixed take/give cycle settles on stable allocations.
    pub fn take(&mut self, len: usize) -> Vec<f64> {
        let mut buf = match self.free.pop() {
            Some(b) => b,
            None => Vec::new(),
        };
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool.
    pub fn give(&mut self, buf: Vec<f64>) {
        self.free.push(buf);
    }

    /// Buffers currently pooled (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::reference;
    use crate::prop;
    use crate::rng::Rng;

    #[test]
    fn matmul_matches_reference_fixed() {
        let mut rng = Rng::new(400);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (1, 7, 5), (5, 7, 1), (17, 33, 9), (64, 64, 64)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let got = matmul(&a, &b);
            let want = reference::matmul(&a, &b);
            assert!(got.close_to(&want, 1e-12), "({m},{k},{n})");
        }
    }

    #[test]
    fn property_blocked_matmul_matches_reference() {
        prop::forall(
            401,
            40,
            |rng| {
                // Mix random shapes with the degenerate edges (1×n, n×1,
                // k spanning a KC boundary via the odd sizes).
                let m = prop::gen::dim(rng, 1, 48);
                let k = prop::gen::dim(rng, 1, 48);
                let n = prop::gen::dim(rng, 1, 48);
                (Mat::randn(m, k, rng), Mat::randn(k, n, rng))
            },
            |(a, b)| {
                let got = matmul(a, b);
                let want = reference::matmul(a, b);
                if !got.close_to(&want, 1e-10) {
                    return Err(format!(
                        "matmul mismatch at ({}, {}, {})",
                        a.rows, a.cols, b.cols
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_nt_tn_match_reference() {
        prop::forall(
            402,
            30,
            |rng| {
                let m = prop::gen::dim(rng, 1, 24);
                let k = prop::gen::dim(rng, 1, 24);
                let n = prop::gen::dim(rng, 1, 24);
                (Mat::randn(m, k, rng), Mat::randn(n, k, rng), Mat::randn(m, n, rng))
            },
            |(a, bt, c)| {
                // NT: a (m,k) · btᵀ (k,n).
                let got = matmul_nt(a, bt);
                let want = reference::matmul(a, &reference::transpose(bt));
                if !got.close_to(&want, 1e-10) {
                    return Err("nt mismatch".into());
                }
                // TN: aᵀ (k,m) · c' — reuse a as the (k=rows) operand pair:
                // aᵀ·c with a (m,k) viewed as (K=m rows, M=k cols), c (m,n).
                let got = matmul_tn(a, c);
                let want = reference::matmul(&reference::transpose(a), c);
                if !got.close_to(&want, 1e-10) {
                    return Err("tn mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matmul_crosses_panel_and_thread_boundaries() {
        // k > KC exercises the k-panel loop seam; m·k·n ≥ PAR_MIN_OPS with
        // m ≥ 2 exercises the pooled row split (including a ragged last
        // chunk via the odd m).  These shapes MUST stay above those
        // thresholds or the riskiest indexing paths ship untested.
        let mut rng = Rng::new(407);
        let (m, k, n) = (37, KC + 45, 112); // 37·301·112 ≈ 1.25M ≥ 1<<20
        assert!(k > KC && m * k * n >= PAR_MIN_OPS);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        assert!(matmul(&a, &b).close_to(&reference::matmul(&a, &b), 1e-10));
        // Same thresholds for the packed Aᵀ·B kernel: A (k×m), B (k×n).
        let at = Mat::randn(k, m, &mut rng);
        let bb = Mat::randn(k, n, &mut rng);
        let want = reference::matmul(&reference::transpose(&at), &bb);
        assert!(matmul_tn(&at, &bb).close_to(&want, 1e-10));
        // And the NT kernel at threaded size.
        let bt = Mat::randn(n, k, &mut rng);
        let want = reference::matmul(&a, &reference::transpose(&bt));
        assert!(matmul_nt(&a, &bt).close_to(&want, 1e-10));
    }

    #[test]
    fn gar_emit_f32_strided_crosses_pool_boundary() {
        // rows·r·(mr+1) ≥ PAR_MIN_OPS forces the pooled path of the strided
        // f32 emit; every row of the strided output must match the serial
        // per-row formula exactly (same dot kernel, same order).
        let mut rng = Rng::new(409);
        let (rows, r, mr) = (128usize, 32usize, 32usize);
        assert!(rows * r * (mr + 1) >= PAR_MIN_OPS);
        let m = r + mr;
        let (stride, off) = (m + 9, 4);
        let t: Vec<f32> = (0..rows * r).map(|_| rng.normal() as f32).collect();
        let u_hat: Vec<f32> = (0..mr * r).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0f32; rows * stride];
        gar_emit_f32(&t, rows, r, &u_hat, mr, &mut y, stride, off);
        for i in 0..rows {
            let trow = &t[i * r..(i + 1) * r];
            let yrow = &y[i * stride + off..i * stride + off + m];
            for j in 0..r {
                assert_eq!(yrow[j], trow[j], "copied factor row {i}");
            }
            for j in 0..mr {
                let want = dot_f32(trow, &u_hat[j * r..(j + 1) * r]);
                assert_eq!(yrow[r + j], want, "emitted row {i} col {j}");
            }
        }
    }

    #[test]
    fn gar_emit_crosses_thread_boundary() {
        // rows·r·(mr+1) ≥ PAR_MIN_OPS forces the pooled emit path.
        let mut rng = Rng::new(408);
        let (rows, r, mr) = (257, 64, 80);
        assert!(rows * r * (mr + 1) >= PAR_MIN_OPS);
        let t = Mat::randn(rows, r, &mut rng);
        let u_hat = Mat::randn(mr, r, &mut rng);
        let mut y = Mat::zeros(rows, r + mr);
        gar_emit(&t, &u_hat, &mut y);
        // Reference: [t | t·ûᵀ].
        let rest = reference::matmul(&t, &reference::transpose(&u_hat));
        for i in 0..rows {
            for j in 0..r {
                assert!((y[(i, j)] - t[(i, j)]).abs() == 0.0);
            }
            for j in 0..mr {
                assert!((y[(i, r + j)] - rest[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn tn_acc_accumulates() {
        let mut rng = Rng::new(403);
        let a = Mat::randn(10, 4, &mut rng);
        let b = Mat::randn(10, 6, &mut rng);
        let mut acc = Mat::randn(4, 6, &mut rng);
        let base = acc.clone();
        matmul_tn_acc(&a, &b, &mut acc);
        let want = &base + &reference::matmul(&reference::transpose(&a), &b);
        assert!(acc.close_to(&want, 1e-10));
    }

    #[test]
    fn transpose_matches_reference() {
        let mut rng = Rng::new(404);
        for &(m, n) in &[(1usize, 1usize), (3, 70), (70, 3), (65, 65)] {
            let a = Mat::randn(m, n, &mut rng);
            assert!(transpose(&a).close_to(&reference::transpose(&a), 0.0));
        }
    }

    #[test]
    fn matvec_matches_reference() {
        let mut rng = Rng::new(405);
        let a = Mat::randn(13, 29, &mut rng);
        let x: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 13];
        matvec_into(&a, &x, &mut y);
        let want = reference::matvec(&a, &x);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn f32_matmul_matches_f64_downcast() {
        let mut rng = Rng::new(406);
        let (m, k, n) = (19, 37, 11);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let a32 = a.to_f32();
        let b32 = b.to_f32();
        let mut out = vec![0f32; m * n];
        matmul_f32(&a32, &b32, m, k, n, &mut out);
        let want = reference::matmul(&a, &b);
        for (g, w) in out.iter().zip(&want.data) {
            let scale = 1.0 + w.abs();
            assert!(((*g as f64) - w).abs() < 1e-4 * scale, "{g} vs {w}");
        }
    }

    #[test]
    fn arena_reuses_buffers() {
        let mut arena = Arena::new();
        let b1 = arena.take(64);
        let p1 = b1.as_ptr() as usize;
        arena.give(b1);
        let b2 = arena.take(64);
        assert_eq!(b2.as_ptr() as usize, p1, "arena must hand back the same buffer");
        assert_eq!(b2.len(), 64);
        arena.give(b2);
        assert_eq!(arena.pooled(), 1);
    }
}
