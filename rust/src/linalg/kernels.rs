//! Native compute kernels: cache-blocked, panel-packed, multi-threaded
//! matmul (f64 and f32 paths), blocked transpose, unrolled matvec, the fused
//! GAR emit, quantized-factor variants, and a reusable scratch [`Arena`] so
//! hot-path ops stop allocating per call.
//!
//! Design (CPU, row-major):
//!
//! * **k-panel blocking** — the inner product dimension is processed in
//!   panels of [`KC`] rows of B, so the streamed B panel stays L2-resident
//!   while a block of output rows is updated.  For row-major `A·B` both
//!   operands stream contiguously, so the classic pack step reduces to
//!   panel streaming; the one kernel whose access pattern is genuinely
//!   strided — `Aᵀ·B` (gradient accumulation, covariance grams) — packs the
//!   A column panel into a thread-local contiguous buffer first.
//! * **SIMD micro-kernels with runtime dispatch** — the f32 dot/axpy inner
//!   loops live in [`super::simd`] behind a once-per-process ISA probe:
//!   AVX2+FMA on x86_64, NEON on aarch64, with the pre-SIMD scalar loops
//!   kept verbatim as the fallback and as the `simd ≡ scalar` test oracle
//!   (`FLEXRANK_SIMD=scalar` forces that tier; `_scalar`-suffixed kernels
//!   expose it in-process for benches).  The f64 kernels stay scalar —
//!   the 1e-10 `kernels ≡ reference` suite pins their summation order.
//! * **quantized factors** — `matmul_f32_q` / `gar_emit_f32_q` accept a
//!   [`QuantMat`] B/û operand (f32 identity, bf16 round-to-nearest-even,
//!   or i8 with per-column f32 scales; see [`super::quant`]) and
//!   dequantize it panel-by-panel into a thread-local 64-byte-aligned
//!   buffer during the pack step — low-precision serving tiers trade
//!   factor bandwidth for a cheap unpack, with zero steady-state
//!   allocations.
//! * **persistent-pool outer loops** — output row blocks are dispatched to
//!   the process-wide worker [`pool`](super::pool) (parked workers, atomic
//!   chunk claiming — no per-call thread spawn) above [`PAR_MIN_OPS`] MACs;
//!   below that even the ~µs pool dispatch dominates and the kernels stay
//!   serial.
//!
//! The pre-existing naive loops live on in [`super::reference`]; property
//! tests assert the two agree to 1e-10 across random and degenerate shapes.

use crate::linalg::aligned::AlignedVec;
use crate::linalg::pool;
use crate::linalg::quant::QuantMat;
use crate::linalg::simd;
use crate::linalg::Mat;

pub use crate::linalg::simd::{dot_f32, dot_f64};

/// Depth of one k-panel (B panel of `KC × n` stays cache-resident).
pub const KC: usize = 256;

/// MAC count below which kernels stay single-threaded.  With the persistent
/// pool this is the dispatch floor (~µs of wake/claim latency), an order of
/// magnitude below the old scoped-thread spawn floor of `1 << 20`.
pub const PAR_MIN_OPS: usize = 1 << 17;

/// Rows per pooled chunk for a kernel over `m` output rows and `ops` MACs;
/// `None` keeps the call single-threaded (below the dispatch floor, tiny
/// outputs, or no hardware parallelism).  `packed` kernels get one chunk
/// per pool thread (each chunk invocation packs a private panel buffer);
/// streaming kernels get ~4× finer chunks so the pool's atomic claim loop
/// load-balances ragged shapes.
fn chunk_rows(m: usize, ops: usize, packed: bool) -> Option<usize> {
    let threads = pool::size();
    if ops < PAR_MIN_OPS || threads <= 1 || m <= 1 {
        return None;
    }
    let chunks = if packed { threads } else { 4 * threads }.min(m);
    Some(m.div_ceil(chunks))
}

// ---------------------------------------------------------------------------
// Slice-level kernels, generated over the micro-kernel pair: f64 (scalar
// micro-kernels), f32 (runtime-dispatched SIMD), and a `_scalar` f32 set
// pinned to the fallback tier as the in-process bench/test oracle.
// ---------------------------------------------------------------------------

macro_rules! kernels_for {
    ($ty:ty, $dot:path, $axpy4:path, $mm:ident, $mm_rows:ident,
     $nt:ident, $nt_rows:ident, $tn_acc:ident) => {
        /// `out = A·B` with `A (m×k)`, `B (k×n)`, all row-major slices.
        pub fn $mm(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize, out: &mut [$ty]) {
            assert_eq!(a.len(), m * k, "matmul: A size");
            assert_eq!(b.len(), k * n, "matmul: B size");
            assert_eq!(out.len(), m * n, "matmul: out size");
            for o in out.iter_mut() {
                *o = 0.0;
            }
            if m == 0 || n == 0 || k == 0 {
                return;
            }
            let Some(rows_per) = chunk_rows(m, m * k * n, false) else {
                $mm_rows(a, b, k, n, 0, out);
                return;
            };
            pool::parallel_for_rows(out, m, n, rows_per, &|i0, chunk| {
                $mm_rows(a, b, k, n, i0, chunk)
            });
        }

        /// Serial worker over output rows `[i0, i0 + chunk.len()/n)`.
        fn $mm_rows(a: &[$ty], b: &[$ty], k: usize, n: usize, i0: usize, chunk: &mut [$ty]) {
            let rows = chunk.len() / n;
            let mut kb = 0;
            while kb < k {
                let kend = (kb + KC).min(k);
                let b_panel = &b[kb * n..kend * n];
                for i in 0..rows {
                    let aseg = &a[(i0 + i) * k + kb..(i0 + i) * k + kend];
                    let orow = &mut chunk[i * n..(i + 1) * n];
                    $axpy4(aseg, b_panel, n, orow);
                }
                kb += KC;
            }
        }

        /// `out = A·Bᵀ` with `A (m×k)`, `B (n×k)` — both stream contiguous
        /// rows, so each output entry is one unrolled dot product.
        pub fn $nt(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize, out: &mut [$ty]) {
            assert_eq!(a.len(), m * k, "matmul_nt: A size");
            assert_eq!(b.len(), n * k, "matmul_nt: B size");
            assert_eq!(out.len(), m * n, "matmul_nt: out size");
            if m == 0 || n == 0 {
                return;
            }
            if k == 0 {
                for o in out.iter_mut() {
                    *o = 0.0;
                }
                return;
            }
            let Some(rows_per) = chunk_rows(m, m * k * n, false) else {
                $nt_rows(a, b, k, n, 0, out);
                return;
            };
            pool::parallel_for_rows(out, m, n, rows_per, &|i0, chunk| {
                $nt_rows(a, b, k, n, i0, chunk)
            });
        }

        fn $nt_rows(a: &[$ty], b: &[$ty], k: usize, n: usize, i0: usize, chunk: &mut [$ty]) {
            let rows = chunk.len() / n;
            for i in 0..rows {
                let arow = &a[(i0 + i) * k..(i0 + i + 1) * k];
                let orow = &mut chunk[i * n..(i + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = $dot(arow, &b[j * k..(j + 1) * k]);
                }
            }
        }

        /// `out += Aᵀ·B` with `A (k×m)`, `B (k×n)` — the one layout where A
        /// access is column-strided, so each worker packs its A column panel
        /// into a contiguous buffer before running the axpy micro-kernel.
        pub fn $tn_acc(a: &[$ty], b: &[$ty], k: usize, m: usize, n: usize, out: &mut [$ty]) {
            assert_eq!(a.len(), k * m, "matmul_tn: A size");
            assert_eq!(b.len(), k * n, "matmul_tn: B size");
            assert_eq!(out.len(), m * n, "matmul_tn: out size");
            if m == 0 || n == 0 || k == 0 {
                return;
            }
            let worker = |i0: usize, chunk: &mut [$ty]| {
                let rows = chunk.len() / n;
                let mut pack = vec![0.0; KC.min(k) * rows];
                let mut kb = 0;
                while kb < k {
                    let kend = (kb + KC).min(k);
                    let klen = kend - kb;
                    // Pack A[kb..kend, i0..i0+rows] transposed: row i of the
                    // pack holds column (i0+i) of A over this k-panel.
                    for i in 0..rows {
                        let prow = &mut pack[i * klen..(i + 1) * klen];
                        for (kk, p) in prow.iter_mut().enumerate() {
                            *p = a[(kb + kk) * m + i0 + i];
                        }
                    }
                    let b_panel = &b[kb * n..kend * n];
                    for i in 0..rows {
                        let aseg = &pack[i * klen..(i + 1) * klen];
                        let orow = &mut chunk[i * n..(i + 1) * n];
                        $axpy4(aseg, b_panel, n, orow);
                    }
                    kb += KC;
                }
            };
            // One chunk per pool thread: every chunk invocation packs its
            // own A-panel buffer, so finer chunking would just re-pack.
            let Some(rows_per) = chunk_rows(m, m * k * n, true) else {
                worker(0, out);
                return;
            };
            pool::parallel_for_rows(out, m, n, rows_per, &worker);
        }
    };
}

kernels_for!(f64, simd::dot_f64, simd::axpy4_f64, matmul_f64, mm_rows_f64, matmul_nt_f64, nt_rows_f64, matmul_tn_acc_f64);
kernels_for!(f32, simd::dot_f32, simd::axpy4_f32, matmul_f32, mm_rows_f32, matmul_nt_f32, nt_rows_f32, matmul_tn_acc_f32);
kernels_for!(f32, simd::dot_f32_scalar, simd::axpy4_f32_scalar, matmul_f32_scalar, mm_rows_f32_scalar, matmul_nt_f32_scalar, nt_rows_f32_scalar, matmul_tn_acc_f32_scalar);

// ---------------------------------------------------------------------------
// Mat-level wrappers (f64 path used by linalg/nn/flexrank).
// ---------------------------------------------------------------------------

/// Blocked parallel `a · b`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut out);
    out
}

/// Allocation-free `out = a · b` (out must be pre-sized `a.rows × b.cols`).
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    assert_eq!((out.rows, out.cols), (a.rows, b.cols), "matmul out dims");
    matmul_f64(&a.data, &b.data, a.rows, a.cols, b.cols, &mut out.data);
}

/// `a · bᵀ` without materializing the transpose.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt dim mismatch");
    let mut out = Mat::zeros(a.rows, b.rows);
    matmul_nt_f64(&a.data, &b.data, a.rows, a.cols, b.rows, &mut out.data);
    out
}

/// `aᵀ · b` without materializing the transpose (panel-packed).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols, b.cols);
    matmul_tn_acc(a, b, &mut out);
    out
}

/// `out += aᵀ · b` (gram/gradient accumulation without temporaries).
pub fn matmul_tn_acc(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.rows, b.rows, "matmul_tn dim mismatch");
    assert_eq!((out.rows, out.cols), (a.cols, b.cols), "matmul_tn out dims");
    matmul_tn_acc_f64(&a.data, &b.data, a.rows, a.cols, b.cols, &mut out.data);
}

/// Tile edge for the blocked transpose (fits two f64 tiles in L1).
const TB: usize = 32;

/// Cache-blocked transpose.
pub fn transpose(a: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols, a.rows);
    for ib in (0..a.rows).step_by(TB) {
        let iend = (ib + TB).min(a.rows);
        for jb in (0..a.cols).step_by(TB) {
            let jend = (jb + TB).min(a.cols);
            for i in ib..iend {
                let arow = &a.data[i * a.cols..(i + 1) * a.cols];
                for j in jb..jend {
                    out.data[j * a.rows + i] = arow[j];
                }
            }
        }
    }
    out
}

/// Allocation-free matvec: `y = a · x`.
pub fn matvec_into(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.cols, "matvec dim mismatch");
    assert_eq!(y.len(), a.rows, "matvec out dims");
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = dot_f64(&a.data[i * a.cols..(i + 1) * a.cols], x);
    }
}

// ---------------------------------------------------------------------------
// Fused GAR emit
// ---------------------------------------------------------------------------

macro_rules! gar_emit_for {
    ($ty:ty, $dot:path, $name:ident) => {
        /// Fused GAR emit with an output column offset and stride: writes
        /// `[t, t·ûᵀ]` into `y[row*stride + off ..]` — no intermediate
        /// `rest` matrix, no second pass over the output, and layer outputs
        /// stream straight into a wider activation buffer.  Fans out over
        /// the worker pool above [`PAR_MIN_OPS`] MACs like the matmul
        /// kernels.
        #[allow(clippy::too_many_arguments)]
        pub fn $name(
            t: &[$ty],
            rows: usize,
            r: usize,
            u_hat: &[$ty],
            mr: usize,
            y: &mut [$ty],
            stride: usize,
            off: usize,
        ) {
            let m = r + mr;
            assert_eq!(t.len(), rows * r, "gar_emit: t size");
            assert_eq!(u_hat.len(), mr * r, "gar_emit: û size");
            assert!(off + m <= stride || (rows == 0), "gar_emit: stride too small");
            assert!(y.len() >= rows * stride, "gar_emit: out size");
            if rows == 0 || m == 0 {
                return;
            }
            // `chunk` starts at absolute row `i0` and holds whole strided rows.
            let worker = |i0: usize, chunk: &mut [$ty]| {
                for i in 0..chunk.len() / stride {
                    let trow = &t[(i0 + i) * r..(i0 + i + 1) * r];
                    let yrow = &mut chunk[i * stride + off..i * stride + off + m];
                    yrow[..r].copy_from_slice(trow);
                    for (j, o) in yrow[r..].iter_mut().enumerate() {
                        *o = $dot(trow, &u_hat[j * r..(j + 1) * r]);
                    }
                }
            };
            let Some(rows_per) = chunk_rows(rows, rows * r * (mr + 1), false) else {
                worker(0, &mut y[..rows * stride]);
                return;
            };
            pool::parallel_for_rows(y, rows, stride, rows_per, &worker);
        }
    };
}

gar_emit_for!(f64, simd::dot_f64, gar_emit_f64);
gar_emit_for!(f32, simd::dot_f32, gar_emit_f32);
gar_emit_for!(f32, simd::dot_f32_scalar, gar_emit_f32_scalar);

/// Fused GAR output stage: given `t = x·Ṽ` `(B × r)` and `û (m−r × r)`,
/// stream `y = [t, t·ûᵀ]` `(B × m)` directly.  Mat-level wrapper over
/// [`gar_emit_f64`].
pub fn gar_emit(t: &Mat, u_hat: &Mat, y: &mut Mat) {
    let r = t.cols;
    let mr = u_hat.rows;
    let m = r + mr;
    assert!(mr == 0 || u_hat.cols == r, "gar_emit: û rank mismatch");
    assert_eq!((y.rows, y.cols), (t.rows, m), "gar_emit: out dims");
    gar_emit_f64(&t.data, t.rows, r, &u_hat.data, mr, &mut y.data, m, 0);
}

// ---------------------------------------------------------------------------
// Quantized-factor kernels: the B / û operand is a [`QuantMat`] that gets
// dequantized panel-by-panel into a thread-local aligned buffer during the
// pack step.  f32-stored operands short-circuit to the plain kernels.
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread dequantization panel, reused across calls (persistent
    /// pool workers keep theirs alive, so steady-state serving performs
    /// zero allocations here after warmup).
    static DEQ_PANEL: std::cell::RefCell<AlignedVec<f32>> =
        std::cell::RefCell::new(AlignedVec::new());
}

/// `out = A·B` where B `(k×n)` is stored quantized.  Identical panel/pool
/// structure to [`matmul_f32`], with the B panel dequantized in the pack
/// step.
pub fn matmul_f32_q(a: &[f32], b: &QuantMat, m: usize, k: usize, n: usize, out: &mut [f32]) {
    if let Some(bf) = b.as_f32() {
        matmul_f32(a, bf, m, k, n, out);
        return;
    }
    assert_eq!(a.len(), m * k, "matmul_f32_q: A size");
    assert_eq!((b.rows, b.cols), (k, n), "matmul_f32_q: B dims");
    assert_eq!(out.len(), m * n, "matmul_f32_q: out size");
    for o in out.iter_mut() {
        *o = 0.0;
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let worker = |i0: usize, chunk: &mut [f32]| {
        let rows = chunk.len() / n;
        DEQ_PANEL.with(|cell| {
            let mut panel = cell.borrow_mut();
            panel.resize(KC.min(k) * n, 0.0);
            let mut kb = 0;
            while kb < k {
                let kend = (kb + KC).min(k);
                let klen = kend - kb;
                let b_panel = &mut panel[..klen * n];
                b.dequant_rows_into(kb, klen, b_panel);
                for i in 0..rows {
                    let aseg = &a[(i0 + i) * k + kb..(i0 + i) * k + kend];
                    let orow = &mut chunk[i * n..(i + 1) * n];
                    simd::axpy4_f32(aseg, b_panel, n, orow);
                }
                kb += KC;
            }
        });
    };
    // One chunk per pool thread: each invocation dequantizes its own panel.
    let Some(rows_per) = chunk_rows(m, m * k * n, true) else {
        worker(0, out);
        return;
    };
    pool::parallel_for_rows(out, m, n, rows_per, &worker);
}

/// Strided f32 GAR emit where `û (mr×r)` is stored quantized: each worker
/// dequantizes û into its thread-local panel once per chunk, then emits
/// with the same dispatched dot kernel as [`gar_emit_f32`].
pub fn gar_emit_f32_q(
    t: &[f32],
    rows: usize,
    r: usize,
    u_hat: &QuantMat,
    y: &mut [f32],
    stride: usize,
    off: usize,
) {
    if let Some(uf) = u_hat.as_f32() {
        gar_emit_f32(t, rows, r, uf, u_hat.rows, y, stride, off);
        return;
    }
    let mr = u_hat.rows;
    assert!(mr == 0 || u_hat.cols == r, "gar_emit_f32_q: û rank mismatch");
    let m = r + mr;
    assert_eq!(t.len(), rows * r, "gar_emit_f32_q: t size");
    assert!(off + m <= stride || (rows == 0), "gar_emit_f32_q: stride too small");
    assert!(y.len() >= rows * stride, "gar_emit_f32_q: out size");
    if rows == 0 || m == 0 {
        return;
    }
    let worker = |i0: usize, chunk: &mut [f32]| {
        DEQ_PANEL.with(|cell| {
            let mut panel = cell.borrow_mut();
            panel.resize(mr * r, 0.0);
            u_hat.dequant_rows_into(0, mr, &mut panel[..mr * r]);
            for i in 0..chunk.len() / stride {
                let trow = &t[(i0 + i) * r..(i0 + i + 1) * r];
                let yrow = &mut chunk[i * stride + off..i * stride + off + m];
                yrow[..r].copy_from_slice(trow);
                for (j, o) in yrow[r..].iter_mut().enumerate() {
                    *o = simd::dot_f32(trow, &panel[j * r..(j + 1) * r]);
                }
            }
        });
    };
    // One chunk per pool thread: each invocation dequantizes û privately.
    let Some(rows_per) = chunk_rows(rows, rows * r * (mr + 1), true) else {
        worker(0, &mut y[..rows * stride]);
        return;
    };
    pool::parallel_for_rows(y, rows, stride, rows_per, &worker);
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Reusable pool of 64-byte-aligned f64 buffers: `take` hands out a buffer
/// resized to the request, `give` returns it for reuse.  After warmup, a
/// fixed take/give pattern performs zero heap allocations.
#[derive(Debug, Default)]
pub struct Arena {
    free: Vec<AlignedVec<f64>>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena { free: Vec::new() }
    }

    /// Check out a buffer of exactly `len` elements (contents unspecified —
    /// callers overwrite).  Reuses the most recently returned buffer, so a
    /// fixed take/give cycle settles on stable allocations.
    pub fn take(&mut self, len: usize) -> AlignedVec<f64> {
        let mut buf = self.free.pop().unwrap_or_default();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool.
    pub fn give(&mut self, buf: AlignedVec<f64>) {
        self.free.push(buf);
    }

    /// Buffers currently pooled (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::quant::Precision;
    use crate::linalg::reference;
    use crate::prop;
    use crate::rng::Rng;

    #[test]
    fn matmul_matches_reference_fixed() {
        let mut rng = Rng::new(400);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (1, 7, 5), (5, 7, 1), (17, 33, 9), (64, 64, 64)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let got = matmul(&a, &b);
            let want = reference::matmul(&a, &b);
            assert!(got.close_to(&want, 1e-12), "({m},{k},{n})");
        }
    }

    #[test]
    fn property_blocked_matmul_matches_reference() {
        prop::forall(
            401,
            40,
            |rng| {
                // Mix random shapes with the degenerate edges (1×n, n×1,
                // k spanning a KC boundary via the odd sizes).
                let m = prop::gen::dim(rng, 1, 48);
                let k = prop::gen::dim(rng, 1, 48);
                let n = prop::gen::dim(rng, 1, 48);
                (Mat::randn(m, k, rng), Mat::randn(k, n, rng))
            },
            |(a, b)| {
                let got = matmul(a, b);
                let want = reference::matmul(a, b);
                if !got.close_to(&want, 1e-10) {
                    return Err(format!(
                        "matmul mismatch at ({}, {}, {})",
                        a.rows, a.cols, b.cols
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_nt_tn_match_reference() {
        prop::forall(
            402,
            30,
            |rng| {
                let m = prop::gen::dim(rng, 1, 24);
                let k = prop::gen::dim(rng, 1, 24);
                let n = prop::gen::dim(rng, 1, 24);
                (Mat::randn(m, k, rng), Mat::randn(n, k, rng), Mat::randn(m, n, rng))
            },
            |(a, bt, c)| {
                // NT: a (m,k) · btᵀ (k,n).
                let got = matmul_nt(a, bt);
                let want = reference::matmul(a, &reference::transpose(bt));
                if !got.close_to(&want, 1e-10) {
                    return Err("nt mismatch".into());
                }
                // TN: aᵀ (k,m) · c' — reuse a as the (k=rows) operand pair:
                // aᵀ·c with a (m,k) viewed as (K=m rows, M=k cols), c (m,n).
                let got = matmul_tn(a, c);
                let want = reference::matmul(&reference::transpose(a), c);
                if !got.close_to(&want, 1e-10) {
                    return Err("tn mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_f32_simd_matches_scalar_oracle() {
        // The dispatched f32 kernels must agree with the `_scalar` set
        // (pre-SIMD loops) over random + degenerate shapes, including
        // lengths off the 8/4-lane vector widths.  FMA reassociation means
        // agreement is relative, not bit-exact.
        prop::forall(
            410,
            40,
            |rng| {
                let m = prop::gen::dim(rng, 1, 40);
                let k = prop::gen::dim(rng, 1, 70);
                let n = prop::gen::dim(rng, 1, 40);
                let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
                let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
                let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
                let at: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
                (a, b, bt, at, m, k, n)
            },
            |(a, b, bt, at, m, k, n)| {
                let (m, k, n) = (*m, *k, *n);
                let mut got = vec![0f32; m * n];
                let mut want = vec![0f32; m * n];
                matmul_f32(a, b, m, k, n, &mut got);
                matmul_f32_scalar(a, b, m, k, n, &mut want);
                prop::close(&got, &want, 1e-4)
                    .map_err(|e| format!("matmul ({m},{k},{n}): {e}"))?;
                matmul_nt_f32(a, bt, m, k, n, &mut got);
                matmul_nt_f32_scalar(a, bt, m, k, n, &mut want);
                prop::close(&got, &want, 1e-4)
                    .map_err(|e| format!("nt ({m},{k},{n}): {e}"))?;
                got.iter_mut().for_each(|x| *x = 0.0);
                want.iter_mut().for_each(|x| *x = 0.0);
                matmul_tn_acc_f32(at, b, k, m, n, &mut got);
                matmul_tn_acc_f32_scalar(at, b, k, m, n, &mut want);
                prop::close(&got, &want, 1e-4)
                    .map_err(|e| format!("tn ({m},{k},{n}): {e}"))?;
                Ok(())
            },
        );
    }

    #[test]
    fn matmul_crosses_panel_and_thread_boundaries() {
        // k > KC exercises the k-panel loop seam; m·k·n ≥ PAR_MIN_OPS with
        // m ≥ 2 exercises the pooled row split (including a ragged last
        // chunk via the odd m).  These shapes MUST stay above those
        // thresholds or the riskiest indexing paths ship untested.
        let mut rng = Rng::new(407);
        let (m, k, n) = (37, KC + 45, 112); // 37·301·112 ≈ 1.25M ≥ 1<<20
        assert!(k > KC && m * k * n >= PAR_MIN_OPS);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        assert!(matmul(&a, &b).close_to(&reference::matmul(&a, &b), 1e-10));
        // Same thresholds for the packed Aᵀ·B kernel: A (k×m), B (k×n).
        let at = Mat::randn(k, m, &mut rng);
        let bb = Mat::randn(k, n, &mut rng);
        let want = reference::matmul(&reference::transpose(&at), &bb);
        assert!(matmul_tn(&at, &bb).close_to(&want, 1e-10));
        // And the NT kernel at threaded size.
        let bt = Mat::randn(n, k, &mut rng);
        let want = reference::matmul(&a, &reference::transpose(&bt));
        assert!(matmul_nt(&a, &bt).close_to(&want, 1e-10));
    }

    #[test]
    fn f32_simd_crosses_panel_and_thread_boundaries() {
        // The dispatched f32 path at pooled + panel-seam size, against the
        // scalar oracle.
        let mut rng = Rng::new(411);
        let (m, k, n) = (37, KC + 45, 112);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0f32; m * n];
        let mut want = vec![0f32; m * n];
        matmul_f32(&a, &b, m, k, n, &mut got);
        matmul_f32_scalar(&a, &b, m, k, n, &mut want);
        for (g, w) in got.iter().zip(&want) {
            // k ≈ 300 accumulations: allow a k-scaled f32 tolerance.
            assert!((g - w).abs() <= 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // NT and TN variants at the same size.
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0f32; m * n];
        let mut want = vec![0f32; m * n];
        matmul_nt_f32(&a, &bt, m, k, n, &mut got);
        matmul_nt_f32_scalar(&a, &bt, m, k, n, &mut want);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-3 * (1.0 + w.abs()), "nt {g} vs {w}");
        }
        let at: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0f32; m * n];
        let mut want = vec![0f32; m * n];
        matmul_tn_acc_f32(&at, &b, k, m, n, &mut got);
        matmul_tn_acc_f32_scalar(&at, &b, k, m, n, &mut want);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-3 * (1.0 + w.abs()), "tn {g} vs {w}");
        }
    }

    #[test]
    fn gar_emit_f32_strided_crosses_pool_boundary() {
        // rows·r·(mr+1) ≥ PAR_MIN_OPS forces the pooled path of the strided
        // f32 emit; every row of the strided output must match the serial
        // per-row formula exactly (same dot kernel, same order).
        let mut rng = Rng::new(409);
        let (rows, r, mr) = (128usize, 32usize, 32usize);
        assert!(rows * r * (mr + 1) >= PAR_MIN_OPS);
        let m = r + mr;
        let (stride, off) = (m + 9, 4);
        let t: Vec<f32> = (0..rows * r).map(|_| rng.normal() as f32).collect();
        let u_hat: Vec<f32> = (0..mr * r).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0f32; rows * stride];
        gar_emit_f32(&t, rows, r, &u_hat, mr, &mut y, stride, off);
        for i in 0..rows {
            let trow = &t[i * r..(i + 1) * r];
            let yrow = &y[i * stride + off..i * stride + off + m];
            for j in 0..r {
                assert_eq!(yrow[j], trow[j], "copied factor row {i}");
            }
            for j in 0..mr {
                let want = dot_f32(trow, &u_hat[j * r..(j + 1) * r]);
                assert_eq!(yrow[r + j], want, "emitted row {i} col {j}");
            }
        }
        // The scalar-pinned emit agrees with its own dot oracle the same way.
        let mut ys = vec![0f32; rows * stride];
        gar_emit_f32_scalar(&t, rows, r, &u_hat, mr, &mut ys, stride, off);
        for i in 0..rows {
            let trow = &t[i * r..(i + 1) * r];
            for j in 0..mr {
                let want = simd::dot_f32_scalar(trow, &u_hat[j * r..(j + 1) * r]);
                assert_eq!(ys[i * stride + off + r + j], want, "scalar emit row {i}");
            }
        }
    }

    #[test]
    fn gar_emit_crosses_thread_boundary() {
        // rows·r·(mr+1) ≥ PAR_MIN_OPS forces the pooled emit path.
        let mut rng = Rng::new(408);
        let (rows, r, mr) = (257, 64, 80);
        assert!(rows * r * (mr + 1) >= PAR_MIN_OPS);
        let t = Mat::randn(rows, r, &mut rng);
        let u_hat = Mat::randn(mr, r, &mut rng);
        let mut y = Mat::zeros(rows, r + mr);
        gar_emit(&t, &u_hat, &mut y);
        // Reference: [t | t·ûᵀ].
        let rest = reference::matmul(&t, &reference::transpose(&u_hat));
        for i in 0..rows {
            for j in 0..r {
                assert!((y[(i, j)] - t[(i, j)]).abs() == 0.0);
            }
            for j in 0..mr {
                assert!((y[(i, r + j)] - rest[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn quantized_matmul_tracks_f32_within_precision_bounds() {
        let mut rng = Rng::new(412);
        // Crosses both the pool floor and a k-panel seam so the panel
        // dequant runs on worker threads with kb > 0.
        let (m, k, n) = (64usize, KC + 21, 48usize);
        assert!(m * k * n >= PAR_MIN_OPS);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0f32; m * n];
        matmul_f32(&a, &b, m, k, n, &mut want);

        // f32-quantized operand short-circuits to the exact same kernel.
        let qf = QuantMat::from_f32(&b, k, n, Precision::F32);
        let mut got = vec![0f32; m * n];
        matmul_f32_q(&a, &qf, m, k, n, &mut got);
        assert_eq!(got, want, "f32 QuantMat must be the identity path");

        // bf16: ~2⁻⁸ relative per factor element; the dot over k≈280 noisy
        // terms keeps relative error well under 1e-1 at |out| scale.
        let qb = QuantMat::from_f32(&b, k, n, Precision::Bf16);
        let mut got = vec![0f32; m * n];
        matmul_f32_q(&a, &qb, m, k, n, &mut got);
        let scale: f32 = (k as f32).sqrt();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 4e-2 * scale.max(w.abs()), "bf16 {g} vs {w}");
        }

        // i8: half-step error per element, still bounded after the dot.
        let qi = QuantMat::from_f32(&b, k, n, Precision::I8);
        let mut got = vec![0f32; m * n];
        matmul_f32_q(&a, &qi, m, k, n, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 2e-1 * scale.max(w.abs()), "i8 {g} vs {w}");
        }
    }

    #[test]
    fn quantized_gar_emit_tracks_f32() {
        let mut rng = Rng::new(413);
        let (rows, r, mr) = (128usize, 32usize, 32usize);
        assert!(rows * r * (mr + 1) >= PAR_MIN_OPS);
        let m = r + mr;
        let (stride, off) = (m + 5, 3);
        let t: Vec<f32> = (0..rows * r).map(|_| rng.normal() as f32).collect();
        let u: Vec<f32> = (0..mr * r).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0f32; rows * stride];
        gar_emit_f32(&t, rows, r, &u, mr, &mut want, stride, off);

        let qf = QuantMat::from_f32(&u, mr, r, Precision::F32);
        let mut got = vec![0f32; rows * stride];
        gar_emit_f32_q(&t, rows, r, &qf, &mut got, stride, off);
        assert_eq!(got, want, "f32 QuantMat emit must be the identity path");

        for (prec, tol) in [(Precision::Bf16, 4e-2f32), (Precision::I8, 2e-1)] {
            let q = QuantMat::from_f32(&u, mr, r, prec);
            let mut got = vec![0f32; rows * stride];
            gar_emit_f32_q(&t, rows, r, &q, &mut got, stride, off);
            let scale = (r as f32).sqrt();
            for i in 0..rows {
                // The passthrough columns must be exact at any precision.
                for j in 0..r {
                    assert_eq!(got[i * stride + off + j], t[i * r + j], "{prec:?} row {i}");
                }
                for j in 0..mr {
                    let g = got[i * stride + off + r + j];
                    let w = want[i * stride + off + r + j];
                    assert!((g - w).abs() <= tol * scale.max(w.abs()), "{prec:?}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn tn_acc_accumulates() {
        let mut rng = Rng::new(403);
        let a = Mat::randn(10, 4, &mut rng);
        let b = Mat::randn(10, 6, &mut rng);
        let mut acc = Mat::randn(4, 6, &mut rng);
        let base = acc.clone();
        matmul_tn_acc(&a, &b, &mut acc);
        let want = &base + &reference::matmul(&reference::transpose(&a), &b);
        assert!(acc.close_to(&want, 1e-10));
    }

    #[test]
    fn transpose_matches_reference() {
        let mut rng = Rng::new(404);
        for &(m, n) in &[(1usize, 1usize), (3, 70), (70, 3), (65, 65)] {
            let a = Mat::randn(m, n, &mut rng);
            assert!(transpose(&a).close_to(&reference::transpose(&a), 0.0));
        }
    }

    #[test]
    fn matvec_matches_reference() {
        let mut rng = Rng::new(405);
        let a = Mat::randn(13, 29, &mut rng);
        let x: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 13];
        matvec_into(&a, &x, &mut y);
        let want = reference::matvec(&a, &x);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn f32_matmul_matches_f64_downcast() {
        let mut rng = Rng::new(406);
        let (m, k, n) = (19, 37, 11);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let a32 = a.to_f32();
        let b32 = b.to_f32();
        let mut out = vec![0f32; m * n];
        matmul_f32(&a32, &b32, m, k, n, &mut out);
        let want = reference::matmul(&a, &b);
        for (g, w) in out.iter().zip(&want.data) {
            let scale = 1.0 + w.abs();
            assert!(((*g as f64) - w).abs() < 1e-4 * scale, "{g} vs {w}");
        }
    }

    #[test]
    fn arena_reuses_buffers() {
        let mut arena = Arena::new();
        let b1 = arena.take(64);
        assert_eq!(b1.as_ptr() as usize % crate::linalg::aligned::ALIGN, 0);
        let p1 = b1.as_ptr() as usize;
        arena.give(b1);
        let b2 = arena.take(64);
        assert_eq!(b2.as_ptr() as usize, p1, "arena must hand back the same buffer");
        assert_eq!(b2.len(), 64);
        arena.give(b2);
        assert_eq!(arena.pooled(), 1);
    }
}
