//! Per-tier quantized storage for the nested low-rank factors.
//!
//! Serving tiers trade factor bandwidth for a cheap unpack in the matmul
//! panel-pack step:
//!
//! * **`f32`** — identity storage; kernels take the slice directly (the
//!   quantized entry points short-circuit to the plain f32 kernels).
//! * **`bf16`** — round-to-nearest-even truncation of the top 16 bits
//!   (8-bit mantissa, full f32 exponent range): 2× less factor traffic at
//!   ≲2⁻⁸ relative error.  The high-accuracy quantized option.
//! * **`i8`** — symmetric per-**column** scales `s_j = max_i |a_ij| / 127`
//!   with round-to-nearest values clamped to ±127: 4× less traffic at
//!   ≤ s_j/2 absolute error per element.  Columns of the stored factor are
//!   rank directions (`Ṽ (n×r)`, `û (m−r×r)` are both stored row-major
//!   with `r` columns), so each rank direction gets its own scale.
//!
//! Dequantization happens inside the kernels' k-panel pack step (see
//! [`crate::linalg::kernels::matmul_f32_q`]) into thread-local reused
//! buffers — steady-state serving stays allocation-free.

use anyhow::{bail, Result};

use crate::linalg::aligned::AlignedVec;

/// Storage precision of one serving tier's factor set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Bf16,
    I8,
}

impl Precision {
    /// Parse the configs/profiles.json spelling (`"f32" | "bf16" | "i8"`).
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f32" => Precision::F32,
            "bf16" => Precision::Bf16,
            "i8" => Precision::I8,
            other => bail!("unknown precision '{other}' (expected f32 | bf16 | i8)"),
        })
    }

    /// The canonical spelling, round-tripping through [`Precision::parse`].
    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::I8 => "i8",
        }
    }

    /// Storage bytes per element (excluding per-column scales).
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 => 2,
            Precision::I8 => 1,
        }
    }
}

/// bf16 bit pattern of `x`, round-to-nearest-even.
fn bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    (bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) >> 16) as u16
}

fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

#[derive(Debug, Clone)]
enum Store {
    F32(AlignedVec<f32>),
    Bf16(AlignedVec<u16>),
    I8 { q: AlignedVec<i8>, scale: AlignedVec<f32> },
}

/// A row-major matrix stored at a chosen [`Precision`], dequantized
/// row-panel-at-a-time by the consuming kernels.
#[derive(Debug, Clone)]
pub struct QuantMat {
    pub rows: usize,
    pub cols: usize,
    store: Store,
}

impl QuantMat {
    /// Quantize a row-major `rows × cols` slice to `prec`.
    pub fn from_f32(a: &[f32], rows: usize, cols: usize, prec: Precision) -> QuantMat {
        assert_eq!(a.len(), rows * cols, "QuantMat: data size");
        let store = match prec {
            Precision::F32 => Store::F32(AlignedVec::from_slice(a)),
            Precision::Bf16 => {
                let mut v: AlignedVec<u16> = AlignedVec::zeroed(a.len());
                for (d, &x) in v.iter_mut().zip(a) {
                    *d = bf16_bits(x);
                }
                Store::Bf16(v)
            }
            Precision::I8 => {
                let mut scale: AlignedVec<f32> = AlignedVec::zeroed(cols);
                for (j, s) in scale.iter_mut().enumerate() {
                    let mut mx = 0f32;
                    for i in 0..rows {
                        mx = mx.max(a[i * cols + j].abs());
                    }
                    *s = if mx > 0.0 { mx / 127.0 } else { 1.0 };
                }
                let mut q: AlignedVec<i8> = AlignedVec::zeroed(a.len());
                for i in 0..rows {
                    for j in 0..cols {
                        let v = (a[i * cols + j] / scale[j]).round().clamp(-127.0, 127.0);
                        q[i * cols + j] = v as i8;
                    }
                }
                Store::I8 { q, scale }
            }
        };
        QuantMat { rows, cols, store }
    }

    pub fn precision(&self) -> Precision {
        match self.store {
            Store::F32(_) => Precision::F32,
            Store::Bf16(_) => Precision::Bf16,
            Store::I8 { .. } => Precision::I8,
        }
    }

    /// Direct slice access — `Some` only for identity (f32) storage, the
    /// kernels' short-circuit past the dequant pack step.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match &self.store {
            Store::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn n_elems(&self) -> usize {
        self.rows * self.cols
    }

    /// Bytes this factor actually occupies (values + per-column scales).
    pub fn stored_bytes(&self) -> usize {
        let scales = match self.store {
            Store::I8 { .. } => self.cols * 4,
            _ => 0,
        };
        self.n_elems() * self.precision().bytes_per_elem() + scales
    }

    /// Dequantize rows `[row0, row0 + nrows)` into `out` (`nrows × cols`,
    /// row-major).  This is the kernels' panel-pack step.
    pub fn dequant_rows_into(&self, row0: usize, nrows: usize, out: &mut [f32]) {
        let c = self.cols;
        assert!(row0 + nrows <= self.rows, "QuantMat: row range");
        assert_eq!(out.len(), nrows * c, "QuantMat: dequant out size");
        match &self.store {
            Store::F32(v) => out.copy_from_slice(&v[row0 * c..(row0 + nrows) * c]),
            Store::Bf16(v) => {
                for (o, &b) in out.iter_mut().zip(&v[row0 * c..(row0 + nrows) * c]) {
                    *o = bf16_to_f32(b);
                }
            }
            Store::I8 { q, scale } => {
                for i in 0..nrows {
                    let qrow = &q[(row0 + i) * c..(row0 + i + 1) * c];
                    let orow = &mut out[i * c..(i + 1) * c];
                    for ((o, &qq), &s) in orow.iter_mut().zip(qrow).zip(scale.iter()) {
                        *o = qq as f32 * s;
                    }
                }
            }
        }
    }

    /// Full dequantization (tests/diagnostics — hot paths use the panel
    /// form).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n_elems()];
        if self.rows > 0 {
            self.dequant_rows_into(0, self.rows, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn precision_labels_round_trip() {
        for p in [Precision::F32, Precision::Bf16, Precision::I8] {
            assert_eq!(Precision::parse(p.label()).unwrap(), p);
        }
        assert!(Precision::parse("fp8").is_err());
        assert_eq!(Precision::F32.bytes_per_elem(), 4);
        assert_eq!(Precision::Bf16.bytes_per_elem(), 2);
        assert_eq!(Precision::I8.bytes_per_elem(), 1);
    }

    #[test]
    fn f32_storage_is_identity() {
        let mut rng = Rng::new(910);
        let a: Vec<f32> = (0..6 * 5).map(|_| rng.normal() as f32).collect();
        let q = QuantMat::from_f32(&a, 6, 5, Precision::F32);
        assert_eq!(q.as_f32().unwrap(), &a[..]);
        assert_eq!(q.to_f32_vec(), a);
        assert_eq!(q.stored_bytes(), 6 * 5 * 4);
    }

    #[test]
    fn i8_round_trip_error_is_bounded_per_column() {
        // |deq − a| ≤ s_j/2 per element, with s_j = max_i |a_ij| / 127.
        let mut rng = Rng::new(911);
        let (rows, cols) = (37, 9);
        let mut a: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        for i in 0..rows {
            a[i * cols + 4] = 0.0; // degenerate zero column
            a[i * cols + 5] *= 100.0; // column scale must adapt per column
        }
        let q = QuantMat::from_f32(&a, rows, cols, Precision::I8);
        assert!(q.as_f32().is_none());
        assert_eq!(q.stored_bytes(), rows * cols + cols * 4);
        let deq = q.to_f32_vec();
        for j in 0..cols {
            let col_max = (0..rows).map(|i| a[i * cols + j].abs()).fold(0f32, f32::max);
            let s = if col_max > 0.0 { col_max / 127.0 } else { 1.0 };
            for i in 0..rows {
                let err = (deq[i * cols + j] - a[i * cols + j]).abs();
                // Half a quantization step, plus f32 slack for quotients
                // that land within rounding error of a tie boundary.
                assert!(
                    err <= 0.5 * s * (1.0 + 1e-4) + 1e-7,
                    "col {j} row {i}: err {err} vs half-step {}",
                    0.5 * s
                );
            }
        }
        // The zero column must reconstruct exactly.
        for i in 0..rows {
            assert_eq!(deq[i * cols + 4], 0.0);
        }
    }

    #[test]
    fn bf16_round_trip_error_is_relative() {
        let mut rng = Rng::new(912);
        let a: Vec<f32> = (0..300).map(|_| (rng.normal() * 10.0) as f32).collect();
        let q = QuantMat::from_f32(&a, 30, 10, Precision::Bf16);
        let deq = q.to_f32_vec();
        for (d, &x) in deq.iter().zip(&a) {
            // 8 mantissa bits + RNE → half-ulp ≤ 2⁻⁹ relative.
            assert!(
                (d - x).abs() <= x.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE,
                "{d} vs {x}"
            );
        }
        // RNE: exactly-representable values survive, and ties go to even.
        let exact = [1.0f32, -2.5, 0.0, 0.15625];
        let q = QuantMat::from_f32(&exact, 1, 4, Precision::Bf16);
        assert_eq!(q.to_f32_vec(), exact);
    }

    #[test]
    fn panel_dequant_matches_full_dequant() {
        let mut rng = Rng::new(913);
        let (rows, cols) = (11, 7);
        let a: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        for prec in [Precision::F32, Precision::Bf16, Precision::I8] {
            let q = QuantMat::from_f32(&a, rows, cols, prec);
            let full = q.to_f32_vec();
            let mut panel = vec![0f32; 4 * cols];
            q.dequant_rows_into(5, 4, &mut panel);
            assert_eq!(&panel[..], &full[5 * cols..9 * cols], "{prec:?}");
        }
    }
}
