//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//! Used for covariance whitening in DataSVD: `Σ^{±1/2} = Q Λ^{±1/2} Qᵀ`.

use super::Mat;

/// Eigendecomposition of a symmetric matrix: `a = q * diag(l) * qᵀ`,
/// eigenvalues sorted descending.
#[derive(Debug, Clone)]
pub struct SymEig {
    pub q: Mat,
    pub l: Vec<f64>,
}

impl SymEig {
    /// Rebuild `Q f(Λ) Qᵀ` for an elementwise spectral function `f`.
    pub fn rebuild(&self, f: impl Fn(f64) -> f64) -> Mat {
        let n = self.l.len();
        let mut scaled = self.q.clone(); // Q f(Λ)
        for j in 0..n {
            let fj = f(self.l[j]);
            scaled.scale_col(j, fj);
        }
        &scaled * &self.q.t()
    }
}

/// Cyclic Jacobi eigensolver for symmetric `a`.
pub fn sym_eig(a: &Mat) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut q = Mat::eye(n);

    let max_sweeps = 80;
    for _ in 0..max_sweeps {
        // Off-diagonal magnitude.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-13 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apq = m[(p, r)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(r, r)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // M <- Jᵀ M J on rows/cols p, r.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, r)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, r)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(r, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(r, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let qkp = q[(k, p)];
                    let qkq = q[(k, r)];
                    q[(k, p)] = c * qkp - s * qkq;
                    q[(k, r)] = s * qkp + c * qkq;
                }
            }
        }
    }

    // Sort descending by eigenvalue.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| m[(j, j)].total_cmp(&m[(i, i)]));
    let mut ql = Mat::zeros(n, n);
    let mut l = Vec::with_capacity(n);
    for (dst, &src) in idx.iter().enumerate() {
        l.push(m[(src, src)]);
        for i in 0..n {
            ql[(i, dst)] = q[(i, src)];
        }
    }
    SymEig { q: ql, l }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::rng::Rng;

    #[test]
    fn eig_reconstructs() {
        let mut rng = Rng::new(12);
        let b = Mat::randn(8, 8, &mut rng);
        let a = &(&b + &b.t()).scale(0.5) * &Mat::eye(8); // symmetric
        let e = sym_eig(&a);
        let recon = e.rebuild(|l| l);
        assert!(recon.close_to(&a, 1e-9), "dist {}", recon.frob_dist(&a));
        // Q orthonormal.
        assert!((&e.q.t() * &e.q).close_to(&Mat::eye(8), 1e-9));
        // Sorted descending.
        assert!(e.l.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }

    #[test]
    fn psd_eigs_nonnegative() {
        let mut rng = Rng::new(13);
        let b = Mat::randn(10, 6, &mut rng);
        let a = &b.t() * &b;
        let e = sym_eig(&a);
        assert!(e.l.iter().all(|&l| l > -1e-9));
    }

    #[test]
    fn property_spectral_function() {
        prop::forall(
            31,
            12,
            |r| {
                let n = prop::gen::dim(r, 2, 14);
                let b = Mat::randn(n, n, r);
                (&b + &b.t()).scale(0.5)
            },
            |a| {
                let e = sym_eig(a);
                // f = identity must reconstruct.
                let recon = e.rebuild(|l| l);
                if !recon.close_to(a, 1e-7) {
                    return Err(format!("reconstruct dist {}", recon.frob_dist(a)));
                }
                // f = square must equal A*A.
                let sq = e.rebuild(|l| l * l);
                let want = a * a;
                if !sq.close_to(&want, 1e-6) {
                    return Err("spectral square mismatch".into());
                }
                Ok(())
            },
        );
    }
}
