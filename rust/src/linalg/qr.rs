//! Householder QR (thin): A (m×n, m ≥ n) = Q (m×n) R (n×n).
//! Used for random orthonormal bases and as a building block in tests.

use super::Mat;

/// Thin QR via Householder reflections.  For m < n, factorizes the leading
/// m columns' span (Q is m×min(m,n), R is min(m,n)×n).
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let m = a.rows;
    let n = a.cols;
    let k = m.min(n);
    let mut r = a.clone();
    // Store Householder vectors.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);

    for j in 0..k {
        // Build the Householder vector for column j below the diagonal.
        let mut v: Vec<f64> = (j..m).map(|i| r[(i, j)]).collect();
        let alpha = -v[0].signum() * v.iter().map(|x| x * x).sum::<f64>().sqrt();
        v[0] -= alpha;
        let vnorm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if vnorm > 1e-300 {
            for x in v.iter_mut() {
                *x /= vnorm;
            }
            // Apply H = I - 2 v vᵀ to R[j.., j..].
            for col in j..n {
                let dot: f64 = (j..m).map(|i| v[i - j] * r[(i, col)]).sum();
                for i in j..m {
                    r[(i, col)] -= 2.0 * v[i - j] * dot;
                }
            }
        }
        vs.push(v);
    }

    // Accumulate Q = H_0 H_1 ... H_{k-1} I_{m×k}.
    let mut q = Mat::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for j in (0..k).rev() {
        let v = &vs[j];
        if v.iter().all(|x| *x == 0.0) {
            continue;
        }
        for col in 0..k {
            let dot: f64 = (j..m).map(|i| v[i - j] * q[(i, col)]).sum();
            for i in j..m {
                q[(i, col)] -= 2.0 * v[i - j] * dot;
            }
        }
    }

    // R upper-triangular k×n.
    let mut rk = Mat::zeros(k, n);
    for i in 0..k {
        for j in i..n {
            rk[(i, j)] = r[(i, j)];
        }
    }
    (q, rk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn qr_reconstructs_tall() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(8, 5, &mut rng);
        let (q, r) = qr(&a);
        assert!((&q * &r).close_to(&a, 1e-9));
        // Orthonormal columns.
        let qtq = &q.t() * &q;
        assert!(qtq.close_to(&Mat::eye(5), 1e-9));
    }

    #[test]
    fn qr_reconstructs_square() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(6, 6, &mut rng);
        let (q, r) = qr(&a);
        assert!((&q * &r).close_to(&a, 1e-9));
    }

    #[test]
    fn qr_wide() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(3, 7, &mut rng);
        let (q, r) = qr(&a);
        assert_eq!(q.cols, 3);
        assert_eq!(r.rows, 3);
        assert!((&q * &r).close_to(&a, 1e-9));
    }

    #[test]
    fn property_qr_orthonormal() {
        crate::prop::forall(
            11,
            25,
            |r| {
                let m = crate::prop::gen::dim(r, 2, 20);
                let n = crate::prop::gen::dim(r, 2, 20);
                let a = Mat::randn(m, n, r);
                (m, n, a)
            },
            |(_m, n, a)| {
                let (q, r) = qr(a);
                let k = q.cols;
                if !(&q * &r).close_to(a, 1e-8) {
                    return Err("QR != A".into());
                }
                let qtq = &q.t() * &q;
                if !qtq.close_to(&Mat::eye(k.min(*n).min(k)), 1e-8) {
                    return Err("Q not orthonormal".into());
                }
                Ok(())
            },
        );
    }
}
