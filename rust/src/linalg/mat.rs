//! Dense row-major f64 matrix with the operations the repo needs.

use std::ops::{Add, Index, IndexMut, Mul, Sub};

use crate::rng::Rng;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal()).collect(),
        }
    }

    /// Random matrix with prescribed singular values: `P diag(sv) Qᵀ` with
    /// random orthonormal P, Q (used by theory experiments, Fig. 2).
    pub fn with_singular_values(m: usize, n: usize, sv: &[f64], rng: &mut Rng) -> Self {
        let k = sv.len().min(m.min(n));
        let p = Mat::randn(m, m, rng).orthonormal_cols(k);
        let q = Mat::randn(n, n, rng).orthonormal_cols(k);
        let mut out = Mat::zeros(m, n);
        for t in 0..k {
            for i in 0..m {
                for j in 0..n {
                    out[(i, j)] += sv[t] * p[(i, t)] * q[(j, t)];
                }
            }
        }
        out
    }

    /// First `k` columns of the Q factor of a QR of self (orthonormal).
    pub fn orthonormal_cols(&self, k: usize) -> Mat {
        let (q, _r) = super::qr(self);
        q.slice_cols(0, k)
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn t(&self) -> Mat {
        super::kernels::transpose(self)
    }

    /// Columns [lo, lo+k).
    pub fn slice_cols(&self, lo: usize, k: usize) -> Mat {
        assert!(lo + k <= self.cols);
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[lo..lo + k]);
        }
        out
    }

    /// Rows [lo, lo+k).
    pub fn slice_rows(&self, lo: usize, k: usize) -> Mat {
        assert!(lo + k <= self.rows);
        Mat {
            rows: k,
            cols: self.cols,
            data: self.data[lo * self.cols..(lo + k) * self.cols].to_vec(),
        }
    }

    /// Scale column j by s.
    pub fn scale_col(&mut self, j: usize, s: f64) {
        for i in 0..self.rows {
            self[(i, j)] *= s;
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn frob_dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    pub fn close_to(&self, other: &Mat, tol: f64) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())))
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        super::kernels::matvec_into(self, x, &mut y);
        y
    }

    /// `self * diag(d)` (column scaling).
    pub fn mul_diag(&self, d: &[f64]) -> Mat {
        assert_eq!(d.len(), self.cols);
        let mut out = self.clone();
        for i in 0..out.rows {
            for j in 0..out.cols {
                out[(i, j)] *= d[j];
            }
        }
        out
    }

    /// Outer-product accumulation: `self += s * x yᵀ`.
    pub fn add_outer(&mut self, s: f64, x: &[f64], y: &[f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        for i in 0..self.rows {
            let xi = s * x[i];
            let row = self.row_mut(i);
            for (rj, yj) in row.iter_mut().zip(y) {
                *rj += xi * yj;
            }
        }
    }

    /// Nuclear norm (sum of singular values).
    pub fn nuclear_norm(&self) -> f64 {
        super::svd(self).s.iter().sum()
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Mul for &Mat {
    type Output = Mat;

    /// Blocked parallel matmul (see [`super::kernels`]; naive ikj loop lives
    /// in [`super::reference`]).
    fn mul(self, rhs: &Mat) -> Mat {
        super::kernels::matmul(self, rhs)
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect(),
        }
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Mat::randn(4, 7, &mut rng);
        let i = Mat::eye(7);
        assert!((&a * &i).close_to(&a, 1e-12));
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = &a * &b;
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(3, 5, &mut rng);
        assert!(a.t().t().close_to(&a, 0.0));
    }

    #[test]
    fn outer_accumulation() {
        let mut m = Mat::zeros(2, 3);
        m.add_outer(2.0, &[1.0, 2.0], &[1.0, 0.0, 1.0]);
        assert_eq!(m.data, vec![2.0, 0.0, 2.0, 4.0, 0.0, 4.0]);
    }

    #[test]
    fn with_singular_values_has_them() {
        let mut rng = Rng::new(3);
        let sv = vec![3.0, 2.0, 1.0];
        let a = Mat::with_singular_values(6, 5, &sv, &mut rng);
        let s = super::super::svd(&a).s;
        for (got, want) in s.iter().zip(&sv) {
            assert!((got - want).abs() < 1e-8, "{got} vs {want}");
        }
        for extra in &s[3..] {
            assert!(extra.abs() < 1e-8);
        }
    }
}
