//! LU decomposition with partial pivoting: solve + inverse.
//! Used by GAR (`G = U_{1:r,:}^{-1}`, Sec. 3.5).

use anyhow::{bail, Result};

use super::Mat;

/// Solve `A x = b` for square A via LU with partial pivoting.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let x = lu_solve_many(a, &Mat::from_vec(b.len(), 1, b.to_vec()))?;
    Ok(x.data)
}

/// Solve `A X = B` (B: n×k) via LU with partial pivoting.
pub fn lu_solve_many(a: &Mat, b: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        bail!("lu_solve: matrix not square ({}x{})", a.rows, a.cols);
    }
    let n = a.rows;
    if b.rows != n {
        bail!("lu_solve: rhs rows {} != {}", b.rows, n);
    }
    let mut lu = a.clone();
    let mut piv: Vec<usize> = (0..n).collect();

    for col in 0..n {
        // Pivot.
        let (pi, pmax) = (col..n)
            .map(|i| (i, lu[(i, col)].abs()))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        if pmax < 1e-300 {
            bail!("lu_solve: singular matrix (pivot {pmax:.3e} at col {col})");
        }
        if pi != col {
            for j in 0..n {
                let tmp = lu[(col, j)];
                lu[(col, j)] = lu[(pi, j)];
                lu[(pi, j)] = tmp;
            }
            piv.swap(col, pi);
        }
        // Eliminate.
        for i in (col + 1)..n {
            let f = lu[(i, col)] / lu[(col, col)];
            lu[(i, col)] = f;
            for j in (col + 1)..n {
                let v = lu[(col, j)];
                lu[(i, j)] -= f * v;
            }
        }
    }

    // Apply to each RHS column.
    let k = b.cols;
    let mut x = Mat::zeros(n, k);
    for c in 0..k {
        // Permute + forward substitution (L has unit diagonal).
        let mut y: Vec<f64> = (0..n).map(|i| b[(piv[i], c)]).collect();
        for i in 0..n {
            for j in 0..i {
                y[i] -= lu[(i, j)] * y[j];
            }
        }
        // Back substitution.
        for i in (0..n).rev() {
            for j in (i + 1)..n {
                y[i] -= lu[(i, j)] * y[j];
            }
            y[i] /= lu[(i, i)];
        }
        for i in 0..n {
            x[(i, c)] = y[i];
        }
    }
    Ok(x)
}

/// Matrix inverse via LU solve against the identity.
pub fn inverse(a: &Mat) -> Result<Mat> {
    lu_solve_many(a, &Mat::eye(a.rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::rng::Rng;

    #[test]
    fn solve_known_system() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = lu_solve(&a, &[3.0, 5.0]).unwrap();
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(14);
        let a = Mat::randn(7, 7, &mut rng);
        let ai = inverse(&a).unwrap();
        assert!((&a * &ai).close_to(&Mat::eye(7), 1e-8));
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(inverse(&a).is_err());
    }

    #[test]
    fn property_solve_random() {
        prop::forall(
            41,
            20,
            |r| {
                let n = prop::gen::dim(r, 1, 16);
                let a = Mat::randn(n, n, r);
                let x: Vec<f64> = (0..n).map(|_| r.normal()).collect();
                (a, x)
            },
            |(a, x)| {
                let b = a.matvec(x);
                match lu_solve(a, &b) {
                    Err(_) => Ok(()), // singular draw: acceptable
                    Ok(got) => {
                        for (g, w) in got.iter().zip(x) {
                            if (g - w).abs() > 1e-6 * (1.0 + w.abs()) {
                                return Err(format!("{g} vs {w}"));
                            }
                        }
                        Ok(())
                    }
                }
            },
        );
    }
}
