//! Dense linear-algebra substrate (f64, row-major).
//!
//! Everything FlexRank's offline stages need, implemented from scratch:
//! Householder QR, one-sided Jacobi SVD, cyclic-Jacobi symmetric
//! eigendecomposition, LU solve/inverse, and PSD square roots (for the
//! whitening step of DataSVD, App. C.1).  Matmul/transpose/matvec route
//! through [`kernels`] — cache-blocked, panel-packed f64/f32 micro-kernels
//! fanned out over the persistent worker [`pool`] — with the seed's naive
//! loops preserved in [`reference`] as the property-test oracle.
//!
//! Sizes in this repo are ≤ ~1024, where Jacobi methods are accurate and
//! fast enough; precision is f64 internally even though model weights are
//! f32 (decomposition quality dominates the error budget).

pub mod aligned;
mod eig;
pub mod kernels;
mod mat;
pub mod pool;
pub mod quant;
mod qr;
pub mod reference;
pub mod simd;
mod solve;
mod svd;

pub use aligned::AlignedVec;
pub use eig::{sym_eig, SymEig};
pub use mat::Mat;
pub use qr::qr;
pub use solve::{inverse, lu_solve, lu_solve_many};
pub use svd::{svd, Svd};

/// PSD square root via symmetric eigendecomposition: `A^{1/2} = Q Λ^{1/2} Qᵀ`.
/// Returns `(A^{1/2}, A^{-1/2})`.  Eigenvalues are clamped at `floor`
/// (covariances from finite samples can have tiny negative eigenvalues).
pub fn psd_sqrt(a: &Mat, floor: f64) -> (Mat, Mat) {
    let e = sym_eig(a);
    let half = e.rebuild(|l| l.max(floor).sqrt());
    let inv_half = e.rebuild(|l| 1.0 / l.max(floor).sqrt());
    (half, inv_half)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn psd_sqrt_roundtrip() {
        let mut rng = Rng::new(5);
        let b = Mat::randn(6, 6, &mut rng);
        let a = &b.t() * &b; // PSD
        let (h, hi) = psd_sqrt(&a, 1e-12);
        let back = &h * &h;
        assert!(a.close_to(&back, 1e-8), "sqrt^2 != a");
        let ident = &h * &hi;
        assert!(ident.close_to(&Mat::eye(6), 1e-6), "h * h^-1 != I");
    }
}
