//! `repro` — FlexRank leader binary.
//!
//! Subcommands (see README):
//!   smoke                 — load + execute one artifact, sanity-check numbers
//!   pipeline              — full FlexRank run: pretrain → DataSVD → DP → KD
//!   serve                 — elastic serving demo over a synthetic trace
//!   figure <figN>         — regenerate a paper figure's series into results/
//!   table  <tabN>         — regenerate a paper table
//!   profiles              — write artifacts/profiles.json from DP selection

use anyhow::Result;
use flexrank::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("smoke") => cmd_smoke(&args),
        Some("pipeline") => flexrank::training::pipeline::run_cli(&args),
        Some("serve") => flexrank::coordinator::run_cli(&args),
        Some("figure") => flexrank::eval::figures::run_cli(&args),
        Some("table") => flexrank::eval::figures::run_table_cli(&args),
        Some("profiles") => flexrank::training::pipeline::write_profiles_cli(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand: {o}");
            }
            eprintln!(
                "usage: repro <smoke|pipeline|serve|figure|table|profiles> [--flags]\n\
                 figures: fig2 fig3 fig4 fig5 fig6 fig7a fig7b fig8 fig9 fig10; tables: tab1"
            );
            Ok(())
        }
    }
}

/// Minimal artifact round-trip: run teacher_fwd on zero tokens and check the
/// output shape; proves the python→HLO→rust→PJRT chain end to end.
fn cmd_smoke(_args: &Args) -> Result<()> {
    use flexrank::runtime::{Engine, Tensor};

    let engine = Engine::new(flexrank::artifacts_dir())?;
    println!("platform: {}", engine.platform());
    let cfg = engine.manifest.config.clone();
    println!("model: {} (d={}, blocks={})", cfg.name, cfg.d_model, cfg.n_blocks);

    let exe = engine.load("teacher_fwd")?;
    let mut inputs = engine.manifest.load_teacher_init()?;
    inputs.push(Tensor::i32(
        vec![cfg.batch_eval, cfg.seq_len],
        vec![0; cfg.batch_eval * cfg.seq_len],
    ));
    let out = exe.run(&inputs)?;
    let logits = &out[0];
    println!("teacher_fwd logits shape: {:?}", logits.shape());
    anyhow::ensure!(
        logits.shape() == [cfg.batch_eval, cfg.seq_len, cfg.vocab],
        "unexpected logits shape"
    );
    let vals = logits.as_f32()?;
    anyhow::ensure!(vals.iter().all(|x| x.is_finite()), "non-finite logits");
    println!("smoke OK (|logits| mean = {:.4})",
        vals.iter().map(|x| x.abs()).sum::<f32>() / vals.len() as f32);
    Ok(())
}
