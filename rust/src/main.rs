//! `repro` — FlexRank leader binary.
//!
//! Subcommands (see README):
//!   smoke                 — exercise the native kernel backend end to end
//!                           (with `--features pjrt`: the PJRT artifact chain)
//!   pipeline              — full FlexRank run: pretrain → DataSVD → DP → KD,
//!                           native backend by default (fully offline);
//!                           `--backend pjrt` drives the AOT artifacts
//!   serve                 — elastic serving demo over a synthetic trace;
//!                           picks up DP tier profiles from the pipeline's
//!                           profiles.json when present.  `--listen [addr]`
//!                           serves real sockets instead (framed protocol +
//!                           HTTP POST fallback; see examples/README.md)
//!   figure <figN>         — regenerate a paper figure's series into results/
//!   table  <tabN>         — regenerate a paper table
//!   profiles              — write stage_dir()/profiles.json from DP selection
//!   lint [path…]          — static invariant linter over rust/src (SAFETY
//!                           comments, hot-path allocation/panic bans,
//!                           pull-parser-only ingest, total_cmp float order);
//!                           nonzero exit on findings

use anyhow::Result;
use flexrank::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    println!("simd: {}", flexrank::linalg::simd::isa_label());
    match args.subcommand.as_deref() {
        Some("smoke") => cmd_smoke(&args),
        Some("pipeline") => flexrank::training::pipeline::run_cli(&args),
        Some("profiles") => flexrank::training::pipeline::write_profiles_cli(&args),
        Some("serve") => flexrank::coordinator::run_cli(&args),
        Some("figure") => flexrank::eval::figures::run_cli(&args),
        Some("table") => flexrank::eval::figures::run_table_cli(&args),
        Some("lint") => flexrank::analysis::run_cli(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand: {o}");
            }
            eprintln!(
                "usage: repro <smoke|pipeline|serve|figure|table|profiles|lint> [--flags]\n\
                 figures: fig2 fig3 fig4 fig5 fig6 fig7a fig7b fig8 fig9 fig10; tables: tab1\n\
                 serve: --policy static|adaptive|elastic  --scenario \
                 steady|diurnal|bursty|adversarial  --tenants (multi-tenant budget mix)\n\
                 \x20       --queue-cap N (0 = unbounded; positive sheds + anchors the \
                 demote-before-shed band)  --dwell-ms MS  --deadline-ms MS\n\
                 serve --listen [addr]: online front-end (default 127.0.0.1:7171; \
                 --queue-cap N --max-conns N --conn-pipeline N --listen-secs S)\n\
                 lint [path…]: static invariant checks (R1 SAFETY / R2 hot-path \
                 / R3 pull-parser ingest / R4 total_cmp); nonzero exit on findings"
            );
            Ok(())
        }
    }
}

/// Native smoke: random teacher → DataSVD student → GAR submodel → forward
/// through the kernel backend; proves the offline serving chain end to end.
#[cfg(not(feature = "pjrt"))]
fn cmd_smoke(args: &Args) -> Result<()> {
    use flexrank::config::load_model_config;
    use flexrank::runtime::native::{uniform_budget_profile, GarSubmodel, Scratch};
    use flexrank::training::params::{decompose_teacher, random_teacher, student_from_factors};

    let cfg = load_model_config(args.get_or("config", "tiny"))?;
    println!("backend: native kernels");
    println!("model: {} (d={}, blocks={})", cfg.name, cfg.d_model, cfg.n_blocks);

    let teacher = random_teacher(&cfg, args.u64_or("seed", 0)?);
    let factors = decompose_teacher(&cfg, &teacher, None)?;
    let student = student_from_factors(&cfg, &teacher, &factors)?;
    let sub = GarSubmodel::from_student(&cfg, &student, &uniform_budget_profile(&cfg, 0.5))?;

    let batch = cfg.batch_eval;
    // Honors the config's attention crossover knobs, like the serving
    // registry — smoke exercises the path the config actually serves with.
    let mut scratch = Scratch::for_config(&cfg, batch * cfg.seq_len);
    let tokens = vec![0i32; batch * cfg.seq_len];
    sub.forward(&tokens, batch, &mut scratch)?;
    let vals = scratch.logits(batch * cfg.seq_len, cfg.vocab);
    anyhow::ensure!(vals.iter().all(|x| x.is_finite()), "non-finite logits");
    println!(
        "smoke OK ({} tiers possible, submodel params {:.2}M, |logits| mean = {:.4})",
        cfg.serve_tiers.len(),
        sub.n_params as f64 / 1e6,
        vals.iter().map(|x| x.abs()).sum::<f32>() / vals.len() as f32
    );
    Ok(())
}

/// Minimal artifact round-trip: run teacher_fwd on zero tokens and check the
/// output shape; proves the python→HLO→rust→PJRT chain end to end.
#[cfg(feature = "pjrt")]
fn cmd_smoke(_args: &Args) -> Result<()> {
    use flexrank::runtime::{Engine, Tensor};

    let engine = Engine::new(flexrank::artifacts_dir())?;
    println!("platform: {}", engine.platform());
    let cfg = engine.manifest.config.clone();
    println!("model: {} (d={}, blocks={})", cfg.name, cfg.d_model, cfg.n_blocks);

    let exe = engine.load("teacher_fwd")?;
    let mut inputs = engine.manifest.load_teacher_init()?;
    inputs.push(Tensor::i32(
        vec![cfg.batch_eval, cfg.seq_len],
        vec![0; cfg.batch_eval * cfg.seq_len],
    ));
    let out = exe.run(&inputs)?;
    let logits = &out[0];
    println!("teacher_fwd logits shape: {:?}", logits.shape());
    anyhow::ensure!(
        logits.shape() == [cfg.batch_eval, cfg.seq_len, cfg.vocab],
        "unexpected logits shape"
    );
    let vals = logits.as_f32()?;
    anyhow::ensure!(vals.iter().all(|x| x.is_finite()), "non-finite logits");
    println!("smoke OK (|logits| mean = {:.4})",
        vals.iter().map(|x| x.abs()).sum::<f32>() / vals.len() as f32);
    Ok(())
}
