//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 seeding + xoshiro256** core, Box–Muller normals.  Everything in
//! the repo that needs randomness (init, data generation, mask sampling,
//! request traces, property tests) goes through this, so runs are exactly
//! reproducible from a seed.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller normal
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (small-n) uses: modulo bias is
        // < n / 2^64, negligible at any n this repo uses.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal (Box–Muller with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }

    /// Sample an index proportionally to non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[0.1, 0.1, 0.8])] += 1;
        }
        assert!(counts[2] > counts[0] + counts[1]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
