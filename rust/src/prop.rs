//! Tiny property-testing substrate (no `proptest` offline).
//!
//! `forall(seed, cases, gen, check)` runs `check` over `cases` generated
//! inputs; on failure it reports the case index and seed so the exact input
//! reproduces.  Generators are plain closures over [`crate::rng::Rng`].

use crate::rng::Rng;

/// Run a property over `cases` generated inputs; panics with a reproducible
/// seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::rng::Rng;

    /// Random dimension in [lo, hi].
    pub fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// Random f32 matrix entries (flat), N(0, scale).
    pub fn mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
        rng.normal_vec(rows * cols, scale)
    }

    /// Strictly-decreasing positive singular values with power-law decay.
    pub fn powerlaw_sv(rng: &mut Rng, k: usize, decay: f64) -> Vec<f64> {
        let base = 1.0 + rng.f64();
        (0..k).map(|i| base / ((i + 1) as f64).powf(decay)).collect()
    }
}

/// Assert two slices are elementwise close; returns Err for prop usage.
pub fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall(0, 50, |r| r.below(100), |x| if *x < 100 { Ok(()) } else { Err("oob".into()) });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(0, 50, |r| r.below(100), |x| if *x < 5 { Ok(()) } else { Err("big".into()) });
    }

    #[test]
    fn close_detects_divergence() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.0001], 1e-3).is_ok());
        assert!(close(&[1.0, 2.0], &[1.0, 2.1], 1e-3).is_err());
        assert!(close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
