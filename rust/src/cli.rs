//! Minimal CLI argument substrate (no `clap` offline).
//!
//! Supports `repro <subcommand> [positional...] [--flag] [--key value]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v != "false").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("figure fig4 extra");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positional, vec!["fig4", "extra"]);
    }

    #[test]
    fn flags_all_forms() {
        let a = parse("train --steps 100 --lr=0.5 --verbose");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --steps abc");
        assert!(a.usize_or("steps", 0).is_err());
    }
}
