//! Minimal JSON substrate (parser + writer).
//!
//! The offline image has no `serde`/`serde_json`; this module provides the
//! small subset the repo needs: parsing `artifacts/manifest.json` and
//! `configs/*.json`, and writing `profiles.json` / results CSV-adjacent JSON.
//! It is a strict recursive-descent parser over UTF-8 with the usual escape
//! handling; numbers are kept as f64 (all our uses fit).
//!
//! Two parsers live here:
//!
//! * the tree-building [`parse`] below — convenient, allocates a
//!   [`Value`] node per element, fine for configs and result files;
//! * [`pull`] — an allocation-free, non-recursive event parser for the
//!   serving ingest path, where the tree builder is **banned** (a request
//!   must not heap-allocate between `read()` and `batcher.push()`).

pub mod pull;

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// Maximum container nesting [`parse`] accepts.  Without a cap, a deeply
/// nested `[[[[…` overflows the recursive-descent stack — once bytes arrive
/// from a socket that is a remote crash, so the limit is a hard parse error
/// (well inside any sane config/result document, far outside the stack).
pub const MAX_DEPTH: usize = 128;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but an error mentioning the key when missing.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Convenience: array of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Convenience: array of f64.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    /// Entering a container: bump the nesting depth, error past [`MAX_DEPTH`].
    fn descend(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("json: nesting deeper than {MAX_DEPTH} at byte {}", self.i);
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "json: expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            )
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("json: unexpected {:?} at byte {}", other.map(|x| x as char), self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("json: bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        self.descend()?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Value::Obj(m));
                }
                other => bail!("json: expected ',' or '}}', got {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        self.descend()?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Value::Arr(a));
                }
                other => bail!("json: expected ',' or ']', got {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("json: unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("json: bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(txt.parse()?))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("json: trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Value> {
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("reading {}: {e}", path.as_ref().display()))?;
    parse(&text)
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize a [`Value`] to compact JSON text.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if !n.is_finite() {
                // `inf`/`NaN` are not JSON; a report that sneaks one in
                // poisons every downstream parser.  Serializers should guard
                // their own numbers (see `finite_num`); this is the backstop.
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => esc(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

/// Builder helpers for writing result/profile files.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
}

pub fn arr_usize(xs: &[usize]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(*x as f64)).collect())
}

pub fn arr_i32(xs: &[i32]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(*x as f64)).collect())
}

/// A number guaranteed to serialize as valid JSON: non-finite inputs
/// (`inf`/`NaN` from a ~0-elapsed rate, an empty-sample percentile, …)
/// collapse to `0.0` instead of emitting an unparseable token.  Report
/// serializers route every float through this.
pub fn finite_num(x: f64) -> Value {
    Value::Num(if x.is_finite() { x } else { 0.0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = parse(r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.req("b").unwrap().as_str().unwrap(), "x\ny");
        assert!(v.req("c").unwrap().as_bool().unwrap());
        let txt = to_string(&v);
        assert_eq!(parse(&txt).unwrap(), v);
    }

    #[test]
    fn nested_and_unicode() {
        let v = parse(r#"{"k": {"inner": ["A", "ß", []]}}"#).unwrap();
        let inner = v.req("k").unwrap().req("inner").unwrap().as_arr().unwrap();
        assert_eq!(inner[0].as_str().unwrap(), "A");
        assert_eq!(inner[1].as_str().unwrap(), "ß");
        assert_eq!(inner[2], Value::Arr(vec![]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_format_stable() {
        assert_eq!(to_string(&Value::Num(5.0)), "5");
        assert_eq!(to_string(&Value::Num(0.25)), "0.25");
    }

    #[test]
    fn depth_cap_is_a_hard_error_not_a_crash() {
        // A 100k-deep array used to overflow the recursive-descent stack —
        // a remote crash once bytes arrive from a socket.
        let bomb = "[".repeat(100_000);
        let err = parse(&bomb).unwrap_err().to_string();
        assert!(err.contains("nesting deeper than"), "{err}");
        // Same for objects.
        let obomb = "{\"k\":".repeat(100_000);
        assert!(parse(&obomb).unwrap_err().to_string().contains("nesting deeper than"));
        // At the cap: fine.  One past: error.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(parse(&ok).is_ok());
        let bad = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(parse(&bad).is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let txt = to_string(&obj(vec![("x", Value::Num(bad))]));
            assert_eq!(txt, r#"{"x":null}"#);
            parse(&txt).expect("guarded output must re-parse");
        }
        assert_eq!(finite_num(f64::NAN), Value::Num(0.0));
        assert_eq!(finite_num(2.5), Value::Num(2.5));
    }
}
