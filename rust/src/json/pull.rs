//! Allocation-free pull (event) parser over a caller-provided byte buffer.
//!
//! The serving listener parses request bodies on the hot path, where the
//! tree-building [`super::parse`] is banned: every `Value` node costs a
//! heap allocation (a `BTreeMap` or `String` per element), and the ingest
//! contract is **zero** request-path allocations between `read()` and
//! `batcher.push()`.  This parser follows the picojson/callback-lexer
//! design instead: the caller drives [`PullParser::next`] and receives
//! borrowed [`Event`]s; nothing is copied, nothing is allocated, and the
//! implementation is one iterative loop (no recursion) over a fixed-size
//! depth bitstack, so nesting depth is capped by construction rather than
//! by the thread stack.
//!
//! Strings are returned as the raw bytes between their quotes, escapes
//! *not* decoded ([`Event::Str`] carries an `escaped` flag).  The serving
//! wire format never needs escape decoding — keys are plain ASCII and
//! payloads are numeric — and offline callers can fall back to the tree
//! parser.  Errors are ordinary `Result`s; the parser is panic-free on
//! arbitrary input (pinned by the fuzz smoke in `tests/fuzz_ingest.rs`).

use anyhow::{bail, Result};

/// Maximum container nesting, tracked in a fixed bitstack (1 bit/level).
pub const MAX_DEPTH: usize = 128;

/// One parse event.  Borrowed slices point into the input buffer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// An object key (raw bytes between the quotes; `escaped` = contains
    /// at least one backslash escape the caller would need to decode).
    Key { raw: &'a [u8], escaped: bool },
    /// A string value (same convention as [`Event::Key`]).
    Str { raw: &'a [u8], escaped: bool },
    Num(f64),
    Bool(bool),
    Null,
    /// Document complete (trailing whitespace consumed, nothing after).
    End,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    /// Expecting a value.
    Value,
    /// Just after `[`: a value or an immediate `]`.
    ValueOrClose,
    /// Inside an object: a key or `}`.
    KeyOrClose,
    /// After a value inside a container: `,` or the closing bracket.
    CommaOrClose,
    /// After the top-level value: only trailing whitespace remains.
    Done,
}

/// Pull parser over `buf`.  `next()` yields events until [`Event::End`]
/// or an error; both are terminal.
pub struct PullParser<'a> {
    b: &'a [u8],
    i: usize,
    /// Container kind per level: bit set = object.
    bits: [u64; MAX_DEPTH / 64],
    depth: usize,
    state: State,
}

impl<'a> PullParser<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        PullParser { b: buf, i: 0, bits: [0; MAX_DEPTH / 64], depth: 0, state: State::Value }
    }

    /// Byte offset of the parse cursor (for error reporting).
    pub fn pos(&self) -> usize {
        self.i
    }

    fn push_level(&mut self, is_obj: bool) -> Result<()> {
        if self.depth >= MAX_DEPTH {
            bail!("json-pull: nesting deeper than {MAX_DEPTH} at byte {}", self.i);
        }
        let (w, m) = (self.depth / 64, 1u64 << (self.depth % 64));
        if is_obj {
            self.bits[w] |= m;
        } else {
            self.bits[w] &= !m;
        }
        self.depth += 1;
        Ok(())
    }

    /// Is the current innermost container an object?
    fn in_obj(&self) -> bool {
        debug_assert!(self.depth > 0);
        let d = self.depth - 1;
        self.bits[d / 64] & (1u64 << (d % 64)) != 0
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    /// State after a completed value at the current depth.
    fn after_value(&self) -> State {
        if self.depth == 0 {
            State::Done
        } else {
            State::CommaOrClose
        }
    }

    /// Scan a string body (cursor on the opening quote); returns the raw
    /// byte range between the quotes and whether it contains escapes.
    fn string_raw(&mut self) -> Result<(&'a [u8], bool)> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.i += 1;
        let start = self.i;
        let mut escaped = false;
        loop {
            match self.peek() {
                None => bail!("json-pull: unterminated string at byte {}", self.i),
                Some(b'"') => {
                    let raw = &self.b[start..self.i];
                    self.i += 1;
                    return Ok((raw, escaped));
                }
                Some(b'\\') => {
                    escaped = true;
                    // Skip the escape introducer + the escaped byte (enough
                    // to never mistake an escaped quote for the terminator;
                    // \uXXXX hex digits are plain bytes and fall through).
                    self.i += 2;
                    if self.i > self.b.len() {
                        bail!("json-pull: unterminated escape at end of input");
                    }
                }
                Some(_) => self.i += 1,
            }
        }
    }

    fn number(&mut self) -> Result<f64> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        // `from_utf8` and `parse::<f64>` borrow — no allocation.
        let txt = match std::str::from_utf8(&self.b[start..self.i]) {
            Ok(t) => t,
            Err(_) => bail!("json-pull: bad number bytes at {start}"),
        };
        match txt.parse::<f64>() {
            Ok(x) if x.is_finite() => Ok(x),
            _ => bail!("json-pull: bad number '{txt}' at byte {start}"),
        }
    }

    fn lit(&mut self, s: &'static str) -> Result<()> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(())
        } else {
            bail!("json-pull: bad literal at byte {}", self.i)
        }
    }

    /// Produce the next event.  After [`Event::End`] or an error the parser
    /// must not be advanced further.
    pub fn next(&mut self) -> Result<Event<'a>> {
        loop {
            self.ws();
            match self.state {
                State::Done => {
                    return if self.i == self.b.len() {
                        Ok(Event::End)
                    } else {
                        bail!("json-pull: trailing garbage at byte {}", self.i)
                    };
                }
                State::KeyOrClose => match self.peek() {
                    Some(b'}') => {
                        self.i += 1;
                        self.depth -= 1;
                        self.state = self.after_value();
                        return Ok(Event::ObjEnd);
                    }
                    Some(b'"') => {
                        let (raw, escaped) = self.string_raw()?;
                        self.ws();
                        if self.peek() != Some(b':') {
                            bail!("json-pull: expected ':' at byte {}", self.i);
                        }
                        self.i += 1;
                        self.state = State::Value;
                        return Ok(Event::Key { raw, escaped });
                    }
                    other => bail!(
                        "json-pull: expected key or '}}' at byte {} (found {other:?})",
                        self.i
                    ),
                },
                State::CommaOrClose => {
                    let close = if self.in_obj() { b'}' } else { b']' };
                    match self.peek() {
                        Some(b',') => {
                            self.i += 1;
                            self.state =
                                if self.in_obj() { State::KeyOrClose } else { State::Value };
                            // No event for a separator — keep scanning.
                        }
                        Some(c) if c == close => {
                            self.i += 1;
                            let was_obj = self.in_obj();
                            self.depth -= 1;
                            self.state = self.after_value();
                            return Ok(if was_obj { Event::ObjEnd } else { Event::ArrEnd });
                        }
                        other => bail!(
                            "json-pull: expected ',' or '{}' at byte {} (found {other:?})",
                            close as char,
                            self.i
                        ),
                    }
                }
                State::Value | State::ValueOrClose => {
                    if self.state == State::ValueOrClose && self.peek() == Some(b']') {
                        self.i += 1;
                        self.depth -= 1;
                        self.state = self.after_value();
                        return Ok(Event::ArrEnd);
                    }
                    match self.peek() {
                        Some(b'{') => {
                            self.i += 1;
                            self.push_level(true)?;
                            self.state = State::KeyOrClose;
                            return Ok(Event::ObjBegin);
                        }
                        Some(b'[') => {
                            self.i += 1;
                            self.push_level(false)?;
                            self.state = State::ValueOrClose;
                            return Ok(Event::ArrBegin);
                        }
                        Some(b'"') => {
                            let (raw, escaped) = self.string_raw()?;
                            self.state = self.after_value();
                            return Ok(Event::Str { raw, escaped });
                        }
                        Some(b't') => {
                            self.lit("true")?;
                            self.state = self.after_value();
                            return Ok(Event::Bool(true));
                        }
                        Some(b'f') => {
                            self.lit("false")?;
                            self.state = self.after_value();
                            return Ok(Event::Bool(false));
                        }
                        Some(b'n') => {
                            self.lit("null")?;
                            self.state = self.after_value();
                            return Ok(Event::Null);
                        }
                        Some(c) if c == b'-' || c.is_ascii_digit() => {
                            let x = self.number()?;
                            self.state = self.after_value();
                            return Ok(Event::Num(x));
                        }
                        other => bail!(
                            "json-pull: unexpected {other:?} at byte {} (expected a value)",
                            self.i
                        ),
                    }
                }
            }
        }
    }

    /// Consume and discard the value whose *first* event was just returned
    /// (a scalar is already fully consumed; for `ObjBegin`/`ArrBegin` this
    /// skips to the matching close).  Lets visitors ignore unknown keys.
    pub fn skip_value(&mut self, first: &Event<'_>) -> Result<()> {
        let mut open = match first {
            Event::ObjBegin | Event::ArrBegin => 1usize,
            _ => return Ok(()),
        };
        while open > 0 {
            match self.next()? {
                Event::ObjBegin | Event::ArrBegin => open += 1,
                Event::ObjEnd | Event::ArrEnd => open -= 1,
                Event::End => bail!("json-pull: input ended inside a skipped value"),
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(s: &str) -> Result<Vec<String>> {
        let mut p = PullParser::new(s.as_bytes());
        let mut out = Vec::new();
        loop {
            let e = p.next()?;
            let done = e == Event::End;
            out.push(format!("{e:?}"));
            if done {
                return Ok(out);
            }
        }
    }

    #[test]
    fn scalar_documents() {
        assert_eq!(events("42").unwrap(), vec!["Num(42.0)", "End"]);
        assert_eq!(events("true").unwrap(), vec!["Bool(true)", "End"]);
        assert_eq!(events("null").unwrap(), vec!["Null", "End"]);
    }

    #[test]
    fn object_and_array_stream() {
        let got = events(r#"{"a": [1, 2], "b": {"c": "x"}, "d": null}"#).unwrap();
        let want = [
            "ObjBegin",
            r#"Key { raw: [97], escaped: false }"#,
            "ArrBegin",
            "Num(1.0)",
            "Num(2.0)",
            "ArrEnd",
            r#"Key { raw: [98], escaped: false }"#,
            "ObjBegin",
            r#"Key { raw: [99], escaped: false }"#,
            r#"Str { raw: [120], escaped: false }"#,
            "ObjEnd",
            r#"Key { raw: [100], escaped: false }"#,
            "Null",
            "ObjEnd",
            "End",
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn empty_containers_and_escapes() {
        assert_eq!(events("[]").unwrap(), vec!["ArrBegin", "ArrEnd", "End"]);
        assert_eq!(events("{}").unwrap(), vec!["ObjBegin", "ObjEnd", "End"]);
        let mut p = PullParser::new(br#""a\"b""#);
        match p.next().unwrap() {
            Event::Str { raw, escaped } => {
                assert!(escaped);
                assert_eq!(raw, br#"a\"b"#);
            }
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,]", "[1 2]", "{\"a\" 1}", "{} extra", "[1,2", "nul", "-", "\"x"] {
            let mut p = PullParser::new(bad.as_bytes());
            let r = loop {
                match p.next() {
                    Ok(Event::End) => break Ok(()),
                    Ok(_) => {}
                    Err(e) => break Err(e),
                }
            };
            assert!(r.is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn depth_capped_without_recursion() {
        let bomb = "[".repeat(1_000_000);
        let mut p = PullParser::new(bomb.as_bytes());
        let err = loop {
            match p.next() {
                Ok(_) => {}
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("nesting deeper than"), "{err}");
    }

    #[test]
    fn skip_value_over_nested_unknowns() {
        let doc = r#"{"skip": {"deep": [1, {"x": [2, 3]}]}, "keep": 7}"#;
        let mut p = PullParser::new(doc.as_bytes());
        assert_eq!(p.next().unwrap(), Event::ObjBegin);
        let _key = p.next().unwrap();
        let first = p.next().unwrap();
        p.skip_value(&first).unwrap();
        match p.next().unwrap() {
            Event::Key { raw, .. } => assert_eq!(raw, b"keep"),
            e => panic!("{e:?}"),
        }
        assert_eq!(p.next().unwrap(), Event::Num(7.0));
        assert_eq!(p.next().unwrap(), Event::ObjEnd);
        assert_eq!(p.next().unwrap(), Event::End);
    }

    #[test]
    fn agrees_with_tree_parser_on_roundtrips() {
        // Random tree-parser documents re-lexed by the pull parser must
        // yield the same scalar stream the tree contains.
        crate::prop::forall(
            313,
            40,
            |rng| {
                let n = 1 + rng.below(8);
                let nums: Vec<f64> = (0..n).map(|_| (rng.below(1000) as f64) / 8.0).collect();
                nums
            },
            |nums| {
                let doc = crate::json::to_string(&crate::json::obj(vec![
                    ("xs", crate::json::arr_f64(nums)),
                    ("n", crate::json::Value::Num(nums.len() as f64)),
                ]));
                let mut p = PullParser::new(doc.as_bytes());
                let mut got: Vec<f64> = Vec::new();
                loop {
                    match p.next().map_err(|e| e.to_string())? {
                        Event::Num(x) => got.push(x),
                        Event::End => break,
                        _ => {}
                    }
                }
                // Keys sort "n" before "xs" in the BTreeMap writer.
                let want: Vec<f64> =
                    std::iter::once(nums.len() as f64).chain(nums.iter().copied()).collect();
                if got != want {
                    return Err(format!("{got:?} != {want:?}"));
                }
                Ok(())
            },
        );
    }
}
