//! Config system: model configs (shared with python via configs/*.json) and
//! run configs (training / selection / serving knobs with CLI overrides).

use std::path::PathBuf;

use anyhow::Result;

use crate::cli::Args;
use crate::json;
pub use crate::runtime::Manifest;
pub use crate::runtime::{ArtifactSpec, TensorSpec};

pub use crate::runtime::manifest::ModelConfig;

/// Load a model config by name ("base", "tiny") or path.
pub fn load_model_config(name_or_path: &str) -> Result<ModelConfig> {
    let path = if std::path::Path::new(name_or_path).exists() {
        PathBuf::from(name_or_path)
    } else {
        crate::repo_root().join("configs").join(format!("model_{name_or_path}.json"))
    };
    ModelConfig::from_json(&json::parse_file(path)?)
}

/// Knobs for the full FlexRank pipeline run (e2e example + figures).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Teacher pretraining steps (builds the "pretrained base model").
    pub pretrain_steps: usize,
    /// Knowledge-consolidation steps (Alg. 1 lines 14-17).
    pub consolidate_steps: usize,
    /// Budget grid for DP selection / evaluation, ascending fractions.
    pub budgets: Vec<f64>,
    /// Sampling weights alpha_k over budgets during consolidation (Eq. 6).
    pub alphas: Vec<f64>,
    /// Calibration batches for DataSVD covariance accumulation.
    pub calib_batches: usize,
    /// Eval batches per measurement.
    pub eval_batches: usize,
    /// RNG seed for the whole pipeline.
    pub seed: u64,
    /// Log every N steps.
    pub log_every: usize,
    /// Rank levels per layer in the sensitivity probe (K of App. C.2).
    pub probe_levels: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        let budgets: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        let alphas = vec![1.0 / budgets.len() as f64; budgets.len()];
        RunConfig {
            pretrain_steps: 300,
            consolidate_steps: 300,
            budgets,
            alphas,
            calib_batches: 16,
            eval_batches: 4,
            seed: 1234,
            log_every: 25,
            probe_levels: 8,
        }
    }
}

impl RunConfig {
    /// Apply CLI overrides: --pretrain-steps, --consolidate-steps, --seed,
    /// --calib-batches, --eval-batches, --log-every.
    pub fn with_args(mut self, args: &Args) -> Result<Self> {
        self.pretrain_steps = args.usize_or("pretrain-steps", self.pretrain_steps)?;
        self.consolidate_steps = args.usize_or("consolidate-steps", self.consolidate_steps)?;
        self.calib_batches = args.usize_or("calib-batches", self.calib_batches)?;
        self.eval_batches = args.usize_or("eval-batches", self.eval_batches)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.log_every = args.usize_or("log-every", self.log_every)?;
        self.probe_levels = args.usize_or("probe-levels", self.probe_levels)?;
        Ok(self)
    }

    /// "Smoke" profile for tests: tiny step counts.
    pub fn smoke() -> Self {
        RunConfig {
            pretrain_steps: 3,
            consolidate_steps: 3,
            calib_batches: 2,
            eval_batches: 1,
            log_every: 1,
            probe_levels: 2,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_budgets_ascending_and_weighted() {
        let rc = RunConfig::default();
        assert!(rc.budgets.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(rc.budgets.len(), rc.alphas.len());
        let s: f64 = rc.alphas.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cli_overrides() {
        let args = crate::cli::Args::parse(
            ["x", "--pretrain-steps", "7", "--seed", "99"].iter().map(|s| s.to_string()),
        );
        let rc = RunConfig::default().with_args(&args).unwrap();
        assert_eq!(rc.pretrain_steps, 7);
        assert_eq!(rc.seed, 99);
    }

    #[test]
    fn model_config_loads() {
        let mc = load_model_config("tiny").unwrap();
        assert_eq!(mc.d_model, 32);
        assert_eq!(mc.n_fact_layers(), 8);
        assert_eq!(mc.layer_dims().len(), 4);
    }

    #[test]
    fn attention_knobs_default_and_parse() {
        use crate::runtime::attention::{DEFAULT_ATTN_TILE, DEFAULT_STREAMING_MIN_SEQ};
        // Configs without the knobs get the built-in crossover defaults…
        let mc = load_model_config("tiny").unwrap();
        assert_eq!(mc.attn_tile, DEFAULT_ATTN_TILE);
        assert_eq!(mc.attn_streaming_min_seq, DEFAULT_STREAMING_MIN_SEQ);
        // …so the tiny config's short sequences resolve to the blocked path.
        assert_eq!(mc.attn_path().resolve(mc.seq_len), None);

        // Explicit knobs parse and drive the path resolution.
        let good = std::fs::read_to_string(
            crate::repo_root().join("configs").join("model_tiny.json"),
        )
        .unwrap();
        let tuned = good.replace(
            "\"seq_len\": 16,",
            "\"seq_len\": 16,\n  \"attn_tile\": 8,\n  \"attn_streaming_min_seq\": 16,",
        );
        assert!(tuned.contains("attn_tile"), "fixture edit failed");
        let mc = ModelConfig::from_json(&json::parse(&tuned).unwrap()).unwrap();
        assert_eq!(mc.attn_tile, 8);
        assert_eq!(mc.attn_streaming_min_seq, 16);
        assert_eq!(mc.attn_path().resolve(mc.seq_len), Some(8));
        assert_eq!(mc.attn_path().resolve(mc.seq_len - 1), None);

        // A zero tile is a config error at parse time.
        let broken = good.replace("\"seq_len\": 16,", "\"seq_len\": 16,\n  \"attn_tile\": 0,");
        let err = ModelConfig::from_json(&json::parse(&broken).unwrap()).unwrap_err();
        assert!(err.to_string().contains("attn_tile"), "{err}");
    }

    #[test]
    fn serving_pressure_knobs_default_parse_and_reject_inverted() {
        // Configs without the knobs keep the derive-from-queue-cap defaults.
        let mc = load_model_config("tiny").unwrap();
        assert_eq!(mc.serve_queue_cap, 0);
        assert_eq!(mc.serve_pressure_band(), None);
        assert_eq!(mc.serve_dwell_ms, 25.0);

        let good = std::fs::read_to_string(
            crate::repo_root().join("configs").join("model_tiny.json"),
        )
        .unwrap();
        let tuned = good.replace(
            "\"seq_len\": 16,",
            "\"seq_len\": 16,\n  \"serve_queue_cap\": 48,\n  \"serve_pressure_hi\": 18,\n  \
             \"serve_pressure_lo\": 3,\n  \"serve_dwell_ms\": 10.0,",
        );
        assert!(tuned.contains("serve_queue_cap"), "fixture edit failed");
        let mc = ModelConfig::from_json(&json::parse(&tuned).unwrap()).unwrap();
        assert_eq!(mc.serve_queue_cap, 48);
        assert_eq!(mc.serve_pressure_band(), Some((18, 3)));
        assert_eq!(mc.serve_dwell_ms, 10.0);

        // Regression: an inverted band (lo >= hi) silently never demoted —
        // now it's a parse-time error, as is a band at/above the shed cap.
        let inverted = good.replace(
            "\"seq_len\": 16,",
            "\"seq_len\": 16,\n  \"serve_pressure_hi\": 4,\n  \"serve_pressure_lo\": 24,",
        );
        let err = ModelConfig::from_json(&json::parse(&inverted).unwrap()).unwrap_err();
        assert!(err.to_string().contains("inverted band"), "{err}");
        let above_cap = good.replace(
            "\"seq_len\": 16,",
            "\"seq_len\": 16,\n  \"serve_queue_cap\": 16,\n  \"serve_pressure_hi\": 16,\n  \
             \"serve_pressure_lo\": 2,",
        );
        let err = ModelConfig::from_json(&json::parse(&above_cap).unwrap()).unwrap_err();
        assert!(err.to_string().contains("before admission sheds"), "{err}");
    }

    #[test]
    fn bad_head_split_fails_at_parse_time() {
        // d_model % n_heads != 0 must be rejected when the config is
        // loaded, not at the first forward (the check used to live,
        // duplicated, at both forward entry points).
        let good = std::fs::read_to_string(
            crate::repo_root().join("configs").join("model_tiny.json"),
        )
        .unwrap();
        let bad = good.replace("\"n_heads\": 2", "\"n_heads\": 5");
        assert!(bad.contains("\"n_heads\": 5"), "fixture edit failed");
        let err = ModelConfig::from_json(&json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("not divisible"), "{err}");

        let zero = good.replace("\"n_heads\": 2", "\"n_heads\": 0");
        assert!(ModelConfig::from_json(&json::parse(&zero).unwrap()).is_err());
    }
}
