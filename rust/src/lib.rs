//! FlexRank — nested low-rank knowledge decomposition for adaptive model
//! deployment (reproduction of Zaccone et al., ICML 2026).
//!
//! Crate layout mirrors DESIGN.md:
//!
//! * [`linalg`] — dense matrix substrate: QR, Jacobi SVD, symmetric
//!   eigendecomposition, inverse; matmul/transpose/matvec run on
//!   [`linalg::kernels`] (cache-blocked, panel-packed, multi-threaded f64 +
//!   f32 micro-kernels, fused GAR emit, scratch arena) with the naive loops
//!   preserved in [`linalg::reference`] as the property-test oracle.
//! * [`nn`] — pure-rust trainable networks (manual backprop) for the paper's
//!   controlled experiments (Figs. 2, 3, 8, 9).
//! * [`flexrank`] — the paper's contribution: DataSVD decomposition, DP rank
//!   selection (Alg. 2+3), GAR reparametrization, nested masks, sensitivity
//!   probing, Pareto utilities, PTS/ASL/NSL theory, KD consolidation.
//! * [`baselines`] — every comparison system in the evaluation: plain SVD,
//!   ACIP-like, LLM-Pruner-like, LayerSkip-like, independent submodels.
//! * [`runtime`] — execution backends: [`runtime::native`] (GAR submodel
//!   forwards over the kernel layer, allocation-free serving scratch; the
//!   default) and the PJRT executor over the AOT artifacts behind the
//!   `pjrt` feature.
//! * [`training`] — teacher pretraining + knowledge-consolidation drivers.
//! * [`coordinator`] — the elastic serving layer: router, dynamic batcher,
//!   submodel registry, SLO policy, metrics.
//! * [`data`] — synthetic corpora / datasets / request traces (substitutes
//!   for FineWebEdu, ImageNet, etc. per DESIGN.md §substitutions).
//! * [`eval`] — evaluation harnesses and figure/table printers.
//! * Support substrates (offline image has no tokio/clap/serde/criterion):
//!   [`json`], [`cli`], [`bench_harness`], [`prop`], [`rng`], [`config`].
//! * [`analysis`] — the in-tree invariant linter behind `repro lint`:
//!   SAFETY-comment, hot-path-allocation, pull-parser, and float-ordering
//!   rules, machine-checking what ROADMAP.md §Static invariants states.

pub mod analysis;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod json;
pub mod linalg;
pub mod nn;
pub mod prop;
pub mod rng;

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod flexrank;
pub mod runtime;
pub mod training;

/// Canonical repo root (compile-time; binaries run from the workspace).
/// `CARGO_MANIFEST_DIR` points at `rust/`; configs/artifacts/results live
/// one level up.
pub fn repo_root() -> std::path::PathBuf {
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    d.parent().map(|p| p.to_path_buf()).unwrap_or(d)
}

/// Default artifacts directory (`$FLEXRANK_ARTIFACTS` overrides).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("FLEXRANK_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| repo_root().join("artifacts"))
}

/// Default results directory (`$FLEXRANK_RESULTS` overrides).
pub fn results_dir() -> std::path::PathBuf {
    let d: std::path::PathBuf = std::env::var("FLEXRANK_RESULTS")
        .map(Into::into)
        .unwrap_or_else(|_| repo_root().join("results"));
    let _ = std::fs::create_dir_all(&d);
    d
}
