//! Parse `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest pins the exact flattened input/output order of every HLO
//! artifact (jax pytree flattening is sorted-dict-key order; the rust side
//! never re-derives it — it just follows the manifest).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::{self, Value};
use crate::linalg::quant::Precision;
use crate::runtime::tensor::DType;

/// Shape + dtype + pytree-path name of one artifact input/output leaf.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
            dtype: DType::parse(v.req("dtype")?.as_str()?)?,
        })
    }
}

/// One lowered HLO entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Rank profile (serving artifacts only).
    pub profile: Option<Vec<usize>>,
    /// Budget tier in (0, 1] (serving artifacts only).
    pub tier: Option<f64>,
}

/// Model config subset the runtime needs (mirror of configs/*.json).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_blocks: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub batch_calib: usize,
    pub batch_serve: usize,
    /// KD temperature τ of Eq. 5 (python `tau_kd`).
    pub tau_kd: f64,
    /// AdamW hyperparameters, shared with python's `adamw_update`.
    pub lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub adam_eps: f64,
    pub serve_tiers: Vec<f64>,
    pub bench_ranks: Vec<usize>,
    pub bench_dim: usize,
    pub bench_batch: usize,
    pub lora_rank: usize,
    /// Streaming (flash-style) attention K/V tile width Tc.  Optional in
    /// configs/*.json; defaults to
    /// [`crate::runtime::attention::DEFAULT_ATTN_TILE`].
    pub attn_tile: usize,
    /// Sequence-length crossover for the attention path: workspaces pick
    /// the streaming formulation at/above this `seq_len` and the blocked
    /// `(t, t)`-score formulation below it.  Optional in configs/*.json;
    /// defaults to
    /// [`crate::runtime::attention::DEFAULT_STREAMING_MIN_SEQ`].
    pub attn_streaming_min_seq: usize,
    /// Factor storage precision per serving tier (parallel to
    /// `serve_tiers`; `"f32" | "bf16" | "i8"`).  Optional in
    /// configs/*.json; defaults to f32 everywhere.
    pub tier_precision: Vec<Precision>,
    /// Tokens per K/V cache page in the incremental decode path (each page
    /// is one `(kv_page_size × head_dim)` K or V tile per (request, layer,
    /// head)).  Optional in configs/*.json; defaults to
    /// [`crate::runtime::kvcache::DEFAULT_KV_PAGE_SIZE`].
    pub kv_page_size: usize,
    /// Total pages in the preallocated K/V pool.  `0` (the default) sizes
    /// the pool so every one of `batch_serve` slots can hold a full
    /// `seq_len` stream simultaneously; a smaller explicit value makes
    /// continuous-batching admission contend for pages.
    pub kv_max_pages: usize,
    /// Serving queue bound for trace replay.  Optional in configs/*.json;
    /// `0` (the default) keeps the unbounded serve-everything replay queue,
    /// a positive cap sheds explicitly and anchors the elastic controller's
    /// demote-before-shed band.  CLI `--queue-cap` overrides.
    pub serve_queue_cap: usize,
    /// Explicit demotion-band thresholds (queue depths): pressure enters at
    /// `serve_pressure_hi`, exits at `serve_pressure_lo`.  Optional; both
    /// `0` (the default) derives the band from the queue cap
    /// ([`crate::coordinator::PressureBand::from_queue_cap`]).  Set, they
    /// must satisfy `lo < hi` — validated at parse time, because an
    /// inverted band silently disables demotion (the regression this knob's
    /// validation pins).
    pub serve_pressure_hi: usize,
    pub serve_pressure_lo: usize,
    /// Elastic controller minimum dwell between tier-level changes (ms).
    /// Optional; defaults to 25 ms.  CLI `--dwell-ms` overrides.
    pub serve_dwell_ms: f64,
}

impl ModelConfig {
    pub fn from_json(v: &Value) -> Result<Self> {
        let cfg = ModelConfig {
            name: v.req("name")?.as_str()?.to_string(),
            vocab: v.req("vocab")?.as_usize()?,
            d_model: v.req("d_model")?.as_usize()?,
            n_blocks: v.req("n_blocks")?.as_usize()?,
            n_heads: v.req("n_heads")?.as_usize()?,
            seq_len: v.req("seq_len")?.as_usize()?,
            batch_train: v.req("batch_train")?.as_usize()?,
            batch_eval: v.req("batch_eval")?.as_usize()?,
            batch_calib: v.req("batch_calib")?.as_usize()?,
            batch_serve: v.req("batch_serve")?.as_usize()?,
            tau_kd: v.req("tau_kd")?.as_f64()?,
            lr: v.req("lr")?.as_f64()?,
            weight_decay: v.req("weight_decay")?.as_f64()?,
            beta1: v.req("beta1")?.as_f64()?,
            beta2: v.req("beta2")?.as_f64()?,
            adam_eps: v.req("adam_eps")?.as_f64()?,
            serve_tiers: v.req("serve_tiers")?.as_f64_vec()?,
            bench_ranks: v.req("bench_ranks")?.as_usize_vec()?,
            bench_dim: v.req("bench_dim")?.as_usize()?,
            bench_batch: v.req("bench_batch")?.as_usize()?,
            lora_rank: v.req("lora_rank")?.as_usize()?,
            attn_tile: v
                .get("attn_tile")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(crate::runtime::attention::DEFAULT_ATTN_TILE),
            attn_streaming_min_seq: v
                .get("attn_streaming_min_seq")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(crate::runtime::attention::DEFAULT_STREAMING_MIN_SEQ),
            tier_precision: Vec::new(),
            kv_page_size: v
                .get("kv_page_size")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(crate::runtime::kvcache::DEFAULT_KV_PAGE_SIZE),
            kv_max_pages: v
                .get("kv_max_pages")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(0),
            serve_queue_cap: v
                .get("serve_queue_cap")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(0),
            serve_pressure_hi: v
                .get("serve_pressure_hi")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(0),
            serve_pressure_lo: v
                .get("serve_pressure_lo")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(0),
            serve_dwell_ms: v
                .get("serve_dwell_ms")
                .map(|x| x.as_f64())
                .transpose()?
                .unwrap_or(25.0),
        };
        let mut cfg = cfg;
        cfg.tier_precision = match v.get("tier_precision") {
            Some(tp) => tp
                .as_arr()?
                .iter()
                .map(|x| Precision::parse(x.as_str()?))
                .collect::<Result<Vec<_>>>()?,
            None => vec![Precision::F32; cfg.serve_tiers.len()],
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural invariants every forward path assumes, checked once at
    /// load time so a bad config fails at parse, not at first forward
    /// (this check used to be duplicated at both the serving and training
    /// forward entry points).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.vocab > 0 && self.d_model > 0 && self.n_blocks > 0 && self.seq_len > 0,
            "config '{}': vocab/d_model/n_blocks/seq_len must all be positive",
            self.name
        );
        anyhow::ensure!(
            self.n_heads > 0 && self.d_model % self.n_heads == 0,
            "config '{}': d_model {} not divisible by n_heads {}",
            self.name,
            self.d_model,
            self.n_heads
        );
        anyhow::ensure!(
            self.attn_tile > 0,
            "config '{}': attn_tile must be positive",
            self.name
        );
        anyhow::ensure!(
            self.kv_page_size > 0,
            "config '{}': kv_page_size must be positive",
            self.name
        );
        anyhow::ensure!(
            self.kv_max_pages == 0
                || self.kv_max_pages
                    >= self.n_blocks * self.n_heads * self.seq_len.div_ceil(self.kv_page_size),
            "config '{}': kv_max_pages {} cannot hold even one full seq_len {} stream \
             ({} blocks x {} heads x {} pages)",
            self.name,
            self.kv_max_pages,
            self.seq_len,
            self.n_blocks,
            self.n_heads,
            self.seq_len.div_ceil(self.kv_page_size)
        );
        anyhow::ensure!(
            self.tier_precision.len() == self.serve_tiers.len(),
            "config '{}': tier_precision has {} entries for {} serve_tiers",
            self.name,
            self.tier_precision.len(),
            self.serve_tiers.len()
        );
        // Serving-pressure knobs: an inverted or degenerate band would
        // silently disable demotion at serve time, so reject it at parse.
        anyhow::ensure!(
            (self.serve_pressure_hi == 0 && self.serve_pressure_lo == 0)
                || self.serve_pressure_lo < self.serve_pressure_hi,
            "config '{}': serve_pressure_lo {} must be < serve_pressure_hi {} \
             (an inverted band never demotes)",
            self.name,
            self.serve_pressure_lo,
            self.serve_pressure_hi
        );
        anyhow::ensure!(
            self.serve_queue_cap == 0
                || self.serve_pressure_hi == 0
                || self.serve_pressure_hi < self.serve_queue_cap,
            "config '{}': serve_pressure_hi {} must sit below serve_queue_cap {} \
             so demotion engages before admission sheds",
            self.name,
            self.serve_pressure_hi,
            self.serve_queue_cap
        );
        anyhow::ensure!(
            self.serve_dwell_ms.is_finite() && self.serve_dwell_ms >= 0.0,
            "config '{}': serve_dwell_ms {} must be finite and non-negative",
            self.name,
            self.serve_dwell_ms
        );
        Ok(())
    }

    /// The explicit demotion band when both pressure knobs are set, `None`
    /// to derive from the queue cap.
    pub fn serve_pressure_band(&self) -> Option<(usize, usize)> {
        if self.serve_pressure_hi > 0 {
            Some((self.serve_pressure_hi, self.serve_pressure_lo))
        } else {
            None
        }
    }

    /// Attention path selection the serving/training workspaces resolve at
    /// their sequence length: streaming at/above `attn_streaming_min_seq`
    /// with tile `attn_tile`, blocked below.
    pub fn attn_path(&self) -> crate::runtime::attention::AttnPath {
        crate::runtime::attention::AttnPath::Auto {
            min_seq: self.attn_streaming_min_seq,
            tile: self.attn_tile,
        }
    }

    /// The four factorization surfaces per block: (kind, n_in, m_out).
    pub fn layer_dims(&self) -> Vec<(&'static str, usize, usize)> {
        let d = self.d_model;
        vec![
            ("qkv", d, 3 * d),
            ("proj", d, d),
            ("fc", d, 4 * d),
            ("fcp", 4 * d, d),
        ]
    }

    /// Full rank of every factorized layer (= d_model in this architecture).
    pub fn rank_full(&self) -> usize {
        self.d_model
    }

    /// Number of factorized layers (4 per block).
    pub fn n_fact_layers(&self) -> usize {
        4 * self.n_blocks
    }
}

/// The whole manifest: config + artifact specs + teacher init blob spec.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub teacher_init: Vec<TensorSpec>,
    pub teacher_init_file: String,
    pub profiles: Vec<Vec<usize>>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let v = json::parse_file(&path).with_context(|| format!("loading {}", path.display()))?;

        let config = ModelConfig::from_json(v.req("config")?)?;
        let mut artifacts = BTreeMap::new();
        for (name, av) in v.req("artifacts")?.as_obj()? {
            let inputs = av
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = av
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let profile = av.get("profile").map(|p| p.as_usize_vec()).transpose()?;
            let tier = av.get("tier").map(|t| t.as_f64()).transpose()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: av.req("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                    profile,
                    tier,
                },
            );
        }
        let ti = v.req("teacher_init")?;
        let teacher_init = ti
            .req("params")?
            .as_arr()?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let profiles = v
            .req("profiles")?
            .as_arr()?
            .iter()
            .map(|p| p.as_usize_vec())
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir,
            config,
            artifacts,
            teacher_init,
            teacher_init_file: ti.req("file")?.as_str()?.to_string(),
            profiles,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (run `make artifacts`)"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Read `teacher_init.bin` and split into per-parameter tensors
    /// (canonical flat order).
    pub fn load_teacher_init(&self) -> Result<Vec<crate::runtime::Tensor>> {
        let path = self.dir.join(&self.teacher_init_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut out = Vec::with_capacity(self.teacher_init.len());
        let mut off = 0usize;
        for spec in &self.teacher_init {
            let n = spec.numel();
            anyhow::ensure!(off + n <= floats.len(), "teacher_init.bin too short");
            out.push(crate::runtime::Tensor::f32(
                spec.shape.clone(),
                floats[off..off + n].to_vec(),
            ));
            off += n;
        }
        anyhow::ensure!(off == floats.len(), "teacher_init.bin has trailing data");
        Ok(out)
    }
}
