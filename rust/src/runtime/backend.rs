//! The serving backend seam: one trait between the elastic coordinator and
//! whatever executes a tier's forward pass.
//!
//! [`crate::coordinator::serve_trace`], the serving bench, and the
//! `repro serve` CLI all dispatch through [`ServingBackend`], so adding a
//! backend (native kernels today, the PJRT registry behind the `pjrt`
//! feature, a GPU runtime later) means implementing one trait — the
//! routing/batching/metrics stack above it is backend-agnostic.

use anyhow::Result;

/// A loaded set of serving tiers that can execute batches.
///
/// Tiers are indexed `0..n_tiers()` in ascending budget order; `infer` runs
/// one padded `(batch() × seq_len())` token batch on a tier and returns the
/// logits `(batch·seq_len, vocab)`, valid until the next `infer` call
/// (backends reuse one scratch/output buffer across requests).
pub trait ServingBackend {
    fn n_tiers(&self) -> usize;
    /// Fixed serving batch size (requests per `infer` call).
    fn batch(&self) -> usize;
    /// Token window length of every request.
    fn seq_len(&self) -> usize;
    /// Budget fraction in (0, 1] of a tier.
    fn tier_budget(&self, tier: usize) -> f64;
    /// Inference parameter count of a tier's submodel.
    fn tier_params(&self, tier: usize) -> usize;
    /// Execute one batch (row-major `(batch, seq_len)` tokens, padded to the
    /// fixed serving batch) on a tier.
    fn infer(&mut self, tier: usize, tokens: &[i32]) -> Result<&[f32]>;
    /// Attention-path tag for bench/log lines ("blocked",
    /// "streaming(tile=64)", …).  The native backend reports its scratch's
    /// resolved [`crate::runtime::attention::AttnPath`]; backends whose
    /// attention is opaque (compiled artifacts, remote devices) keep the
    /// default.
    fn attn_path_label(&self) -> String {
        "n/a".to_string()
    }
    /// Storage-precision label of a tier's factor set ("f32" | "bf16" |
    /// "i8").  Backends without quantized storage keep the default.
    fn tier_precision_label(&self, _tier: usize) -> &'static str {
        "f32"
    }
}
