//! The serving backend seam: one trait between the elastic coordinator and
//! whatever executes a tier's forward pass.
//!
//! [`crate::coordinator::serve_trace`], the serving bench, and the
//! `repro serve` CLI all dispatch through [`ServingBackend`], so adding a
//! backend (native kernels today, the PJRT registry behind the `pjrt`
//! feature, a GPU runtime later) means implementing one trait — the
//! routing/batching/metrics stack above it is backend-agnostic.
//!
//! Two execution styles share the trait.  The original one-shot seam is
//! [`ServingBackend::infer`]: a padded full-window batch in, logits out.
//! The incremental seam — [`acquire_slot`] / [`prefill`] / [`decode_step`]
//! / [`release_slot`] — serves variable-length requests token by token
//! against per-request paged K/V state, and is what the continuous-batching
//! loop ([`crate::coordinator::serve_trace_decode`]) drives.  Every
//! incremental method has a default (`supports_decode() == false`, the rest
//! unreachable or erroring), so window-only backends like the PJRT registry
//! keep compiling untouched.
//!
//! [`acquire_slot`]: ServingBackend::acquire_slot
//! [`prefill`]: ServingBackend::prefill
//! [`decode_step`]: ServingBackend::decode_step
//! [`release_slot`]: ServingBackend::release_slot

use anyhow::{bail, Result};

/// A loaded set of serving tiers that can execute batches.
///
/// Tiers are indexed `0..n_tiers()` in ascending budget order; `infer` runs
/// one padded `(batch() × seq_len())` token batch on a tier and returns the
/// logits `(batch·seq_len, vocab)`, valid until the next `infer` call
/// (backends reuse one scratch/output buffer across requests).
pub trait ServingBackend {
    fn n_tiers(&self) -> usize;
    /// Fixed serving batch size (requests per `infer` call).
    fn batch(&self) -> usize;
    /// Token window length of every request.
    fn seq_len(&self) -> usize;
    /// Budget fraction in (0, 1] of a tier.
    fn tier_budget(&self, tier: usize) -> f64;
    /// Inference parameter count of a tier's submodel.
    fn tier_params(&self, tier: usize) -> usize;
    /// Calibration error of a tier — the difficulty signal the
    /// input-adaptive router's per-SLO quality bars interpolate over
    /// (lower = closer to the teacher).  Backends loaded from
    /// `profiles.json` report the DP chain's measured per-tier `error`;
    /// the default is the `1 - budget` proxy, which preserves the tier
    /// ordering without claiming measured quality.
    fn tier_error(&self, tier: usize) -> f64 {
        (1.0 - self.tier_budget(tier)).max(0.0)
    }
    /// Execute one batch (row-major `(batch, seq_len)` tokens, padded to the
    /// fixed serving batch) on a tier.
    fn infer(&mut self, tier: usize, tokens: &[i32]) -> Result<&[f32]>;
    /// Attention-path tag for bench/log lines ("blocked",
    /// "streaming(tile=64)", …).  The native backend reports its scratch's
    /// resolved [`crate::runtime::attention::AttnPath`]; backends whose
    /// attention is opaque (compiled artifacts, remote devices) keep the
    /// default.
    fn attn_path_label(&self) -> String {
        "n/a".to_string()
    }
    /// Storage-precision label of a tier's factor set ("f32" | "bf16" |
    /// "i8").  Backends without quantized storage keep the default.
    fn tier_precision_label(&self, _tier: usize) -> &'static str {
        "f32"
    }

    /// Whether the incremental prefill/decode seam below is implemented.
    fn supports_decode(&self) -> bool {
        false
    }

    /// Concurrent decode request slots (0 for window-only backends).
    fn decode_slots(&self) -> usize {
        0
    }

    /// Reserve a request slot plus K/V capacity for `need_tokens` tokens
    /// (prompt + maximum generation).  `None` = no slot or no pages free —
    /// the caller queues the request and retries after a release.  Eager
    /// reservation means an admitted request never stalls mid-decode.
    fn acquire_slot(&mut self, _need_tokens: usize) -> Option<usize> {
        None
    }

    /// Return a finished (or abandoned) request's slot and pages.
    fn release_slot(&mut self, _slot: usize) {}

    /// Run a prompt through a tier, appending its K/V rows to `slot`'s
    /// stream; returns logits `(prompt_len, vocab)`, one row per prompt
    /// position, valid until the next incremental call.
    fn prefill(&mut self, _tier: usize, _slot: usize, _tokens: &[i32]) -> Result<&[f32]> {
        bail!("this backend does not implement incremental decode")
    }

    /// Advance every listed request by one token on a tier: `tokens[r]` is
    /// the latest sampled token of the request in `slots[r]`.  Returns
    /// logits `(slots.len(), vocab)` in `slots` order, valid until the next
    /// incremental call.
    fn decode_step(&mut self, _tier: usize, _slots: &[usize], _tokens: &[i32]) -> Result<&[f32]> {
        bail!("this backend does not implement incremental decode")
    }
}
