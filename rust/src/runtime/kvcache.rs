//! Paged K/V cache: the per-request state store behind incremental decode.
//!
//! Serving used to replay fixed-`seq_len` full windows, recomputing every
//! key/value projection for every generated token.  This module holds the
//! K/V rows each request has already produced so a decode step only touches
//! the **new** token: fixed-size pages per (request-slot, layer, head) drawn
//! from one preallocated pool.  A page is `page_size` consecutive token
//! rows of one head's `hd`-wide K (or V) — exactly the `(Tc × hd)` panel
//! shape the streaming-attention tile consumes, so the decode kernel
//! ([`crate::runtime::attention::decode_attend_paged`]) gathers pages as
//! natural tiles with no repacking.
//!
//! Allocation discipline (the serving zero-alloc contract, extended):
//! every buffer — both K/V pools, the free list, the page table, the
//! per-slot length/capacity arrays — is sized once at construction.
//! Acquire/append/release move indices around inside that footprint;
//! [`fingerprint`] exposes the base pointers so tests pin that no decode
//! loop ever reallocates.
//!
//! Admission is **eager**: [`try_acquire`] reserves every page a request
//! could touch (`prompt + max generation` tokens) up front, or admits
//! nothing.  An admitted request can therefore always run to completion —
//! there is no mid-decode allocation failure and no preemption machinery.
//!
//! [`fingerprint`]: PagedKvCache::fingerprint
//! [`try_acquire`]: PagedKvCache::try_acquire

use crate::linalg::AlignedVec;

/// Default tokens per page (a `(16 × hd)` K/V tile; configs override via
/// `kv_page_size`).
pub const DEFAULT_KV_PAGE_SIZE: usize = 16;

/// Sentinel for an unassigned page-table entry (debug builds assert reads
/// never touch one).
const NO_PAGE: u32 = u32::MAX;

/// A pool-backed paged K/V cache over `max_slots` concurrent request slots.
#[derive(Debug)]
pub struct PagedKvCache {
    page_size: usize,
    layers: usize,
    heads: usize,
    hd: usize,
    max_slots: usize,
    /// Page-table entries per (slot, layer, head) stream:
    /// `ceil(max_seq / page_size)`.
    pages_per_stream: usize,
    /// Total pages in the pool.
    n_pages: usize,
    /// K pool: `n_pages × page_size × hd`.
    pool_k: AlignedVec<f32>,
    /// V pool, same shape.
    pool_v: AlignedVec<f32>,
    /// Unassigned page ids (stack; capacity `n_pages`, never grows).
    free: Vec<u32>,
    /// `[slot][layer][head][page_idx] → page id`, flat.
    table: Vec<u32>,
    /// Tokens appended so far, per slot.
    len: Vec<usize>,
    /// Reserved token capacity per slot (`None` = slot free).
    cap: Vec<Option<usize>>,
}

impl PagedKvCache {
    /// A cache for `max_slots` concurrent requests of up to `max_seq`
    /// tokens each, over a model with `layers` blocks × `heads` heads of
    /// width `hd`.  `max_pages = 0` sizes the pool so every slot can hold a
    /// full `max_seq` stream simultaneously (the no-page-pressure default);
    /// a smaller explicit `max_pages` makes admission contend for pages,
    /// which [`try_acquire`] surfaces as `None`.
    pub fn new(
        page_size: usize,
        layers: usize,
        heads: usize,
        hd: usize,
        max_slots: usize,
        max_seq: usize,
        max_pages: usize,
    ) -> PagedKvCache {
        assert!(page_size > 0, "kv page size must be positive");
        assert!(layers > 0 && heads > 0 && hd > 0 && max_slots > 0 && max_seq > 0);
        let pages_per_stream = max_seq.div_ceil(page_size);
        let full = max_slots * layers * heads * pages_per_stream;
        let n_pages = if max_pages == 0 { full } else { max_pages };
        let mut free = Vec::with_capacity(n_pages);
        // Stack order: page 0 comes off first, so fresh pools allocate the
        // pool front-to-back (cache-friendly and deterministic).
        for p in (0..n_pages as u32).rev() {
            free.push(p);
        }
        PagedKvCache {
            page_size,
            layers,
            heads,
            hd,
            max_slots,
            pages_per_stream,
            n_pages,
            pool_k: AlignedVec::zeroed(n_pages * page_size * hd),
            pool_v: AlignedVec::zeroed(n_pages * page_size * hd),
            free,
            // lint: allow(hot_path) -- page table sized once at pool construction.
            table: vec![NO_PAGE; max_slots * layers * heads * pages_per_stream],
            // lint: allow(hot_path) -- per-slot lengths sized once at pool construction.
            len: vec![0; max_slots],
            // lint: allow(hot_path) -- per-slot capacities sized once at pool construction.
            cap: vec![None; max_slots],
        }
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Concurrent request slots.
    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// Total pages in the pool.
    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    /// Pages currently unassigned.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Slots currently free.
    pub fn free_slots(&self) -> usize {
        self.cap.iter().filter(|c| c.is_none()).count()
    }

    /// Pages a request reserving `tokens` of capacity needs across all its
    /// (layer, head) streams.
    pub fn pages_for(&self, tokens: usize) -> usize {
        self.layers * self.heads * tokens.div_ceil(self.page_size)
    }

    /// Tokens appended to `slot` so far.
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    /// Whether `slot` has no appended tokens.
    pub fn is_empty(&self, slot: usize) -> bool {
        self.len[slot] == 0
    }

    /// Reserved token capacity of an active `slot`.
    pub fn capacity(&self, slot: usize) -> usize {
        // lint: allow(hot_path) -- free-slot misuse is a caller bug; surfacing it beats silent reads.
        self.cap[slot].expect("capacity() on a free slot")
    }

    /// Reserve a slot plus every page `need_tokens` tokens will touch.
    /// Returns the slot id, or `None` when no slot is free or the pool
    /// cannot cover the reservation (caller queues and retries after a
    /// release — eager reservation means admitted requests never stall).
    pub fn try_acquire(&mut self, need_tokens: usize) -> Option<usize> {
        assert!(need_tokens > 0, "a request must reserve at least one token");
        assert!(
            need_tokens <= self.pages_per_stream * self.page_size,
            "reservation of {need_tokens} tokens exceeds the cache's max stream length {}",
            self.pages_per_stream * self.page_size
        );
        let slot = (0..self.max_slots).find(|&s| self.cap[s].is_none())?;
        let need_pages = self.pages_for(need_tokens);
        if self.free.len() < need_pages {
            return None;
        }
        let per_stream = need_tokens.div_ceil(self.page_size);
        for layer in 0..self.layers {
            for head in 0..self.heads {
                let base = self.stream_base(slot, layer, head);
                for p in 0..per_stream {
                    // lint: allow(hot_path) -- reserve() counted pages against the free list above; an empty pop is a bookkeeping bug.
                    self.table[base + p] = self.free.pop().expect("free list undercounted");
                }
            }
        }
        self.cap[slot] = Some(need_tokens);
        self.len[slot] = 0;
        Some(slot)
    }

    /// Return every page of `slot` to the pool and free the slot.
    pub fn release(&mut self, slot: usize) {
        // lint: allow(hot_path) -- releasing a free slot is a double-free; panicking is the contract.
        let cap = self.cap[slot].expect("release() on a free slot");
        let per_stream = cap.div_ceil(self.page_size);
        for layer in 0..self.layers {
            for head in 0..self.heads {
                let base = self.stream_base(slot, layer, head);
                for p in 0..per_stream {
                    debug_assert_ne!(self.table[base + p], NO_PAGE);
                    self.free.push(self.table[base + p]);
                    self.table[base + p] = NO_PAGE;
                }
            }
        }
        self.cap[slot] = None;
        self.len[slot] = 0;
    }

    /// Write one token's K/V rows (`d = heads · hd` wide, heads packed
    /// side by side as in the qkv buffer) into `slot` at position `pos` for
    /// `layer`.  Positions are written once per layer; [`advance`] moves
    /// the slot's length after every layer has seen the token.
    ///
    /// [`advance`]: PagedKvCache::advance
    pub fn write_kv(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let d = self.heads * self.hd;
        debug_assert!(k.len() >= d && v.len() >= d);
        debug_assert!(
            // lint: allow(hot_path) -- inside debug_assert!: compiled out of release decode.
            pos < self.cap[slot].expect("write_kv() on a free slot"),
            "position {pos} outside the slot's reservation"
        );
        let (page_idx, in_page) = (pos / self.page_size, pos % self.page_size);
        for head in 0..self.heads {
            let page = self.table[self.stream_base(slot, layer, head) + page_idx];
            debug_assert_ne!(page, NO_PAGE, "write into an unassigned page");
            let at = (page as usize * self.page_size + in_page) * self.hd;
            let src = head * self.hd;
            self.pool_k[at..at + self.hd].copy_from_slice(&k[src..src + self.hd]);
            self.pool_v[at..at + self.hd].copy_from_slice(&v[src..src + self.hd]);
        }
    }

    /// Advance `slot`'s stream length by `n` freshly written tokens.
    pub fn advance(&mut self, slot: usize, n: usize) {
        // lint: allow(hot_path) -- advancing a free slot is a caller bug; surfacing it beats corrupting the table.
        let cap = self.cap[slot].expect("advance() on a free slot");
        assert!(self.len[slot] + n <= cap, "stream overran its reservation");
        self.len[slot] += n;
    }

    /// One `(page_size × hd)` K tile of a stream (the tail page is valid
    /// only up to the stream length; callers mask by row count).
    pub fn k_page(&self, slot: usize, layer: usize, head: usize, page_idx: usize) -> &[f32] {
        let page = self.table[self.stream_base(slot, layer, head) + page_idx];
        debug_assert_ne!(page, NO_PAGE, "read of an unassigned page");
        let at = page as usize * self.page_size * self.hd;
        &self.pool_k[at..at + self.page_size * self.hd]
    }

    /// One `(page_size × hd)` V tile of a stream.
    pub fn v_page(&self, slot: usize, layer: usize, head: usize, page_idx: usize) -> &[f32] {
        let page = self.table[self.stream_base(slot, layer, head) + page_idx];
        debug_assert_ne!(page, NO_PAGE, "read of an unassigned page");
        let at = page as usize * self.page_size * self.hd;
        &self.pool_v[at..at + self.page_size * self.hd]
    }

    /// Buffer base pointers + free-list capacity — the decode loop's
    /// zero-allocation pin (same contract as `Scratch::fingerprint`).
    pub fn fingerprint(&self) -> Vec<usize> {
        // lint: allow(hot_path) -- fingerprint() is a test/debug pin, not on the decode path.
        vec![
            self.pool_k.as_ptr() as usize,
            self.pool_v.as_ptr() as usize,
            self.free.as_ptr() as usize,
            self.free.capacity(),
            self.table.as_ptr() as usize,
            self.len.as_ptr() as usize,
            self.cap.as_ptr() as usize,
        ]
    }

    fn stream_base(&self, slot: usize, layer: usize, head: usize) -> usize {
        ((slot * self.layers + layer) * self.heads + head) * self.pages_per_stream
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> PagedKvCache {
        // 2 layers × 2 heads × hd 4, 2 slots, streams up to 8 tokens in
        // pages of 3 (deliberately not dividing 8).
        PagedKvCache::new(3, 2, 2, 4, 2, 8, 0)
    }

    #[test]
    fn acquire_write_read_roundtrip() {
        let mut c = tiny();
        let slot = c.try_acquire(5).unwrap();
        let d = 8; // heads · hd
        for pos in 0..5 {
            let k: Vec<f32> = (0..d).map(|j| (pos * d + j) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            for layer in 0..2 {
                c.write_kv(slot, layer, pos, &k, &v);
            }
            c.advance(slot, 1);
        }
        assert_eq!(c.len(slot), 5);
        // Row `pos` of head `h` lands at page pos/3, in-page row pos%3.
        for pos in 0..5 {
            for head in 0..2 {
                let kt = c.k_page(slot, 1, head, pos / 3);
                let row = &kt[(pos % 3) * 4..(pos % 3) * 4 + 4];
                let want: Vec<f32> =
                    (0..4).map(|j| (pos * d + head * 4 + j) as f32).collect();
                assert_eq!(row, &want[..]);
                let vt = c.v_page(slot, 1, head, pos / 3);
                let vrow = &vt[(pos % 3) * 4..(pos % 3) * 4 + 4];
                assert!(vrow.iter().zip(&want).all(|(a, b)| *a == -b));
            }
        }
    }

    #[test]
    fn eager_reservation_and_release_accounting() {
        let mut c = tiny();
        let total = c.n_pages();
        assert_eq!(c.free_pages(), total);
        // 5 tokens in pages of 3 → 2 pages per stream × 4 streams.
        let s0 = c.try_acquire(5).unwrap();
        assert_eq!(c.free_pages(), total - c.pages_for(5));
        let s1 = c.try_acquire(8).unwrap();
        assert_ne!(s0, s1);
        // Both slots busy: a third request is refused even though pages
        // remain only if slots are the bottleneck…
        assert!(c.try_acquire(1).is_none());
        c.release(s0);
        // …and released pages come straight back.
        assert_eq!(c.free_pages(), total - c.pages_for(8));
        let s2 = c.try_acquire(8).unwrap();
        assert_eq!(c.free_pages(), total - 2 * c.pages_for(8));
        c.release(s1);
        c.release(s2);
        assert_eq!(c.free_pages(), total);
        assert_eq!(c.free_slots(), 2);
    }

    #[test]
    fn page_pressure_refuses_admission() {
        // Pool deliberately smaller than slots × full-stream: 1 slot's
        // worth of pages shared by 2 slots.
        let mut c = PagedKvCache::new(4, 1, 1, 4, 2, 8, 2);
        let s0 = c.try_acquire(8).unwrap(); // takes both pages
        assert!(c.try_acquire(1).is_none(), "pool exhausted, must refuse");
        c.release(s0);
        assert!(c.try_acquire(4).is_some(), "released pages readmit");
    }

    #[test]
    fn fingerprint_stable_across_churn() {
        let mut c = tiny();
        let fp = c.fingerprint();
        for round in 0..20 {
            let n = 1 + round % 8;
            let slot = c.try_acquire(n).unwrap();
            let k = vec![0.5f32; 8];
            for pos in 0..n {
                for layer in 0..2 {
                    c.write_kv(slot, layer, pos, &k, &k);
                }
                c.advance(slot, 1);
            }
            c.release(slot);
        }
        assert_eq!(fp, c.fingerprint(), "cache churn must never reallocate");
    }

    #[test]
    #[should_panic(expected = "reservation")]
    fn over_long_reservation_panics() {
        let mut c = tiny();
        let _ = c.try_acquire(9); // max stream is ceil(8/3)·3 = 9 — ok…
        let mut c = tiny();
        let _ = c.try_acquire(10); // …but 10 overruns the page table.
    }
}
