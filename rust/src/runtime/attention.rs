//! The one blocked causal multi-head attention — shared by the serving
//! forward ([`crate::runtime::native`]) and the training forward/backward
//! ([`crate::training::native`]), which were previously byte-duplicated
//! copies that a consistency test pinned together.
//!
//! Formulation (per (sequence, head) pair): the strided head columns of the
//! packed `(rows, 3d)` qkv activation are gathered into contiguous
//! `(t_len × hd)` Q/K/V panels held in a caller-supplied [`AttnWorkspace`],
//! scores `S = Q·Kᵀ` come from one `matmul_nt_f32` call, the causal softmax
//! runs row-wise in place (masked strict upper triangle zeroed so it never
//! contributes), the weighted values `O = S·V` come from one `matmul_f32`
//! call, and the output panel is scattered back into the `(rows × d)`
//! activation buffer.
//!
//! The two callers differ in exactly one way, so it is a parameter: serving
//! discards the softmax probs (`probs = None`, scores live in workspace
//! scratch), training retains them for the backward pass (`probs =
//! Some(buf)`, scores are computed directly in the retained buffer — one
//! `(t_len, t_len)` matrix per (batch, head) pair).
//!
//! **Parallelism:** the `(batch × head)` panel loop fans out over the
//! persistent worker pool ([`crate::linalg::pool`]).  The workspace holds
//! `slots` independent panel sets; chunk `ci` of the pooled dispatch owns
//! slot `ci` and processes pairs `ci, ci+slots, ci+2·slots, …`, so panel
//! buffers are never shared between concurrent chunks and the whole pass
//! stays allocation-free.  Matmuls issued from inside a chunk find the pool
//! busy and run inline — the pool's deadlock-free nesting rule.

use crate::linalg::kernels;
use crate::linalg::pool::{self, SendPtr};

/// Preallocated panel workspace for the blocked attention: `slots`
/// independent sets of Q/K/V/O `(seq × hd)` panels plus one `(seq × seq)`
/// score matrix each.  Sized once; [`causal_attention`] never allocates.
#[derive(Debug)]
pub struct AttnWorkspace {
    seq: usize,
    hd: usize,
    slots: usize,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
    scores: Vec<f32>,
}

impl AttnWorkspace {
    /// Workspace for sequences up to `seq` tokens at head width `hd`, with
    /// `slots` concurrent panel sets (1 = sequential head loop).
    pub fn new(seq: usize, hd: usize, slots: usize) -> AttnWorkspace {
        let slots = slots.max(1);
        AttnWorkspace {
            seq,
            hd,
            slots,
            q: vec![0.0; slots * seq * hd],
            k: vec![0.0; slots * seq * hd],
            v: vec![0.0; slots * seq * hd],
            o: vec![0.0; slots * seq * hd],
            scores: vec![0.0; slots * seq * seq],
        }
    }

    /// Slot count that saturates the worker pool for a panel loop over
    /// `max_pairs = batch × heads` (batch, head) pairs: more slots than
    /// pool threads only waste memory, more than pairs never run.
    pub fn auto_slots(max_pairs: usize) -> usize {
        pool::size().min(max_pairs).max(1)
    }

    /// Buffer base pointers — lets tests assert repeated attention calls
    /// never reallocate (the zero-per-request-allocation invariant).
    pub fn fingerprint(&self) -> Vec<usize> {
        vec![
            self.q.as_ptr() as usize,
            self.k.as_ptr() as usize,
            self.v.as_ptr() as usize,
            self.o.as_ptr() as usize,
            self.scores.as_ptr() as usize,
        ]
    }
}

/// Backward-pass panel workspace: per slot, seven `(seq × hd)` panels
/// (Q/K/V gathers, dO, dQ, dK, dV) plus one `(seq × seq)` dS matrix.
#[derive(Debug)]
pub struct AttnGradWorkspace {
    seq: usize,
    hd: usize,
    slots: usize,
    panels: Vec<f32>,
}

impl AttnGradWorkspace {
    pub fn new(seq: usize, hd: usize, slots: usize) -> AttnGradWorkspace {
        let slots = slots.max(1);
        AttnGradWorkspace {
            seq,
            hd,
            slots,
            panels: vec![0.0; slots * (7 * seq * hd + seq * seq)],
        }
    }

    pub fn fingerprint(&self) -> Vec<usize> {
        vec![self.panels.as_ptr() as usize]
    }
}

/// Scale + causal softmax over the first `t_len` rows of `sc` in place:
/// row `t` normalizes entries `0..=t` and zeroes the strict upper triangle
/// (masked keys must contribute exactly nothing to `S·V`).
fn masked_softmax_rows(sc: &mut [f32], t_len: usize, scale: f32) {
    for t1 in 0..t_len {
        let srow = &mut sc[t1 * t_len..t1 * t_len + t1 + 1];
        let mut mx = f32::NEG_INFINITY;
        for s in srow.iter_mut() {
            *s *= scale;
            if *s > mx {
                mx = *s;
            }
        }
        let mut sum = 0.0f32;
        for s in srow.iter_mut() {
            *s = (*s - mx).exp();
            sum += *s;
        }
        let inv = 1.0 / sum;
        for s in srow.iter_mut() {
            *s *= inv;
        }
        for s in sc[t1 * t_len + t1 + 1..(t1 + 1) * t_len].iter_mut() {
            *s = 0.0;
        }
    }
}

/// Blocked causal multi-head attention over the packed qkv buffer
/// (`(batch·t_len, 3d)`: q | k | v, heads interleaved within each third),
/// merged heads written to `att` (`(batch·t_len, d)`).
///
/// `probs = Some(buf)` retains the causal softmax weights — `buf` must hold
/// `batch · heads · t_len²` floats, one `(t_len, t_len)` matrix per
/// (batch, head) pair — for a training backward pass
/// ([`causal_attention_backward`]); `None` discards them (serving).
///
/// Allocation-free: all intermediates live in `ws`; the `(batch × head)`
/// pair loop fans out over the worker pool, one workspace slot per chunk.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention(
    qkv: &[f32],
    batch: usize,
    t_len: usize,
    d: usize,
    heads: usize,
    ws: &mut AttnWorkspace,
    att: &mut [f32],
    probs: Option<&mut [f32]>,
) {
    assert!(heads > 0 && d % heads == 0, "d {d} not divisible by heads {heads}");
    let hd = d / heads;
    assert_eq!(hd, ws.hd, "workspace head width mismatch");
    assert!(t_len <= ws.seq, "workspace sized for seq {}, got {t_len}", ws.seq);
    let rows = batch * t_len;
    let w3 = 3 * d;
    assert!(qkv.len() >= rows * w3, "qkv buffer too small");
    assert!(att.len() >= rows * d, "att buffer too small");
    let n_pairs = batch * heads;
    if n_pairs == 0 || t_len == 0 {
        return;
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let slots = ws.slots.min(n_pairs);

    let probs_ptr = probs.map(|p| {
        assert_eq!(p.len(), n_pairs * t_len * t_len, "probs buffer size");
        SendPtr(p.as_mut_ptr())
    });
    let att_ptr = SendPtr(att.as_mut_ptr());
    let (qp, kp, vp, op, sp) = (
        SendPtr(ws.q.as_mut_ptr()),
        SendPtr(ws.k.as_mut_ptr()),
        SendPtr(ws.v.as_mut_ptr()),
        SendPtr(ws.o.as_mut_ptr()),
        SendPtr(ws.scores.as_mut_ptr()),
    );
    let panel = ws.seq * ws.hd;
    let smat = ws.seq * ws.seq;

    pool::parallel_for(slots, &|ci| {
        // Safety: slot regions `[ci·panel, ci·panel + t_len·hd)` are
        // disjoint across chunk indices (ci < slots), and `ws` is borrowed
        // mutably for the whole dispatch, so nothing else touches them.
        let (qh, kh, vh, oh, slot_sc) = unsafe {
            (
                std::slice::from_raw_parts_mut(qp.0.add(ci * panel), t_len * hd),
                std::slice::from_raw_parts_mut(kp.0.add(ci * panel), t_len * hd),
                std::slice::from_raw_parts_mut(vp.0.add(ci * panel), t_len * hd),
                std::slice::from_raw_parts_mut(op.0.add(ci * panel), t_len * hd),
                std::slice::from_raw_parts_mut(sp.0.add(ci * smat), t_len * t_len),
            )
        };
        for pair in (ci..n_pairs).step_by(slots) {
            let b = pair / heads;
            let head = pair % heads;
            let base = b * t_len;
            let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
            for t1 in 0..t_len {
                let row = (base + t1) * w3;
                qh[t1 * hd..(t1 + 1) * hd].copy_from_slice(&qkv[row + qo..row + qo + hd]);
                kh[t1 * hd..(t1 + 1) * hd].copy_from_slice(&qkv[row + ko..row + ko + hd]);
                vh[t1 * hd..(t1 + 1) * hd].copy_from_slice(&qkv[row + vo..row + vo + hd]);
            }
            // Scores land directly in the retained probs matrix when the
            // caller keeps them, in the slot scratch otherwise.
            // Safety (Some): pair regions `[pair·t_len², (pair+1)·t_len²)`
            // are disjoint across pairs, and each pair is processed exactly
            // once (strided partition over ci).
            let sc: &mut [f32] = match probs_ptr {
                Some(p) => unsafe {
                    std::slice::from_raw_parts_mut(p.0.add(pair * t_len * t_len), t_len * t_len)
                },
                None => &mut slot_sc[..],
            };
            kernels::matmul_nt_f32(qh, kh, t_len, hd, t_len, sc);
            masked_softmax_rows(sc, t_len, scale);
            kernels::matmul_f32(sc, vh, t_len, t_len, hd, oh);
            for t1 in 0..t_len {
                let dst = (base + t1) * d + head * hd;
                // Safety: pair (b, head) owns columns [head·hd, (head+1)·hd)
                // of rows [base, base + t_len) — disjoint across pairs.
                let out = unsafe { std::slice::from_raw_parts_mut(att_ptr.0.add(dst), hd) };
                out.copy_from_slice(&oh[t1 * hd..(t1 + 1) * hd]);
            }
        }
    });
}

/// Backward through the causal attention: `datt` (rows, d) and the retained
/// `probs` from [`causal_attention`] → `dqkv` (rows, 3d).  Same slot-strided
/// pooled pair loop as the forward; allocation-free given `ws`.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_backward(
    qkv: &[f32],
    probs: &[f32],
    datt: &[f32],
    batch: usize,
    t_len: usize,
    d: usize,
    heads: usize,
    ws: &mut AttnGradWorkspace,
    dqkv: &mut [f32],
) {
    assert!(heads > 0 && d % heads == 0, "d {d} not divisible by heads {heads}");
    let hd = d / heads;
    assert_eq!(hd, ws.hd, "grad workspace head width mismatch");
    assert!(t_len <= ws.seq, "grad workspace sized for seq {}, got {t_len}", ws.seq);
    let rows = batch * t_len;
    let w3 = 3 * d;
    let n_pairs = batch * heads;
    assert!(qkv.len() >= rows * w3 && datt.len() >= rows * d && dqkv.len() >= rows * w3);
    assert!(probs.len() >= n_pairs * t_len * t_len, "probs buffer too small");
    if n_pairs == 0 || t_len == 0 {
        return;
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let slots = ws.slots.min(n_pairs);

    let dqkv_ptr = SendPtr(dqkv.as_mut_ptr());
    let panels_ptr = SendPtr(ws.panels.as_mut_ptr());
    let panel = ws.seq * ws.hd;
    let slot_stride = 7 * panel + ws.seq * ws.seq;

    pool::parallel_for(slots, &|ci| {
        // Safety: slot `ci` owns panels `[ci·slot_stride, (ci+1)·slot_stride)`
        // — disjoint across chunk indices; `ws` is mutably borrowed for the
        // whole dispatch.
        let slot = unsafe {
            std::slice::from_raw_parts_mut(panels_ptr.0.add(ci * slot_stride), slot_stride)
        };
        let (qh, rest) = slot.split_at_mut(panel);
        let (kh, rest) = rest.split_at_mut(panel);
        let (vh, rest) = rest.split_at_mut(panel);
        let (doh, rest) = rest.split_at_mut(panel);
        let (dqh, rest) = rest.split_at_mut(panel);
        let (dkh, rest) = rest.split_at_mut(panel);
        let (dvh, ds) = rest.split_at_mut(panel);
        let (qh, kh, vh) = (&mut qh[..t_len * hd], &mut kh[..t_len * hd], &mut vh[..t_len * hd]);
        let (doh, dqh) = (&mut doh[..t_len * hd], &mut dqh[..t_len * hd]);
        let (dkh, dvh) = (&mut dkh[..t_len * hd], &mut dvh[..t_len * hd]);
        let ds = &mut ds[..t_len * t_len];
        for pair in (ci..n_pairs).step_by(slots) {
            let b = pair / heads;
            let head = pair % heads;
            let base = b * t_len;
            let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
            for t1 in 0..t_len {
                let row = (base + t1) * w3;
                qh[t1 * hd..(t1 + 1) * hd].copy_from_slice(&qkv[row + qo..row + qo + hd]);
                kh[t1 * hd..(t1 + 1) * hd].copy_from_slice(&qkv[row + ko..row + ko + hd]);
                vh[t1 * hd..(t1 + 1) * hd].copy_from_slice(&qkv[row + vo..row + vo + hd]);
                let adst = (base + t1) * d + head * hd;
                doh[t1 * hd..(t1 + 1) * hd].copy_from_slice(&datt[adst..adst + hd]);
            }
            let p = &probs[pair * t_len * t_len..(pair + 1) * t_len * t_len];
            // dV = Pᵀ·dO
            for x in dvh.iter_mut() {
                *x = 0.0;
            }
            kernels::matmul_tn_acc_f32(p, doh, t_len, t_len, hd, dvh);
            // dP = dO·Vᵀ
            kernels::matmul_nt_f32(doh, vh, t_len, hd, t_len, ds);
            // dS = P ⊙ (dP − Σ_j dP⊙P) · scale  (strict upper triangle stays 0)
            for t1 in 0..t_len {
                let prow = &p[t1 * t_len..(t1 + 1) * t_len];
                let dsrow = &mut ds[t1 * t_len..(t1 + 1) * t_len];
                let mut dot = 0f32;
                for j in 0..=t1 {
                    dot += dsrow[j] * prow[j];
                }
                for j in 0..t_len {
                    dsrow[j] = if j <= t1 { prow[j] * (dsrow[j] - dot) * scale } else { 0.0 };
                }
            }
            // dQ = dS·K ; dK = dSᵀ·Q
            kernels::matmul_f32(ds, kh, t_len, t_len, hd, dqh);
            for x in dkh.iter_mut() {
                *x = 0.0;
            }
            kernels::matmul_tn_acc_f32(ds, qh, t_len, t_len, hd, dkh);
            for t1 in 0..t_len {
                let row = (base + t1) * w3;
                // Safety: pair (b, head) owns the q/k/v column ranges of its
                // head within rows [base, base + t_len) — disjoint across
                // pairs (every pair is processed exactly once).
                let (dq, dk, dv) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + qo), hd),
                        std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + ko), hd),
                        std::slice::from_raw_parts_mut(dqkv_ptr.0.add(row + vo), hd),
                    )
                };
                dq.copy_from_slice(&dqh[t1 * hd..(t1 + 1) * hd]);
                dk.copy_from_slice(&dkh[t1 * hd..(t1 + 1) * hd]);
                dv.copy_from_slice(&dvh[t1 * hd..(t1 + 1) * hd]);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Plain scalar causal softmax-attention recurrence — the oracle the
    /// blocked formulation must reproduce (f32 tolerance: the kernels
    /// re-associate the dot/axpy sums).
    fn scalar_reference(qkv: &[f32], batch: usize, t_len: usize, d: usize, heads: usize) -> Vec<f32> {
        let hd = d / heads;
        let w3 = 3 * d;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut att = vec![0f32; batch * t_len * d];
        for b in 0..batch {
            let base = b * t_len;
            for head in 0..heads {
                let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
                for t1 in 0..t_len {
                    let q = &qkv[(base + t1) * w3 + qo..(base + t1) * w3 + qo + hd];
                    let mut sc = vec![0f32; t1 + 1];
                    let mut mx = f32::NEG_INFINITY;
                    for t2 in 0..=t1 {
                        let k = &qkv[(base + t2) * w3 + ko..(base + t2) * w3 + ko + hd];
                        sc[t2] = q.iter().zip(k).map(|(a, b)| a * b).sum::<f32>() * scale;
                        mx = mx.max(sc[t2]);
                    }
                    let mut sum = 0f32;
                    for v in sc.iter_mut() {
                        *v = (*v - mx).exp();
                        sum += *v;
                    }
                    for j in 0..hd {
                        let mut o = 0f32;
                        for (t2, w) in sc.iter().enumerate() {
                            o += w / sum * qkv[(base + t2) * w3 + vo + j];
                        }
                        att[(base + t1) * d + head * hd + j] = o;
                    }
                }
            }
        }
        att
    }

    #[test]
    fn property_blocked_attention_matches_scalar_reference() {
        // Randomized (batch, heads, head width, seq, slot count): the pooled
        // head-parallel path and the probs-retaining path must both agree
        // with the scalar recurrence, and retained probs rows must be causal
        // distributions.
        crate::prop::forall(
            610,
            40,
            |rng| {
                let batch = 1 + rng.below(3);
                let heads = 1 + rng.below(4);
                let hd = 1 + rng.below(6);
                let t_len = 1 + rng.below(12);
                let slots = 1 + rng.below(8);
                let d = heads * hd;
                let qkv: Vec<f32> =
                    (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
                (batch, heads, t_len, slots, qkv)
            },
            |(batch, heads, t_len, slots, qkv)| {
                let (batch, heads, t_len) = (*batch, *heads, *t_len);
                let d = qkv.len() / (batch * t_len * 3);
                let hd = d / heads;
                let want = scalar_reference(qkv, batch, t_len, d, heads);

                let mut ws = AttnWorkspace::new(t_len, hd, *slots);
                let mut att = vec![0f32; batch * t_len * d];
                causal_attention(qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
                for (i, (g, w)) in att.iter().zip(&want).enumerate() {
                    if (g - w).abs() > 1e-4 {
                        return Err(format!("discard-probs att[{i}]: {g} vs {w}"));
                    }
                }

                let mut probs = vec![0f32; batch * heads * t_len * t_len];
                let mut att2 = vec![0f32; batch * t_len * d];
                causal_attention(qkv, batch, t_len, d, heads, &mut ws, &mut att2, Some(&mut probs));
                if att != att2 {
                    return Err("probs-retaining path changed the output".into());
                }
                for (pair, mat) in probs.chunks_exact(t_len * t_len).enumerate() {
                    for t1 in 0..t_len {
                        let row = &mat[t1 * t_len..(t1 + 1) * t_len];
                        let s: f32 = row[..=t1].iter().sum();
                        if (s - 1.0).abs() > 1e-4 {
                            return Err(format!("pair {pair} row {t1} sums to {s}"));
                        }
                        if row[t1 + 1..].iter().any(|&x| x != 0.0) {
                            return Err(format!("pair {pair} row {t1} leaks future keys"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn backward_matches_finite_difference_through_forward() {
        // Central-difference check of dL/dqkv for L = Σ c·att through the
        // shared forward/backward pair, across several slot counts.
        let (batch, heads, hd, t_len) = (2usize, 3usize, 4usize, 5usize);
        let d = heads * hd;
        let mut rng = Rng::new(611);
        let mut qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
        let coef: Vec<f32> = (0..batch * t_len * d).map(|_| rng.normal() as f32).collect();

        let loss = |qkv: &[f32], ws: &mut AttnWorkspace| -> f32 {
            let mut att = vec![0f32; batch * t_len * d];
            causal_attention(qkv, batch, t_len, d, heads, ws, &mut att, None);
            att.iter().zip(&coef).map(|(a, c)| a * c).sum()
        };

        for slots in [1usize, 3, 8] {
            let mut ws = AttnWorkspace::new(t_len, hd, slots);
            let mut gws = AttnGradWorkspace::new(t_len, hd, slots);
            let mut att = vec![0f32; batch * t_len * d];
            let mut probs = vec![0f32; batch * heads * t_len * t_len];
            causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, Some(&mut probs));
            let mut dqkv = vec![0f32; batch * t_len * 3 * d];
            causal_attention_backward(
                &qkv, &probs, &coef, batch, t_len, d, heads, &mut gws, &mut dqkv,
            );

            let eps = 1e-2f32;
            for idx in [0usize, 7, 3 * d - 1, batch * t_len * 3 * d - 5] {
                let orig = qkv[idx];
                qkv[idx] = orig + eps;
                let lp = loss(&qkv, &mut ws);
                qkv[idx] = orig - eps;
                let lm = loss(&qkv, &mut ws);
                qkv[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - dqkv[idx]).abs() < 2e-2 + 0.05 * dqkv[idx].abs(),
                    "slots {slots} dqkv[{idx}]: numeric {num} vs analytic {}",
                    dqkv[idx]
                );
            }
        }
    }

    #[test]
    fn workspace_never_reallocates_across_calls() {
        let (batch, heads, hd, t_len) = (2usize, 4usize, 8usize, 16usize);
        let d = heads * hd;
        let mut rng = Rng::new(612);
        let qkv: Vec<f32> = (0..batch * t_len * 3 * d).map(|_| rng.normal() as f32).collect();
        let mut ws = AttnWorkspace::new(t_len, hd, AttnWorkspace::auto_slots(batch * heads));
        let mut att = vec![0f32; batch * t_len * d];
        causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
        let fp = ws.fingerprint();
        for _ in 0..4 {
            causal_attention(&qkv, batch, t_len, d, heads, &mut ws, &mut att, None);
        }
        assert_eq!(ws.fingerprint(), fp, "attention workspace must not reallocate");
    }
}
